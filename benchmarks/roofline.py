"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and, per
(arch x shape x mesh) cell, derives the three roofline terms for TPU v5e:

    compute    = HLO_FLOPs_per_device / 197e12           [s]
    memory     = HLO_bytes_per_device / 819e9            [s]
    collective = sum_k w_k * bytes_k_per_device / 50e9   [s]

cost_analysis() reports *per-device* flops/bytes for the SPMD module (we
verified this against a hand-computed matmul). collective bytes are parsed
from the optimized HLO result shapes; weights w_k approximate ring-
algorithm traffic: all-reduce 2x (reduce-scatter + all-gather phases),
everything else 1x.

MODEL_FLOPS (the "useful" floor):
    train   6 * N_active * tokens   (fwd+bwd)
    prefill 2 * N_active * tokens
    decode  2 * N_active * batch  + 2 * cache_bytes/2 read as flops-equiv?
            -> decode is bandwidth-bound; we report 2*N_active*B and let
               the memory term carry the cache traffic.
ratio = MODEL_FLOPS / (HLO_FLOPs_per_device * devices): <1 means padding /
recompute / masked-block waste; >1 would flag an accounting bug.
"""

from __future__ import annotations

import glob
import json
import os
import sys

import repro.configs as configs

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

COLL_WEIGHT = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_ACTIVE = {}


def active_params(arch_id: str) -> int:
    if arch_id not in _ACTIVE:
        _ACTIVE[arch_id] = configs.get(arch_id).active_param_count()
    return _ACTIVE[arch_id]


def model_flops(arch_id: str, shape: configs.ShapeCell) -> float:
    n = active_params(arch_id)
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def memory_floor_bytes(arch_id: str, shape: configs.ShapeCell, devices: int) -> float:
    """Analytic per-device HBM-traffic floor, assuming ideal fusion.

    HLO 'bytes accessed' on the CPU-lowered module counts every op's
    operands (no TPU fusion) — a loose upper bound. The floor counts only
    irreducible traffic:
      params streamed through compute: N*wbytes/tp per pass
        (train: 3 passes — fwd, remat recompute, bwd; serve: 1)
      optimizer state R/W (train): fp32 m+v 16B/N, int8 4B/N + grads 8B/N
      activation checkpoints (train): 3 x L*(B/dp)*S*d*2B
      decode: + KV cache read per step
    """
    cfg = configs.get(arch_id)
    n_total = cfg.param_count()
    tp = 16
    dp = devices // tp
    serve_int8 = arch_id in ("arctic-480b", "mistral-large-123b")
    if shape.kind == "train":
        passes, wbytes = 3, 2
        opt = (4 + 8) * n_total / devices if serve_int8 else (16 + 8) * n_total / devices
    elif shape.kind == "prefill":
        passes, wbytes = 1, (1 if serve_int8 else 2)
        opt = 0.0
    else:
        passes, wbytes = 1, (1 if serve_int8 else 2)
        opt = 0.0
    wstream = passes * n_total * wbytes / tp
    act = 0.0
    if shape.kind in ("train", "prefill"):
        b_loc = max(shape.global_batch // dp, 1)
        mult = 3 if shape.kind == "train" else 1
        act = mult * cfg.n_layers * b_loc * shape.seq_len * cfg.d_model * 2
    cache = 0.0
    if shape.kind == "decode" and cfg.n_heads > 0:
        kvb = 1 if serve_int8 else 2  # fp8 vs bf16 cache
        eff_s = min(cfg.window or shape.seq_len, shape.seq_len)
        n_local = sum(1 for k in cfg.pattern if k == "local") / len(cfg.pattern)
        s_eff = n_local * min(cfg.window or shape.seq_len, shape.seq_len) + (
            1 - n_local
        ) * shape.seq_len
        cache = (
            cfg.n_layers * shape.global_batch * s_eff * cfg.n_kv_heads * cfg.hd
            * 2 * kvb / devices
        )
    return wstream + opt + act + cache


def analyze_cell(rec: dict) -> dict | None:
    if rec.get("status") != "OK":
        return None
    arch, shape_name, mesh_name = rec["cell"].split("__")
    shape = configs.SHAPES[shape_name]
    devices = rec["devices"]
    fl = rec["flops_per_device"]
    by = rec["bytes_accessed_per_device"]
    coll = rec.get("collective_bytes_per_device", {})
    t_compute = fl / PEAK_FLOPS
    t_mem_upper = by / HBM_BW
    floor_by = memory_floor_bytes(arch, shape, devices)
    t_mem_floor = floor_by / HBM_BW
    t_coll = sum(COLL_WEIGHT.get(k, 1.0) * v for k, v in coll.items()) / ICI_BW
    # bottleneck model: fused-TPU estimate = max(compute, floor, collective)
    terms = {"compute": t_compute, "memory": t_mem_floor, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(arch, shape)
    hlo_total = fl * devices
    ratio = mf / hlo_total if hlo_total > 0 else float("nan")
    step_time = max(terms.values())
    mfu = mf / devices / PEAK_FLOPS / step_time if step_time > 0 else 0.0
    mem = rec.get("memory_analysis") or {}
    hbm = (
        mem.get("argument_size_in_bytes", 0)
        + mem.get("temp_size_in_bytes", 0)
    )
    return {
        "cell": rec["cell"],
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "devices": devices,
        "t_compute_s": t_compute,
        "t_memory_s": t_mem_floor,
        "t_memory_upper_s": t_mem_upper,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": ratio,
        "roofline_fraction": min(mfu, 1.0),
        "hbm_bytes_per_dev": hbm,
        "fits_16g": hbm <= 16e9,
    }


def suggestion(row: dict) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["useful_ratio"] < 0.5:
            return "cut recompute/masked-block waste (remat policy, kernel causal skip)"
        return "compute-bound near useful flops: increase arithmetic intensity per chip or scale out"
    if d == "memory":
        return "cut bytes: fuse elementwise chains, lower-precision weights/caches, bigger block reuse"
    return "overlap or shrink collectives: fold gathers into compute, int8 collectives, rebalance mesh axes"


def main(out_dir: str = "experiments/dryrun", write: str | None = None):
    rows = []
    skips = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        if f.endswith(".measured.json"):
            continue
        rec = json.load(open(f))
        if rec.get("status") == "SKIP":
            skips.append(rec)
            continue
        # prefer the depth-extrapolated measurement (unrolled 1/2-group
        # variants) for flops/bytes/collectives — the scanned full lowering
        # under-counts loop bodies; keep its memory_analysis (authoritative)
        mf = f.replace(".json", ".measured.json")
        if os.path.exists(mf):
            m = json.load(open(mf))
            if m.get("status") == "OK":
                rec = dict(
                    rec,
                    flops_per_device=m["flops_per_device"],
                    bytes_accessed_per_device=m["bytes_accessed_per_device"],
                    collective_bytes_per_device=m["collective_bytes_per_device"],
                    measured=True,
                )
        row = analyze_cell(rec)
        if row:
            rows.append(row)
    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    lines = []
    hdr = (
        f"| {'cell':44s} | {'compute':>9s} | {'mem-floor':>9s} | {'mem-hlo':>9s} | "
        f"{'collect':>9s} | {'dominant':>10s} | {'useful':>6s} | {'roofline':>8s} | fits |"
    )
    lines.append(hdr)
    lines.append("|" + "-" * (len(hdr) - 2) + "|")
    for r in rows:
        lines.append(
            f"| {r['cell']:44s} | {r['t_compute_s']*1e3:7.1f}ms | "
            f"{r['t_memory_s']*1e3:7.1f}ms | {r['t_memory_upper_s']*1e3:7.1f}ms | "
            f"{r['t_collective_s']*1e3:7.1f}ms | "
            f"{r['dominant']:>10s} | {r['useful_ratio']:6.2f} | "
            f"{r['roofline_fraction']*100:7.1f}% | {'Y' if r['fits_16g'] else 'N':>4s} |"
        )
    for s in skips:
        lines.append(f"| {s['cell']:44s} | SKIP: {s['reason']}")
    text = "\n".join(lines)
    print(text)
    if write:
        with open(write, "w") as fh:
            json.dump({"rows": rows, "skips": skips}, fh, indent=2)
    return rows, skips


if __name__ == "__main__":
    main(write=sys.argv[1] if len(sys.argv) > 1 else "experiments/roofline.json")
