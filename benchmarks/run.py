"""Benchmark harness — one function per paper table + substrate benches.

Prints ``name,us_per_call,derived`` CSV rows. Run:
    PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import sys
import time


def _row(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def main() -> None:
    from benchmarks import paper_tables as pt

    print("name,us_per_call,derived")

    # ---- Paper Table I: training latency (normal / streams / deployed)
    t1 = pt.table1_training_latency()
    for mode, s in t1.items():
        _row(f"table1_training_{mode}", s, f"{s:.2f}s_total")
    _row(
        "table1_stream_overhead", t1["streams"] - t1["normal"],
        f"{(t1['streams'] / t1['normal'] - 1) * 100:.1f}%_vs_normal",
    )
    _row(
        "table1_deploy_overhead", t1["deployed"] - t1["normal"],
        f"{(t1['deployed'] / t1['normal'] - 1) * 100:.1f}%_vs_normal",
    )

    # ---- Paper Table II: inference latency
    t2 = pt.table2_inference_latency()
    for mode, s in t2.items():
        _row(f"table2_inference_{mode}", s, f"{s * 1e3:.2f}ms_batch64")

    # ---- substrate: distributed-log throughput
    tp = pt.log_throughput()
    _row("log_produce", 1.0 / tp["produce_msgs_per_s"],
         f"{tp['produce_MB_per_s']:.0f}MB/s")
    _row("log_consume", 1.0 / tp["consume_msgs_per_s"],
         f"{tp['consume_MB_per_s']:.0f}MB/s")

    # ---- §V stream reuse: control message vs re-ingestion
    ru = pt.stream_reuse_cost()
    _row("stream_ingest_10k", ru["ingest_s"])
    _row("stream_reuse_ctrlmsg", ru["reuse_s"],
         f"{ru['reuse_speedup']:.0f}x_cheaper_{ru['control_msg_bytes']}B")

    # ---- kernels: interpret-mode correctness-path timings (CPU; the TPU
    # numbers come from the §Roofline dry-run, not wall clock)
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention

    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 3)
    q = jax.random.normal(ks[0], (1, 4, 512, 64))
    kk = jax.random.normal(ks[1], (1, 4, 512, 64))
    v = jax.random.normal(ks[2], (1, 4, 512, 64))
    for name, fn in (
        ("mha_ref_xla", lambda: ref.mha(q, kk, v)),
        ("flash_interpret", lambda: flash_attention(q, kk, v, interpret=True)),
    ):
        fn()  # warm
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        _row(f"kernel_{name}", time.perf_counter() - t0)


if __name__ == "__main__":
    main()
