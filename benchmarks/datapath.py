"""Broker→device data-path microbenchmarks (DESIGN.md §10).

The paper's central claim is training/serving *directly from the
stream*; this benchmark measures the path that makes it real — a fetched
``RecordBatch`` becoming device-resident ``jnp`` arrays — and gates the
two optimizations PR-7 added:

* **decode** — µs/batch and bytes/s for decoding one fetched batch of
  fixed-layout records, four ways: the per-record Python baseline
  (``codec.decode(bytes(v))`` per record — what a naive consumer
  writes), the copying matrix path (``to_matrix`` + column slicing, the
  pre-PR-7 vectorized path), the **zero-copy framed view path**
  (``decode_frames``: per-field strided ndarray views over the segment
  buffer, no bytes move), and the **measured fallback copy** (the same
  entry point on a deliberately unaligned layout — one vectorized column
  copy per field). ``DEC_REPS`` slice-interleaved (per_record, framed)
  pairs; ``check_bench.py`` gates the median within-pair speedup at
  ≥ ``10x`` (measures ~1000x+ — the gate floor is deliberately far below
  the quiet-host reading so only a real regression to per-record work
  trips it).
* **overlap** — end-to-end poll→device records/s over a
  :class:`~repro.data.pipeline.StreamingBatchIterator` consumed through
  :func:`~repro.data.pipeline.device_feed`, double-buffered
  (``depth=2``) vs fully serial (``depth=0``), with a jitted
  matmul-stack device step per batch. ``OVR_REPS`` slice-interleaved
  (serial, overlap) pairs so shared-host drift cancels out of the
  within-pair ratio. The file records ``host_cores``
  (``sched_getaffinity``): on a multi-core host the background
  poll+decode+``device_put`` genuinely runs during the device step and
  ``check_bench.py`` gates the median ratio at ≥ 1.05x; on a
  **single-core** host (this reference container) the two legs timeshare
  one CPU — overlap physically cannot beat serial, the theoretical
  ceiling is 1.0 — so the gate instead holds overlap at parity (≥ 0.90x:
  the pipeline must cost nothing to leave on, which is what lets the
  same code path win on real multi-core metal).
* **step** — poll→step records/s feeding a *real* kernel from
  ``kernels/``: each streamed batch reshapes into (B, S, H, D) and runs
  :func:`~repro.kernels.ops.attention_op` (Pallas flash attention,
  interpret mode on CPU), overlap on. Schema-gated (must be present and
  positive); the absolute number is the honest record of what this host
  sustains stream→kernel.

Prints ``name,us_per_call,derived`` CSV rows like the other benchmarks
and writes the full result set to ``BENCH_datapath.json``::

    PYTHONPATH=src python -m benchmarks.datapath

Nightly CI sources ``scripts/profile_env.sh`` first (tcmalloc, XLA
flags) so the recorded numbers reflect the tuned-host configuration.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.log import LogConfig, StreamLog
from repro.data.formats import RawCodec
from repro.data.pipeline import StreamingBatchIterator, device_feed, ingest
from repro.kernels.ops import attention_op

# decode section: 4096 × 260 B records (float32[64] data + int32 label)
DEC_N = 4096
DEC_REPS = 5
DEC_FRAMED_ITERS = 200  # framed decode is ~µs; amortize timer granularity
DEC_COPY_ITERS = 50

# overlap section: 1024-record batches, 2048-record fetches
OVR_N = 24_576
OVR_BATCH = 1024
OVR_FETCH = 2048
OVR_REPS = 9
# tanh(x @ W) repetitions per device step: deep enough that the fixed
# per-batch pipeline cost (queue handoff, thread wakeup) amortizes into
# a realistic device leg — at 8 the handoff tax alone reads ~10% on the
# single-core reference host
OVR_STEP_DEPTH = 24

# step section: records reshape to (B, S, 1, D) for flash attention
STEP_SEQ = 64
STEP_DIM = 64
STEP_EPOCHS = 2

OUT_JSON = "BENCH_datapath.json"


def _row(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def _median(xs: list[float]) -> float:
    ys = sorted(xs)
    return ys[len(ys) // 2]


# ------------------------------------------------------------------- decode
def _decode_fixture(codec: RawCodec, n: int, seed: int = 0):
    """One contiguous fetched batch of n encoded records."""
    rng = np.random.default_rng(seed)
    log = StreamLog()
    log.create_topic("bench", LogConfig(num_partitions=1))
    arrays = {}
    for f in codec.fields:
        if np.issubdtype(np.dtype(f.dtype), np.floating):
            arrays[f.name] = rng.normal(size=(n,) + f.shape).astype(f.dtype)
        else:
            arrays[f.name] = (
                rng.integers(0, 100, size=(n,) + f.shape).astype(f.dtype)
            )
    log.produce_batch("bench", codec.encode_batch(arrays), partition=0)
    return log.read_range("bench", 0, 0, n)


def bench_decode() -> dict:
    codec = RawCodec("float32", (64,), "int32", ())  # 260 B, aligned
    batch = _decode_fixture(codec, DEC_N)
    assert batch.framed(codec.record_bytes) is not None
    nbytes = DEC_N * codec.record_bytes

    def time_per_record() -> float:
        t0 = time.perf_counter()
        out = [codec.decode(bytes(v)) for v in batch.values]
        dt = time.perf_counter() - t0
        assert len(out) == DEC_N
        return dt

    def time_framed() -> float:
        t0 = time.perf_counter()
        for _ in range(DEC_FRAMED_ITERS):
            out = codec.decode_frames(batch)
        dt = (time.perf_counter() - t0) / DEC_FRAMED_ITERS
        assert out["data"].shape == (DEC_N, 64)
        return dt

    # slice-interleaved pairs: each (per_record, framed) pair runs back
    # to back, so the within-pair ratio is immune to absolute-speed drift
    pairs = []
    for _ in range(DEC_REPS):
        pairs.append(
            {"per_record_us": time_per_record() * 1e6,
             "framed_us": time_framed() * 1e6}
        )
    per_rec_s = _median([p["per_record_us"] for p in pairs]) / 1e6
    framed_s = _median([p["framed_us"] for p in pairs]) / 1e6
    speedup = _median([p["per_record_us"] / p["framed_us"] for p in pairs])

    def timed(fn, iters: int) -> float:
        t0 = time.perf_counter()
        for _ in range(iters):
            fn()
        return (time.perf_counter() - t0) / iters

    # pre-PR-7 vectorized path: one (n, record_bytes) copy + column copies
    matrix_s = timed(lambda: codec.decode_matrix(batch.to_matrix()),
                     DEC_COPY_ITERS)

    # measured fallback: a 3-byte uint8 field forces every later offset
    # off-alignment, so decode_frames takes the vectorized column copy
    codec_u = RawCodec("uint8", (3,), "float32", (64,))
    batch_u = _decode_fixture(codec_u, DEC_N, seed=1)
    arrays_u, zero_copy_u = codec_u.decode_span(
        *batch_u.framed(codec_u.record_bytes)[0]
    )
    assert not zero_copy_u  # the fixture really is unaligned
    fallback_s = timed(lambda: codec_u.decode_frames(batch_u),
                       DEC_COPY_ITERS)
    nbytes_u = DEC_N * codec_u.record_bytes

    return {
        "per_record": {
            "us_per_batch": per_rec_s * 1e6,
            "MB_per_s": nbytes / per_rec_s / 1e6,
        },
        "matrix_copy": {
            "us_per_batch": matrix_s * 1e6,
            "MB_per_s": nbytes / matrix_s / 1e6,
        },
        "framed_view": {
            "us_per_batch": framed_s * 1e6,
            "MB_per_s": nbytes / framed_s / 1e6,
            "zero_copy": True,
        },
        "fallback_copy": {
            "us_per_batch": fallback_s * 1e6,
            "MB_per_s": nbytes_u / fallback_s / 1e6,
            "zero_copy": False,
        },
        "pairs": pairs,
        "speedup": speedup,
    }


# ------------------------------------------------------------------ overlap
def _overlap_fixture() -> tuple[StreamLog, object]:
    rng = np.random.default_rng(2)
    log = StreamLog()
    msg = ingest(
        log, "stream", RawCodec("float32", (STEP_DIM,), "int32", ()),
        {
            "data": rng.normal(size=(OVR_N, STEP_DIM)).astype(np.float32),
            "label": np.arange(OVR_N, dtype=np.int32),
        },
        "bench-datapath",
        message_set_size=OVR_FETCH,
    )
    return log, msg


def _make_step():
    @jax.jit
    def step(x, w):
        y = x
        for _ in range(OVR_STEP_DEPTH):
            y = jnp.tanh(y @ w)
        return y.sum()

    w = jnp.eye(STEP_DIM, dtype=jnp.float32) * 0.5
    # warm the compile cache outside the measured region
    step(jnp.zeros((OVR_BATCH, STEP_DIM), jnp.float32), w).block_until_ready()
    return step, w


def _run_pipeline(log, msg, step, w, depth: int) -> float:
    """records/s through poll → zero-copy decode → device_put → step."""
    it = StreamingBatchIterator(
        log, msg, OVR_BATCH, split="all", epochs=1, fetch_records=OVR_FETCH
    )
    n_records = it.steps_per_epoch() * OVR_BATCH
    t0 = time.perf_counter()
    last = None
    for b in device_feed(iter(it), depth=depth):
        last = step(b["data"], w)
    last.block_until_ready()
    return n_records / (time.perf_counter() - t0)


def bench_overlap() -> dict:
    log, msg = _overlap_fixture()
    step, w = _make_step()
    _run_pipeline(log, msg, step, w, 0)  # warm page cache / allocator
    pairs = []
    for _ in range(OVR_REPS):
        pairs.append(
            {
                "serial_records_per_s": _run_pipeline(log, msg, step, w, 0),
                "overlap_records_per_s": _run_pipeline(log, msg, step, w, 2),
            }
        )
    return {
        "serial": {
            "records_per_s": _median(
                [p["serial_records_per_s"] for p in pairs]
            )
        },
        "overlap": {
            "records_per_s": _median(
                [p["overlap_records_per_s"] for p in pairs]
            )
        },
        "pairs": pairs,
        "speedup": _median(
            [
                p["overlap_records_per_s"] / p["serial_records_per_s"]
                for p in pairs
            ]
        ),
        "host_cores": len(os.sched_getaffinity(0)),
    }


# --------------------------------------------------------------------- step
def bench_step(log, msg) -> dict:
    """poll→step through a real Pallas kernel (flash attention)."""
    att_b = OVR_BATCH // STEP_SEQ

    @jax.jit
    def step(x):
        qkv = x.reshape(att_b, STEP_SEQ, 1, STEP_DIM)
        return attention_op(
            qkv, qkv, qkv, causal=True, block_q=STEP_SEQ, block_k=STEP_SEQ
        ).sum()

    step(jnp.zeros((OVR_BATCH, STEP_DIM), jnp.float32)).block_until_ready()
    it = StreamingBatchIterator(
        log, msg, OVR_BATCH, split="all", epochs=STEP_EPOCHS,
        fetch_records=OVR_FETCH,
    )
    steps = it.steps_per_epoch() * STEP_EPOCHS
    t0 = time.perf_counter()
    last = None
    for b in device_feed(iter(it), depth=2):
        last = step(b["data"])
    last.block_until_ready()
    dt = time.perf_counter() - t0
    return {
        "kernel": "attention_op",
        "records_per_s": steps * OVR_BATCH / dt,
        "us_per_step": dt / steps * 1e6,
        "steps": steps,
    }


def main() -> None:
    results: dict = {
        "config": {
            "decode": {"records": DEC_N, "reps": DEC_REPS},
            "overlap": {
                "records": OVR_N,
                "batch": OVR_BATCH,
                "fetch_records": OVR_FETCH,
                "reps": OVR_REPS,
            },
            "step": {"seq": STEP_SEQ, "dim": STEP_DIM,
                     "epochs": STEP_EPOCHS},
            "host_cores": len(os.sched_getaffinity(0)),
        },
    }
    print("name,us_per_call,derived")

    dec = bench_decode()
    results["decode"] = dec
    _row("datapath_decode_per_record", dec["per_record"]["us_per_batch"] / 1e6,
         f"{dec['per_record']['MB_per_s']:.0f}MB/s")
    _row("datapath_decode_matrix_copy",
         dec["matrix_copy"]["us_per_batch"] / 1e6,
         f"{dec['matrix_copy']['MB_per_s']:.0f}MB/s")
    _row("datapath_decode_framed_view",
         dec["framed_view"]["us_per_batch"] / 1e6,
         f"{dec['framed_view']['MB_per_s']:.0f}MB/s_"
         f"{dec['speedup']:.0f}x_vs_per_record")
    _row("datapath_decode_fallback_copy",
         dec["fallback_copy"]["us_per_batch"] / 1e6,
         f"{dec['fallback_copy']['MB_per_s']:.0f}MB/s_unaligned")

    ovr = bench_overlap()
    results["overlap"] = ovr
    _row("datapath_poll_to_device_serial",
         1.0 / ovr["serial"]["records_per_s"],
         f"{ovr['serial']['records_per_s'] / 1e3:.0f}krec/s")
    _row("datapath_poll_to_device_overlap",
         1.0 / ovr["overlap"]["records_per_s"],
         f"{ovr['overlap']['records_per_s'] / 1e3:.0f}krec/s_"
         f"{ovr['speedup']:.2f}x_cores{ovr['host_cores']}")

    log, msg = _overlap_fixture()
    st = bench_step(log, msg)
    results["step"] = st
    _row("datapath_poll_to_kernel_step", st["us_per_step"] / 1e6,
         f"{st['records_per_s'] / 1e3:.0f}krec/s_{st['kernel']}")

    with open(OUT_JSON, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"# wrote {OUT_JSON}")


if __name__ == "__main__":
    main()
