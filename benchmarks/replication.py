"""Replication microbenchmarks: throughput vs rf/acks, producer
contention on the concurrent data plane, idempotent-producer overhead,
and controller-failover latency.

Six sections:

* **single** — append throughput vs replication factor and acks on one
  producer thread, relative to the bare single-broker log (the
  durability/latency trade-off the paper inherits from Kafka, §II).
* **contended** — aggregate throughput with 1/2/4/8 producer threads over
  4 partitions, for each rf × acks, on the per-partition-locked data
  plane; plus the same thread sweep against the pre-refactor data plane
  (``legacy_global_lock=True``: one cluster-wide lock + fetch-based
  synchronous replication) as the baseline. ``speedup_4threads`` is the
  acceptance ratio: concurrent vs global-lock at 4 threads, rf=3,
  acks=all.
* **idempotent** — the exactly-once tax: single-producer rf=3 acks=all
  throughput with and without ``ClusterProducer(idempotent=True)``
  (producer-state bookkeeping + per-batch sequence stamping on the
  leader and every direct-pushed ISR follower). Same slice-interleaved
  pair structure as **transactions** (median per-batch time per side,
  median within-pair ratio over ``IDEM_REPS`` pairs), so shared-host
  drift cancels out of the ratio; plus a contended t4 column.
  ``benchmarks/check_bench.py`` gates the overhead at ≤35% of the
  non-idempotent baseline (recalibrated with the estimator — the PR-4
  back-to-back pairs read ≈0% only because drift swamped the true
  bookkeeping tax, ~15% quiet and up to ~30% under host contention).
* **transactions** — the exactly-once *read-process-write* tax (PR-5):
  committed-transaction throughput (``begin_txn`` → batches →
  ``commit_txn`` every ``TXN_COMMIT_EVERY`` batches, so the measurement
  amortizes the coordinator round-trips and marker writes the way a real
  streaming stage does) against the PR-4 idempotent acks=all baseline.
  Same back-to-back pair structure as **idempotent** (best-of-
  ``TXN_REPS`` pairs, median within-pair ratio, drift-immune);
  ``benchmarks/check_bench.py`` gates the overhead at ≤25%.
* **observability** — the metrics tax (PR-6): a **paired-difference**
  estimator. The instrumentation cost is O(1) per batch (bound
  counter/histogram handles, sampled latency records), so one run
  measures (a) the absolute per-batch delta on *1-record* batches —
  where the ~6 µs tax is ~30% of the batch and resolves cleanly above
  scheduler noise — by toggling ``cluster.metrics.enabled`` in
  shuffled blocks on ONE cluster, and (b) the median baseline batch
  time at the acceptance config (256 records, rf=3, acks=all, metrics
  disabled). The stored pair's instrumented side is
  ``baseline + delta``; a plain ratio-of-medians at 256 records is
  unusable here (the null test shows ±3% bias from multi-hundred-µs
  co-tenant drift, against a ~2% true cost — see
  :func:`bench_observability_run`). ``OBS_REPS`` independent pairs;
  ``benchmarks/check_bench.py`` gates the median within-pair ratio at
  ≤5%.
* **storage** — storage-engine v2 recovery (DESIGN.md §11): (a)
  restart recovery of the producer/txn state table from the newest
  producer-state snapshot + suffix replay vs a full log replay, as
  back-to-back pairs (``check_bench.py`` gates the median within-pair
  speedup at ≥2x — the whole point of snapshotting at segment rolls);
  (b) ``read_committed``'s abort prefilter answering from the spanned
  segments' ``.txnindex`` vs the pre-PR-8 partition-wide abort-list
  scan, recorded as pairs for trend tracking.
* **controller** — quorum-controller failover latency: with the
  replication daemon ticking the control plane, kill the controller
  leader AND a partition leader in the same tick (the partition election
  deferred, so only a newly elected controller can complete it) and
  measure the time until a successor controller has committed the
  partition's new leadership. Best/mean/worst over ``CTRL_REPS`` runs.

Every config runs ``REPS`` times and reports the best run — the host is
shared, and scheduling noise only ever makes a run slower, so the minimum
cost estimates the true cost.

Prints ``name,us_per_call,derived`` CSV rows like :mod:`benchmarks.run`
and writes the full result set to ``BENCH_replication.json``::

    PYTHONPATH=src python -m benchmarks.replication
"""

from __future__ import annotations

import json
import random
import time
from concurrent.futures import ThreadPoolExecutor

from repro.core.cluster import BrokerCluster, ClusterProducer, ReplicationService
from repro.core.log import LogConfig, StreamLog

RECORD_BYTES = 1024
BATCH = 256
BATCHES = 200  # 200 * 256 * 1KiB = 50 MiB per single-producer config

C_RECORD_BYTES = 256
C_BATCH = 256
C_BATCHES = 480  # total across all threads per contended config
C_PARTS = 4
REPS = 3
IDEM_REPS = 9  # slice-interleaved base/idem pairs for the overhead gate
TXN_REPS = 7  # back-to-back idem/txn pairs for the transactions gate
# batches per committed transaction: 32 × 256 records ≈ one commit per
# ~8K records, the cadence a real streaming stage runs at (Kafka Streams
# EOS commits on a ~100 ms interval, thousands of records per txn at
# these rates) — each commit still pays 3 quorum metadata commands plus
# a replicated marker write, all inside the measured time
TXN_COMMIT_EVERY = 32

OBS_REPS = 3  # independent paired-difference runs for the metrics gate
OBS_DELTA_BLOCKS = 60  # amplified (1-record) toggle blocks per run
OBS_DELTA_K = 8  # instrumented + disabled batches per side per block
OBS_BASE_BATCHES = 200  # acceptance-config baseline batches per run

CTRL_REPS = 5
CTRL_LEASE_S = 0.05
CTRL_DAEMON_INTERVAL_S = 0.002

# storage section: segments of idempotent traffic for the recovery pair,
# aborted transactions for the txnindex pair
STORAGE_SEGMENTS = 64
STORAGE_BATCH = 32  # records per segment (segment_bytes sized to match)
STORAGE_RECORD_BYTES = 64
STORAGE_REPS = 5
STORAGE_REBUILDS = 20  # rebuilds per timed side (amplifies sub-ms cost)
STORAGE_TXNS = 400  # aborted/committed transactions on the txnindex log
STORAGE_READS = 200  # tail-window read_committed reads per timed side

OUT_JSON = "BENCH_replication.json"


def _row(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def _throughput(append_batch, n_batches: int = BATCHES) -> dict[str, float]:
    payload = [bytes(RECORD_BYTES) for _ in range(BATCH)]
    append_batch(payload)  # warm topic structures
    t0 = time.perf_counter()
    for _ in range(n_batches):
        append_batch(payload)
    dt = time.perf_counter() - t0
    msgs = n_batches * BATCH
    return {
        "s_per_batch": dt / n_batches,
        "msgs_per_s": msgs / dt,
        "MB_per_s": msgs * RECORD_BYTES / dt / 1e6,
    }


def bench_bare_log() -> dict[str, float]:
    log = StreamLog()
    log.create_topic("bench", LogConfig(num_partitions=1))
    return _throughput(lambda vs: log.produce_batch("bench", vs, partition=0))


def bench_cluster(
    rf: int, acks: int | str, brokers: int = 3, *, idempotent: bool = False
) -> dict[str, float]:
    cluster = BrokerCluster(brokers, default_acks=acks)
    cluster.create_topic(
        "bench", LogConfig(num_partitions=1, replication_factor=rf)
    )
    prod = ClusterProducer(cluster, acks=acks, idempotent=idempotent)
    return _throughput(lambda vs: prod.send_batch("bench", vs, partition=0))


def bench_idempotent_pair_once(
    rf: int = 3,
    acks: int | str = "all",
    slices: int = 8,
    slice_batches: int = 25,
) -> dict[str, float]:
    """One (plain, idempotent) produce throughput pair, with the same
    two noise defenses the transactions pair uses (see
    :func:`bench_txn_pair_once`): the sides are **slice-interleaved**
    (alternating 25-batch runs, so both eat the same host drift instead
    of each eating a different mood of a back-to-back pair), and each
    side's cost is its **median per-batch time** (a scheduler stall on
    one unlucky call would dominate a totals-based ratio)."""
    base_cluster = BrokerCluster(3, default_acks=acks)
    base_cluster.create_topic(
        "bench", LogConfig(num_partitions=1, replication_factor=rf)
    )
    base_prod = ClusterProducer(base_cluster, acks=acks)
    idem_cluster = BrokerCluster(3, default_acks=acks)
    idem_cluster.create_topic(
        "bench", LogConfig(num_partitions=1, replication_factor=rf)
    )
    idem_prod = ClusterProducer(idem_cluster, acks=acks, idempotent=True)
    payload = [bytes(RECORD_BYTES) for _ in range(BATCH)]
    base_prod.send_batch("bench", payload, partition=0)  # warm both sides
    idem_prod.send_batch("bench", payload, partition=0)
    base_t: list[float] = []
    idem_t: list[float] = []
    for _ in range(slices):
        for _ in range(slice_batches):
            t0 = time.perf_counter()
            base_prod.send_batch("bench", payload, partition=0)
            base_t.append(time.perf_counter() - t0)
        for _ in range(slice_batches):
            t0 = time.perf_counter()
            idem_prod.send_batch("bench", payload, partition=0)
            idem_t.append(time.perf_counter() - t0)
    return {
        "baseline_msgs_per_s": BATCH / _median(base_t),
        "idempotent_msgs_per_s": BATCH / _median(idem_t),
    }


def bench_idempotent_pairs(
    rf: int = 3, acks: int | str = "all", reps: int = IDEM_REPS
) -> dict:
    """Baseline vs idempotent at the same config, measured as ``reps``
    slice-interleaved **pairs**. On a shared host the absolute
    throughput of a 0.5 s run can swing 2x between samples, so
    comparing two independent best-ofs is meaningless; the
    *within-pair* ratio is drift-immune, and the gate takes the
    **median** ratio across pairs to kill the remaining outliers.
    Returns the pair list plus best-of rows for display."""
    pairs = [bench_idempotent_pair_once(rf, acks) for _ in range(reps)]
    ratios = sorted(
        p["baseline_msgs_per_s"] / p["idempotent_msgs_per_s"] - 1.0
        for p in pairs
    )

    def best_row(key: str) -> dict[str, float]:
        msgs_per_s = max(p[key] for p in pairs)
        return {
            "msgs_per_s": msgs_per_s,
            "MB_per_s": msgs_per_s * RECORD_BYTES / 1e6,
            "s_per_batch": BATCH / msgs_per_s,
        }

    return {
        "baseline_rf3_acksall": best_row("baseline_msgs_per_s"),
        "idempotent_rf3_acksall": best_row("idempotent_msgs_per_s"),
        "pairs": pairs,
        "overhead_frac": ratios[len(ratios) // 2],  # median
    }


def _median(xs: list[float]) -> float:
    ys = sorted(xs)
    return ys[len(ys) // 2]


def bench_txn_pair_once(
    rf: int = 3,
    commit_every: int = TXN_COMMIT_EVERY,
    slices: int = 8,
    slice_batches: int = 25,
) -> dict[str, float]:
    """One (idempotent baseline, transactional) throughput pair.

    Two noise defenses beyond the PR-4 pair structure, both needed on
    this shared host (whose absolute speed swings 2-3x within seconds
    and whose scheduler stalls individual calls for 100+ ms):

    * the two sides are **interleaved in slices** (alternating 25-batch
      runs) so both see the same drift, instead of back-to-back runs
      that each eat a different host mood;
    * each side's cost is the **median per-batch time** — a stall that
      freezes one unlucky call would otherwise dominate a totals-based
      ratio — with the transactional side's per-commit cost (3 quorum
      metadata commands + the replicated marker write, measured the same
      way) amortized in at its ``commit_every`` cadence.
    """
    base_cluster = BrokerCluster(3, default_acks="all")
    base_cluster.create_topic(
        "bench", LogConfig(num_partitions=1, replication_factor=rf)
    )
    base_prod = ClusterProducer(base_cluster, acks="all", idempotent=True)
    txn_cluster = BrokerCluster(3, default_acks="all")
    txn_cluster.create_topic(
        "bench", LogConfig(num_partitions=1, replication_factor=rf)
    )
    txn_prod = ClusterProducer(txn_cluster, transactional_id="bench-txn")
    payload = [bytes(RECORD_BYTES) for _ in range(BATCH)]
    base_prod.send_batch("bench", payload, partition=0)  # warm both sides
    txn_prod.begin_txn()
    txn_prod.send_batch("bench", payload, partition=0)
    txn_prod.commit_txn()
    base_t: list[float] = []
    txn_t: list[float] = []
    commit_t: list[float] = []
    txn_batches = 0
    for _ in range(slices):
        for _ in range(slice_batches):
            t0 = time.perf_counter()
            base_prod.send_batch("bench", payload, partition=0)
            base_t.append(time.perf_counter() - t0)
        for _ in range(slice_batches):
            if txn_batches % commit_every == 0:
                t0 = time.perf_counter()
                if txn_prod.in_txn:
                    txn_prod.commit_txn()
                txn_prod.begin_txn()
                commit_t.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            txn_prod.send_batch("bench", payload, partition=0)
            txn_t.append(time.perf_counter() - t0)
            txn_batches += 1
    t0 = time.perf_counter()
    txn_prod.commit_txn()  # the tail commit counts too
    commit_t.append(time.perf_counter() - t0)
    base_cost = _median(base_t)
    txn_cost = _median(txn_t) + _median(commit_t) / commit_every
    return {
        "baseline_msgs_per_s": BATCH / base_cost,
        "txn_msgs_per_s": BATCH / txn_cost,
    }


def bench_txn_pairs(rf: int = 3, reps: int = TXN_REPS) -> dict:
    """Transactional vs idempotent acks=all at the same config, as
    slice-interleaved pairs (the PR-4 ``IDEM_REPS`` pattern, tightened):
    the within-pair ratio cancels shared-host drift, and the gate takes
    the median across pairs. Returns the pair list plus best-of summary
    rows for display."""
    pairs = [bench_txn_pair_once(rf) for _ in range(reps)]
    ratios = sorted(
        p["baseline_msgs_per_s"] / p["txn_msgs_per_s"] - 1.0 for p in pairs
    )

    def best_row(key: str) -> dict[str, float]:
        msgs_per_s = max(p[key] for p in pairs)
        return {
            "msgs_per_s": msgs_per_s,
            "MB_per_s": msgs_per_s * RECORD_BYTES / 1e6,
            "s_per_batch": BATCH / msgs_per_s,
        }

    return {
        "baseline_idem_rf3_acksall": best_row("baseline_msgs_per_s"),
        "txn_rf3_acksall": best_row("txn_msgs_per_s"),
        "pairs": pairs,
        "overhead_frac": ratios[len(ratios) // 2],  # median
        "commit_every_batches": TXN_COMMIT_EVERY,
    }


# ---------------------------------------------------- observability overhead
def bench_observability_run(rf: int = 3, seed: int = 0) -> dict[str, float]:
    """One paired-difference measurement of the instrumentation tax;
    returns one ``(baseline, instrumented)`` throughput pair.

    The instrumented produce path adds a fixed per-batch cost — bound
    counter handles, two sampled histogram records, a handful of
    ``perf_counter`` calls — and **no per-record work** (every ``inc``
    takes the record count as an argument). So the tax is measured where
    it is *measurable* and applied where it is *paid*:

    1. **Delta stage** (amplified): 1-record batches, where the ~6 µs
       tax is ~30% of the batch time and resolves far above scheduler
       noise. ONE cluster serves both sides by toggling
       ``cluster.metrics.enabled`` between batches — no second-cluster
       allocation/layout confound, and the off side pays exactly the
       disabled-registry guard cost. Each block runs ``OBS_DELTA_K``
       instrumented + ``OBS_DELTA_K`` disabled batches in *shuffled*
       order (a fixed pattern aliases with periodic cluster work such
       as segment rolls); the block's delta is the difference of the
       two within-block medians, and the run's delta is the median over
       ``OBS_DELTA_BLOCKS`` blocks. Null runs (toggle wired off) land
       within ±0.3 µs.
    2. **Baseline stage**: median batch time at the acceptance config
       (``BATCH`` × ``RECORD_BYTES``, rf, acks=all) with the registry
       disabled, over ``OBS_BASE_BATCHES`` batches.

    The pair's instrumented side is ``t_base + delta``. A direct
    ratio-of-medians at the 256-record config is unusable on this
    shared host: its null test shows ±3% bias from multi-hundred-µs
    co-tenant drift, swamping the ~2% true cost; the paired-difference
    null lands within ±0.1%.
    """
    rng = random.Random(seed)
    cluster = BrokerCluster(3, default_acks="all")  # metrics on (default)
    cluster.create_topic(
        "bench", LogConfig(num_partitions=1, replication_factor=rf)
    )
    prod = ClusterProducer(cluster, acks="all")
    m = cluster.metrics
    k = OBS_DELTA_K

    # -- delta stage: absolute per-batch tax, amplified on tiny batches
    tiny = [b"x"]
    for _ in range(100):  # warm past the histogram sampling threshold
        prod.send_batch("bench", tiny, partition=0)
    deltas: list[float] = []
    for _ in range(OBS_DELTA_BLOCKS):
        order = [True] * k + [False] * k
        rng.shuffle(order)
        on_t: list[float] = []
        off_t: list[float] = []
        for instrumented in order:
            m.enabled = instrumented
            t0 = time.perf_counter()
            prod.send_batch("bench", tiny, partition=0)
            dt = time.perf_counter() - t0
            (on_t if instrumented else off_t).append(dt)
        on_t.sort()
        off_t.sort()
        deltas.append(on_t[k // 2] - off_t[k // 2])
    deltas.sort()
    delta = deltas[len(deltas) // 2]

    # -- baseline stage: acceptance-config batch time, registry disabled
    m.enabled = False
    payload = [bytes(RECORD_BYTES) for _ in range(BATCH)]
    for _ in range(40):
        prod.send_batch("bench", payload, partition=0)
    base_t: list[float] = []
    for _ in range(OBS_BASE_BATCHES):
        t0 = time.perf_counter()
        prod.send_batch("bench", payload, partition=0)
        base_t.append(time.perf_counter() - t0)
    m.enabled = True
    base_t.sort()
    t_base = base_t[len(base_t) // 2]

    return {
        "baseline_msgs_per_s": BATCH / t_base,
        "instrumented_msgs_per_s": BATCH / (t_base + delta),
        "delta_us_per_batch": delta * 1e6,
        "baseline_us_per_batch": t_base * 1e6,
    }


def bench_observability_pairs(rf: int = 3, reps: int = OBS_REPS) -> dict:
    """Instrumented vs metrics-disabled produce at the acceptance config
    (rf=3, acks=all): ``reps`` independent paired-difference runs (one
    stored pair each — see :func:`bench_observability_run`); the gate
    takes the median within-pair ratio and budgets it at ≤5%."""
    pairs: list[dict[str, float]] = []
    for rep in range(reps):
        pairs.append(bench_observability_run(rf, seed=rep))
    ratios = sorted(
        p["baseline_msgs_per_s"] / p["instrumented_msgs_per_s"] - 1.0
        for p in pairs
    )

    def best_row(key: str) -> dict[str, float]:
        msgs_per_s = max(p[key] for p in pairs)
        return {
            "msgs_per_s": msgs_per_s,
            "MB_per_s": msgs_per_s * RECORD_BYTES / 1e6,
            "s_per_batch": BATCH / msgs_per_s,
        }

    return {
        "baseline_nometrics_rf3_acksall": best_row("baseline_msgs_per_s"),
        "instrumented_rf3_acksall": best_row("instrumented_msgs_per_s"),
        "pairs": pairs,
        "overhead_frac": ratios[len(ratios) // 2],  # median
    }


# ------------------------------------------------------- contended producers
def _contended_once(
    threads: int, rf: int, acks: int | str, *, legacy: bool,
    idempotent: bool = False,
) -> dict[str, float]:
    cluster = BrokerCluster(3, default_acks=acks, legacy_global_lock=legacy)
    cluster.create_topic(
        "bench", LogConfig(num_partitions=C_PARTS, replication_factor=rf)
    )
    payload = [bytes(C_RECORD_BYTES) for _ in range(C_BATCH)]
    for p in range(C_PARTS):  # warm every partition
        cluster.produce_batch("bench", payload, partition=p)
    per_thread = max(C_BATCHES // threads, 1)

    def worker(tid: int) -> None:
        prod = ClusterProducer(cluster, acks=acks, idempotent=idempotent)
        for _ in range(per_thread):
            prod.send_batch("bench", payload, partition=tid % C_PARTS)

    t0 = time.perf_counter()
    with ThreadPoolExecutor(threads) as pool:
        list(pool.map(worker, range(threads)))
    dt = time.perf_counter() - t0
    msgs = per_thread * threads * C_BATCH
    return {
        "msgs_per_s": msgs / dt,
        "MB_per_s": msgs * C_RECORD_BYTES / dt / 1e6,
        "seconds": dt,
    }


def bench_contended(
    threads: int, rf: int, acks: int | str, *, legacy: bool = False,
    idempotent: bool = False,
) -> dict[str, float]:
    best: dict[str, float] | None = None
    for _ in range(REPS):
        r = _contended_once(threads, rf, acks, legacy=legacy,
                            idempotent=idempotent)
        if best is None or r["msgs_per_s"] > best["msgs_per_s"]:
            best = r
    return best


# ------------------------------------------------------ controller failover
def _controller_failover_once() -> float:
    """One double-kill failover: controller leader + partition leader die
    in the same tick; returns seconds until a successor controller has
    committed new partition leadership (the daemon does all the work)."""
    cluster = BrokerCluster(
        3, default_acks="all", controller_lease_s=CTRL_LEASE_S
    )
    cluster.create_topic(
        "bench", LogConfig(num_partitions=1, replication_factor=3)
    )
    prod = ClusterProducer(cluster, acks="all")
    prod.send_batch("bench", [bytes(C_RECORD_BYTES)] * 64, partition=0)
    with ReplicationService(
        cluster, interval_s=CTRL_DAEMON_INTERVAL_S, workers=2
    ):
        victim = cluster.leader_for("bench", 0)
        t0 = time.perf_counter()
        cluster.kill_controller()
        cluster.kill_broker(victim, defer_election=True)
        deadline = t0 + 30.0
        while cluster.leader_for("bench", 0) == victim:
            if time.perf_counter() > deadline:
                # fail fast with state instead of stalling the nightly job
                raise RuntimeError(
                    "controller failover never completed: "
                    f"{cluster.controller.describe()}"
                )
            time.sleep(0.0002)
        dt = time.perf_counter() - t0
    # sanity: the new leader accepts acks=all traffic end to end
    prod.send_batch("bench", [b"post-failover"], partition=0)
    return dt


def bench_controller_failover() -> dict[str, float]:
    times = [_controller_failover_once() for _ in range(CTRL_REPS)]
    return {
        "best_s": min(times),
        "mean_s": sum(times) / len(times),
        "worst_s": max(times),
        "reps": CTRL_REPS,
        "lease_s": CTRL_LEASE_S,
        "daemon_interval_s": CTRL_DAEMON_INTERVAL_S,
    }


def bench_storage_recovery_pairs(reps: int = STORAGE_REPS) -> dict:
    """Restart recovery: rebuild the producer/txn state table from the
    newest producer-state snapshot + suffix replay vs a full replay from
    the log start, on the same log (``STORAGE_SEGMENTS`` segments of
    idempotent traffic — recovery work the dedup table actually pays).
    Back-to-back pairs, so host drift cancels out of the ratio."""
    log = StreamLog()
    log.create_topic("bench", LogConfig(
        num_partitions=1,
        segment_bytes=STORAGE_BATCH * STORAGE_RECORD_BYTES,
    ))
    seq = 0
    payload = [bytes(STORAGE_RECORD_BYTES)] * STORAGE_BATCH
    for _ in range(STORAGE_SEGMENTS):
        log.producer_append("bench", 0, payload, None, 0,
                            pid=1, epoch=0, seq=seq)
        seq += STORAGE_BATCH
    part = log._partition("bench", 0)
    assert part.snapshots, "no producer-state snapshots were taken"
    pairs: list[dict[str, float]] = []
    for _ in range(reps):
        saved = part.snapshots
        part.snapshots = []  # force the full-replay path
        t0 = time.perf_counter()
        for _ in range(STORAGE_REBUILDS):
            part._rebuild_producer_state()
        replay_s = (time.perf_counter() - t0) / STORAGE_REBUILDS
        part.snapshots = saved  # snapshot + suffix replay
        t0 = time.perf_counter()
        for _ in range(STORAGE_REBUILDS):
            part._rebuild_producer_state()
        snapshot_s = (time.perf_counter() - t0) / STORAGE_REBUILDS
        pairs.append({"replay_s": replay_s, "snapshot_s": snapshot_s})
    speedups = sorted(p["replay_s"] / p["snapshot_s"] for p in pairs)
    return {
        "pairs": pairs,
        "replay_full": {"best_s": min(p["replay_s"] for p in pairs)},
        "snapshot_suffix": {"best_s": min(p["snapshot_s"] for p in pairs)},
        "speedup": speedups[len(speedups) // 2],  # median
        "config": {
            "segments": STORAGE_SEGMENTS,
            "records": STORAGE_SEGMENTS * STORAGE_BATCH,
            "rebuilds_per_side": STORAGE_REBUILDS,
            "reps": reps,
        },
    }


def bench_storage_txnindex_pairs(reps: int = STORAGE_REPS) -> dict:
    """read_committed abort prefilter: the per-segment ``.txnindex``
    (consults only the segments a read spans) vs the pre-PR-8
    partition-wide abort-list scan, on a log carrying ``STORAGE_TXNS``
    resolved transactions. Each timed side serves ``STORAGE_READS``
    tail-window reads; the fullscan side re-runs the old prefilter (a
    pass over the whole abort history) on top of the same read."""
    log = StreamLog()
    log.create_topic("bench", LogConfig(
        num_partitions=1,
        segment_bytes=STORAGE_BATCH * STORAGE_RECORD_BYTES,
    ))
    for i in range(STORAGE_TXNS):
        log.producer_append(
            "bench", 0, [bytes(STORAGE_RECORD_BYTES)], None, 0,
            pid=7, epoch=0, seq=i, txn=True,
        )
        log.append_control("bench", 0, 7, 0, abort=(i % 2 == 0))
    part = log._partition("bench", 0)
    assert len(part.aborted) == STORAGE_TXNS // 2
    lo = max(0, log.end_offset("bench", 0) - STORAGE_BATCH)
    hi = lo + STORAGE_BATCH

    def old_prefilter() -> dict:
        # the pre-.txnindex path: every read walked the partition-wide
        # abort history to collect ranges overlapping its window
        ranges: dict[int, list[tuple[int, int]]] = {}
        for pid, first, marker in part.aborted:
            if first < hi and marker > lo:
                ranges.setdefault(pid, []).append((first, marker))
        return ranges

    pairs: list[dict[str, float]] = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(STORAGE_READS):
            log.read("bench", 0, lo, STORAGE_BATCH,
                     isolation="read_committed")
        indexed_us = (time.perf_counter() - t0) * 1e6 / STORAGE_READS
        t0 = time.perf_counter()
        for _ in range(STORAGE_READS):
            old_prefilter()
            log.read("bench", 0, lo, STORAGE_BATCH,
                     isolation="read_committed")
        fullscan_us = (time.perf_counter() - t0) * 1e6 / STORAGE_READS
        pairs.append({"indexed_us": indexed_us, "fullscan_us": fullscan_us})
    speedups = sorted(p["fullscan_us"] / p["indexed_us"] for p in pairs)
    return {
        "pairs": pairs,
        "indexed": {"best_us": min(p["indexed_us"] for p in pairs)},
        "fullscan": {"best_us": min(p["fullscan_us"] for p in pairs)},
        "speedup": speedups[len(speedups) // 2],  # median
        "config": {
            "transactions": STORAGE_TXNS,
            "reads_per_side": STORAGE_READS,
            "window_records": STORAGE_BATCH,
            "reps": reps,
        },
    }


def main() -> None:
    results: dict = {
        "config": {
            "single": {"record_bytes": RECORD_BYTES, "batch": BATCH,
                       "batches": BATCHES},
            "contended": {"record_bytes": C_RECORD_BYTES, "batch": C_BATCH,
                          "batches_total": C_BATCHES, "partitions": C_PARTS,
                          "reps_best_of": REPS},
        },
        "single": {},
        "contended": {},
    }
    print("name,us_per_call,derived")
    base = bench_bare_log()
    results["single"]["bare_streamlog"] = base
    _row(
        "replication_bare_streamlog", base["s_per_batch"],
        f"{base['MB_per_s']:.0f}MB/s",
    )
    for rf in (1, 2, 3):
        for acks in (0, 1, "all"):
            r = bench_cluster(rf, acks)
            rel = base["MB_per_s"] / r["MB_per_s"]
            results["single"][f"rf{rf}_acks{acks}"] = r
            _row(
                f"replication_rf{rf}_acks{acks}", r["s_per_batch"],
                f"{r['MB_per_s']:.0f}MB/s_{rel:.2f}x_vs_bare",
            )

    # contended grid on the concurrent (per-partition-locked) data plane
    for threads in (1, 2, 4, 8):
        for rf in (1, 2, 3):
            for acks in (0, 1, "all"):
                r = bench_contended(threads, rf, acks)
                name = f"contended_t{threads}_rf{rf}_acks{acks}"
                results["contended"][name] = r
                _row(name, 1.0 / r["msgs_per_s"],
                     f"{r['msgs_per_s'] / 1e3:.0f}kmsg/s")
    # pre-refactor baseline: global data-plane lock + fetch-based
    # synchronous replication, same thread sweep at the acceptance config
    for threads in (1, 2, 4, 8):
        r = bench_contended(threads, 3, "all", legacy=True)
        name = f"contended_t{threads}_rf3_acksall_globallock"
        results["contended"][name] = r
        _row(name, 1.0 / r["msgs_per_s"],
             f"{r['msgs_per_s'] / 1e3:.0f}kmsg/s_baseline")

    new4 = results["contended"]["contended_t4_rf3_acksall"]["msgs_per_s"]
    old4 = results["contended"]["contended_t4_rf3_acksall_globallock"]["msgs_per_s"]
    results["speedup_4threads"] = new4 / old4
    _row("contended_speedup_4threads", 0.0, f"{new4 / old4:.2f}x_vs_global_lock")

    # idempotent-producer column: the exactly-once tax at the acceptance
    # config (rf=3, acks=all), IDEM_REPS slice-interleaved pairs, median
    # within-pair ratio; check_bench gates it at <= 35%
    results["idempotent"] = idem_section = bench_idempotent_pairs(3, "all")
    idem = idem_section["idempotent_rf3_acksall"]
    overhead = idem_section["overhead_frac"]
    _row(
        "replication_rf3_acksall_idempotent", idem["s_per_batch"],
        f"{idem['MB_per_s']:.0f}MB/s_{overhead * 100:+.1f}%_overhead",
    )
    r = bench_contended(4, 3, "all", idempotent=True)
    results["contended"]["contended_t4_rf3_acksall_idem"] = r
    _row("contended_t4_rf3_acksall_idem", 1.0 / r["msgs_per_s"],
         f"{r['msgs_per_s'] / 1e3:.0f}kmsg/s_idempotent")

    # transactional column: committed-txn throughput vs the idempotent
    # acks=all baseline, TXN_REPS back-to-back pairs, median within-pair
    # ratio; check_bench gates it at <= 25%
    results["transactions"] = txn_section = bench_txn_pairs(3)
    txn = txn_section["txn_rf3_acksall"]
    overhead = txn_section["overhead_frac"]
    _row(
        "replication_rf3_acksall_txn", txn["s_per_batch"],
        f"{txn['MB_per_s']:.0f}MB/s_{overhead * 100:+.1f}%_overhead"
        f"_commit_every_{TXN_COMMIT_EVERY}",
    )

    # observability column: instrumented vs metrics-disabled produce at
    # the acceptance config, paired-difference estimator (amplified
    # per-batch delta + measured baseline, one pair per rep), median
    # within-pair ratio; check_bench gates it at <= 5%
    results["observability"] = obs_section = bench_observability_pairs(3)
    obs = obs_section["instrumented_rf3_acksall"]
    overhead = obs_section["overhead_frac"]
    _row(
        "replication_rf3_acksall_instrumented", obs["s_per_batch"],
        f"{obs['MB_per_s']:.0f}MB/s_{overhead * 100:+.1f}%_overhead",
    )

    # storage engine v2: restart-recovery snapshot-vs-replay pairs
    # (gated >=2x) and the txnindex-vs-fullscan read_committed prefilter
    rec = bench_storage_recovery_pairs()
    tx = bench_storage_txnindex_pairs()
    results["storage"] = {"recovery": rec, "txnindex": tx}
    _row("storage_recovery_snapshot", rec["snapshot_suffix"]["best_s"],
         f"{rec['speedup']:.1f}x_vs_full_replay")
    _row("storage_txnindex_read", tx["indexed"]["best_us"] / 1e6,
         f"{tx['speedup']:.1f}x_vs_abortlist_fullscan")

    # controller-leader + partition-leader double-kill failover latency
    fo = bench_controller_failover()
    results["controller"] = {"failover": fo}
    _row("controller_failover", fo["best_s"],
         f"{fo['best_s'] * 1e3:.1f}ms_best_{fo['mean_s'] * 1e3:.1f}ms_mean")

    with open(OUT_JSON, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"# wrote {OUT_JSON}")


if __name__ == "__main__":
    main()
