"""Replication microbenchmark: append throughput vs replication factor/acks.

Quantifies what the replicated substrate costs relative to the bare
single-broker log — the durability/latency trade-off the paper inherits
from Kafka (§II). Prints ``name,us_per_call,derived`` CSV rows like
:mod:`benchmarks.run`:

    PYTHONPATH=src python -m benchmarks.replication
"""

from __future__ import annotations

import time

from repro.core.cluster import BrokerCluster, ClusterProducer
from repro.core.log import LogConfig, StreamLog

RECORD_BYTES = 1024
BATCH = 256
BATCHES = 200  # 200 * 256 * 1KiB = 50 MiB per config


def _row(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def _throughput(append_batch, n_batches: int = BATCHES) -> dict[str, float]:
    payload = [bytes(RECORD_BYTES) for _ in range(BATCH)]
    append_batch(payload)  # warm topic structures
    t0 = time.perf_counter()
    for _ in range(n_batches):
        append_batch(payload)
    dt = time.perf_counter() - t0
    msgs = n_batches * BATCH
    return {
        "s_per_batch": dt / n_batches,
        "msgs_per_s": msgs / dt,
        "MB_per_s": msgs * RECORD_BYTES / dt / 1e6,
    }


def bench_bare_log() -> dict[str, float]:
    log = StreamLog()
    log.create_topic("bench", LogConfig(num_partitions=1))
    return _throughput(lambda vs: log.produce_batch("bench", vs, partition=0))


def bench_cluster(rf: int, acks: int | str, brokers: int = 3) -> dict[str, float]:
    cluster = BrokerCluster(brokers, default_acks=acks)
    cluster.create_topic(
        "bench", LogConfig(num_partitions=1, replication_factor=rf)
    )
    prod = ClusterProducer(cluster, acks=acks)
    return _throughput(lambda vs: prod.send_batch("bench", vs, partition=0))


def main() -> None:
    print("name,us_per_call,derived")
    base = bench_bare_log()
    _row(
        "replication_bare_streamlog", base["s_per_batch"],
        f"{base['MB_per_s']:.0f}MB/s",
    )
    for rf in (1, 2, 3):
        for acks in (0, 1, "all"):
            r = bench_cluster(rf, acks)
            rel = base["MB_per_s"] / r["MB_per_s"]
            _row(
                f"replication_rf{rf}_acks{acks}", r["s_per_batch"],
                f"{r['MB_per_s']:.0f}MB/s_{rel:.2f}x_vs_bare",
            )


if __name__ == "__main__":
    main()
