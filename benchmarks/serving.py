"""LM serving benchmark: continuous vs wave batching (DESIGN.md §13).

The workload the wave design admits it cannot serve well: requests
arrive in mixed prompt lengths with spread ``max_new`` budgets, in
arrival order (lengths interleaved, as a streaming request topic
delivers them). The wave engine must cut equal-length waves from that
order — underfilled waves, lanes idling until the longest sequence in a
wave finishes — while the continuous engine admits each request into the
in-flight decode batch the moment a slot frees.

Measured:

* **throughput** — ``REPS`` slice-interleaved (wave, continuous) pairs
  over the identical request set, recording each side's generated
  tokens/s AND the raw per-request TTFT samples (first-token timestamp
  minus submit timestamp), so ``check_bench.py --serving`` recomputes
  the median within-pair speedup and the p50/p99 TTFT from the stored
  pairs — never trusting stored ratios. Host-aware gate: continuous must
  beat wave tokens/s on any host (the win is algorithmic — fewer wasted
  lane steps — not a parallelism artifact), with a lower floor on the
  1-core reference container where per-admission batch-1 prefills
  timeshare with decode.
* **batch_sweep** — continuous tokens/s vs ``n_slots`` (the serving
  capacity curve; schema-gated, recorded not floored).
* **lane_utilization** — useful/total lane steps per engine, the direct
  measure of the idle-lane waste continuous batching removes.

Prints ``name,us_per_call,derived`` CSV rows like the other benchmarks
and writes the full result set to ``BENCH_serving.json``::

    PYTHONPATH=src python -m benchmarks.serving

Nightly CI sources ``scripts/profile_env.sh`` first (tcmalloc, XLA
flags) so the recorded numbers reflect the tuned-host configuration.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

import jax

import repro.configs as C
from repro.models.model import StreamModel
from repro.models.policy import Policy
from repro.serve.lm_engine import ContinuousLMEngine, LMEngine, Request

OUT_JSON = "BENCH_serving.json"
REPS = 5  # slice-interleaved (wave, continuous) pairs
N_SLOTS = 4
S_CACHE = 64  # wave cache: fits max plen + max_new
BLOCK = 8
N_BLOCKS = 48
MAX_BLOCKS = 8
PLENS = (8, 16, 24)
N_REQ = 18
SWEEP_SLOTS = (1, 2, 4, 8)


def _row(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def _median(xs) -> float:
    s = sorted(xs)
    return s[len(s) // 2]


def _build_model():
    cfg = C.get_reduced("yi-6b")
    model = StreamModel(cfg, Policy(param_dtype="float32", compute_dtype="float32"))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _workload(cfg, seed: int = 0) -> list[Request]:
    """Mixed lengths in arrival order: lengths interleave, so the wave
    engine cannot fill equal-length waves from the queue head."""
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(N_REQ):
        plen = PLENS[rid % len(PLENS)]
        reqs.append(Request(
            rid, rng.integers(0, cfg.vocab, plen).astype(np.int32),
            int(rng.integers(4, 17)),
        ))
    return reqs


def _run_side(engine, reqs) -> dict:
    """Submit the whole set, drain, record tokens/s + raw TTFT samples."""
    submit_t = {}
    t0 = time.perf_counter()
    for r in reqs:
        submit_t[r.req_id] = time.perf_counter()
        engine.submit(r)
    done = engine.run_until_drained()
    elapsed = time.perf_counter() - t0
    toks = sum(len(gen) for _rid, gen in done)
    assert len(done) == len(reqs)
    ttft = [engine.first_token_s[r.req_id] - submit_t[r.req_id] for r in reqs]
    return {"tokens": toks, "elapsed_s": elapsed,
            "tokens_per_s": toks / elapsed, "ttft_s": ttft}


def bench_throughput(model, params) -> dict:
    cfg = model.cfg
    reqs = _workload(cfg)
    wave = LMEngine(model, params, n_slots=N_SLOTS, s_cache=S_CACHE)
    cont = ContinuousLMEngine(
        model, params, n_slots=N_SLOTS, n_blocks=N_BLOCKS,
        block_size=BLOCK, max_blocks=MAX_BLOCKS,
    )
    # warm-up: compile every prefill shape + the decode steps outside the
    # timed region (both sides equally)
    _run_side(wave, reqs)
    _run_side(cont, reqs)
    # slice-interleaved pairs: wave then continuous back to back per rep,
    # so shared-host drift cancels out of the within-pair ratio
    pairs = []
    for _ in range(REPS):
        w = _run_side(wave, reqs)
        c = _run_side(cont, reqs)
        pairs.append({
            "wave_tokens_per_s": w["tokens_per_s"],
            "continuous_tokens_per_s": c["tokens_per_s"],
            "wave_ttft_s": w["ttft_s"],
            "continuous_ttft_s": c["ttft_s"],
        })
    speedup = _median(
        [p["continuous_tokens_per_s"] / p["wave_tokens_per_s"] for p in pairs]
    )
    wave_ttft = sorted(t for p in pairs for t in p["wave_ttft_s"])
    cont_ttft = sorted(t for p in pairs for t in p["continuous_ttft_s"])

    def pct(xs, q):
        return xs[min(len(xs) - 1, int(q * len(xs)))]

    return {
        "pairs": pairs,
        "wave": {
            "tokens_per_s": _median([p["wave_tokens_per_s"] for p in pairs]),
            "lane_utilization": wave.lane_utilization,
            "ttft_p50_s": pct(wave_ttft, 0.50),
            "ttft_p99_s": pct(wave_ttft, 0.99),
        },
        "continuous": {
            "tokens_per_s": _median(
                [p["continuous_tokens_per_s"] for p in pairs]
            ),
            "lane_utilization": cont.lane_utilization,
            "ttft_p50_s": pct(cont_ttft, 0.50),
            "ttft_p99_s": pct(cont_ttft, 0.99),
        },
        "speedup": speedup,
        "host_cores": len(os.sched_getaffinity(0)),
    }


def bench_batch_sweep(model, params) -> list[dict]:
    cfg = model.cfg
    reqs = _workload(cfg, seed=1)
    out = []
    for n in SWEEP_SLOTS:
        eng = ContinuousLMEngine(
            model, params, n_slots=n, n_blocks=N_BLOCKS,
            block_size=BLOCK, max_blocks=MAX_BLOCKS,
        )
        _run_side(eng, reqs)  # warm-up/compile at this batch shape
        r = _run_side(eng, reqs)
        out.append({"n_slots": n, "tokens_per_s": r["tokens_per_s"]})
    return out


def main() -> None:
    cfg, model, params = _build_model()
    results = {
        "config": {
            "model": "yi-6b-reduced",
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_slots": N_SLOTS,
            "block_size": BLOCK,
            "n_blocks": N_BLOCKS,
            "prompt_lens": list(PLENS),
            "n_requests": N_REQ,
            "reps": REPS,
            "host_cores": len(os.sched_getaffinity(0)),
        }
    }
    print("name,us_per_call,derived")

    thr = bench_throughput(model, params)
    results["throughput"] = thr
    _row("serving_wave_tokens", 1.0 / thr["wave"]["tokens_per_s"],
         f"{thr['wave']['tokens_per_s']:.0f}tok/s_"
         f"util{thr['wave']['lane_utilization']:.2f}")
    _row("serving_continuous_tokens", 1.0 / thr["continuous"]["tokens_per_s"],
         f"{thr['continuous']['tokens_per_s']:.0f}tok/s_"
         f"util{thr['continuous']['lane_utilization']:.2f}_"
         f"{thr['speedup']:.2f}x_cores{thr['host_cores']}")
    _row("serving_wave_ttft_p99", thr["wave"]["ttft_p99_s"],
         f"p50_{thr['wave']['ttft_p50_s'] * 1e3:.0f}ms")
    _row("serving_continuous_ttft_p99", thr["continuous"]["ttft_p99_s"],
         f"p50_{thr['continuous']['ttft_p50_s'] * 1e3:.0f}ms")

    sweep = bench_batch_sweep(model, params)
    results["batch_sweep"] = sweep
    for s in sweep:
        _row(f"serving_sweep_slots{s['n_slots']}", 1.0 / s["tokens_per_s"],
             f"{s['tokens_per_s']:.0f}tok/s")

    with open(OUT_JSON, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"# wrote {OUT_JSON}")


if __name__ == "__main__":
    main()
