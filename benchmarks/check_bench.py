"""CI regression gate over ``BENCH_replication.json`` (stdlib only).

Two checks, wired into the nightly CI job right after the benchmark run
(`.github/workflows/ci.yml`):

* **schema** — the result file must carry every section the benchmark
  writes (``config`` / ``single`` / ``contended`` / ``speedup_4threads``
  / ``controller``) with sane values, so a silently truncated or
  hand-edited file fails loudly;
* **throughput floor** — contended-producer throughput at 4 threads
  (rf=3, acks=all — the PR-2 acceptance configuration) must not regress
  more than ``TOLERANCE`` (20%) below the recorded PR-2 baseline; the
  absolute baseline is hardware-specific (``--baseline`` overrides it on
  other machines), so the gate also enforces the hardware-independent
  relative floor ``speedup_4threads >= MIN_SPEEDUP_4T`` (concurrent vs
  global-lock data plane, measured in the same run).

Exit code 0 on pass, 1 on any failure (the CI job fails on non-zero).

    python benchmarks/check_bench.py [BENCH_replication.json]
        [--baseline MSGS_PER_S] [--tolerance FRACTION]
"""

from __future__ import annotations

import argparse
import json
import sys

# Recorded PR-2 baseline for contended_t4_rf3_acksall (msgs/s) on the
# reference container; override with --baseline when gating on different
# hardware.
PR2_BASELINE_MSGS_PER_S = 553_112.33
TOLERANCE = 0.20
# hardware-independent floor: the concurrent data plane must stay at
# least this much faster than the same run's global-lock baseline
MIN_SPEEDUP_4T = 1.5

ACCEPTANCE_KEY = "contended_t4_rf3_acksall"

REQUIRED_SECTIONS = ("config", "single", "contended", "speedup_4threads",
                     "controller")
REQUIRED_CONTENDED = (
    "contended_t1_rf3_acksall",
    "contended_t4_rf3_acksall",
    "contended_t4_rf3_acksall_globallock",
)


def check(results: dict, baseline: float, tolerance: float) -> list[str]:
    """Return a list of failure messages (empty == pass)."""
    failures: list[str] = []
    for key in REQUIRED_SECTIONS:
        if key not in results:
            failures.append(f"schema: missing top-level section {key!r}")
    contended = results.get("contended", {})
    for key in REQUIRED_CONTENDED:
        row = contended.get(key)
        if not isinstance(row, dict) or row.get("msgs_per_s", 0) <= 0:
            failures.append(f"schema: contended[{key!r}] missing or non-positive")
    single = results.get("single", {})
    if not isinstance(single.get("bare_streamlog"), dict):
        failures.append("schema: single['bare_streamlog'] missing")
    speedup = results.get("speedup_4threads")
    if not isinstance(speedup, (int, float)) or speedup <= 0:
        failures.append("schema: speedup_4threads missing or non-positive")
    elif speedup < MIN_SPEEDUP_4T:
        failures.append(
            f"regression: speedup_4threads {speedup:.2f}x below the "
            f"relative floor {MIN_SPEEDUP_4T:.1f}x (concurrent vs "
            "global-lock, same hardware)"
        )
    controller = results.get("controller", {})
    failover = controller.get("failover", {}) if isinstance(controller, dict) else {}
    if not isinstance(failover, dict) or failover.get("best_s", 0) <= 0:
        failures.append("schema: controller['failover']['best_s'] missing "
                        "or non-positive")

    row = contended.get(ACCEPTANCE_KEY)
    if isinstance(row, dict) and row.get("msgs_per_s", 0) > 0:
        got = row["msgs_per_s"]
        floor = (1.0 - tolerance) * baseline
        if got < floor:
            failures.append(
                f"regression: {ACCEPTANCE_KEY} = {got:,.0f} msgs/s is "
                f"{100 * (1 - got / baseline):.1f}% below the recorded "
                f"baseline {baseline:,.0f} (floor {floor:,.0f}, "
                f"tolerance {tolerance:.0%})"
            )
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("json_path", nargs="?", default="BENCH_replication.json")
    ap.add_argument("--baseline", type=float, default=PR2_BASELINE_MSGS_PER_S,
                    help="baseline msgs/s for the acceptance config "
                         "(default: recorded PR-2 value)")
    ap.add_argument("--tolerance", type=float, default=TOLERANCE,
                    help="allowed fractional regression (default 0.20)")
    args = ap.parse_args(argv)

    try:
        with open(args.json_path) as f:
            results = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_bench: FAIL — cannot read {args.json_path}: {e}")
        return 1

    failures = check(results, args.baseline, args.tolerance)
    if failures:
        for msg in failures:
            print(f"check_bench: FAIL — {msg}")
        return 1

    got = results["contended"][ACCEPTANCE_KEY]["msgs_per_s"]
    fo = results["controller"]["failover"]["best_s"]
    print(
        f"check_bench: OK — {ACCEPTANCE_KEY} {got:,.0f} msgs/s "
        f"(baseline {args.baseline:,.0f}, tolerance {args.tolerance:.0%}); "
        f"speedup_4threads {results['speedup_4threads']:.2f}x; "
        f"controller failover {fo * 1e3:.1f} ms"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
