"""CI regression gate over ``BENCH_replication.json`` and (with
``--datapath``) ``BENCH_datapath.json`` (stdlib only).

Two checks, wired into the nightly CI job right after the benchmark run
(`.github/workflows/ci.yml`):

* **schema** — the result file must carry every section the benchmark
  writes (``config`` / ``single`` / ``contended`` / ``speedup_4threads``
  / ``idempotent`` / ``transactions`` / ``observability`` /
  ``controller`` / ``storage``) with sane values, so a silently truncated or
  hand-edited file fails loudly;
* **throughput floor** — contended-producer throughput at 4 threads
  (rf=3, acks=all — the PR-2 acceptance configuration) must not regress
  more than ``TOLERANCE`` (20%) below the recorded PR-2 baseline; the
  absolute baseline is hardware-specific (``--baseline`` overrides it on
  other machines), so the gate also enforces the hardware-independent
  relative floor ``speedup_4threads >= MIN_SPEEDUP_4T`` (concurrent vs
  global-lock data plane, measured in the same run);
* **idempotent overhead** — the exactly-once producer path (PR-4) must
  cost at most ``IDEM_MAX_OVERHEAD`` (35%) versus the same run's
  non-idempotent rf=3/acks=all baseline. The statistic is the **median
  within-pair ratio** over the recorded slice-interleaved run pairs —
  recomputed from the pair throughputs, never trusted from a stored
  ratio, and immune to the shared host's absolute-speed drift;
* **transactional overhead** — the atomic read-process-write path (PR-5:
  coordinator commands, txn flags, COMMIT markers + their replication)
  must cost at most ``TXN_MAX_OVERHEAD`` (25%) versus the same run's
  *idempotent* acks=all baseline, with the same median-of-paired-runs
  statistic;
* **observability overhead** — the metrics-instrumented produce hot path
  (PR-6: latency histograms + per-partition counters) must cost at most
  ``OBS_MAX_OVERHEAD`` (5%) versus the same run's ``metrics_enabled=False``
  baseline, with the same median-of-paired-runs statistic;
* **recovery speedup** — restart recovery of the producer/txn state
  table from the newest producer-state snapshot + suffix replay (PR-8,
  DESIGN.md §11) must beat a full log replay by at least
  ``MIN_RECOVERY_SPEEDUP`` (2x), median within-pair ratio recomputed
  from the recorded (replay_s, snapshot_s) timing pairs. The same
  ``storage`` section also records the ``.txnindex``-vs-full-abort-scan
  ``read_committed`` prefilter pairs; those are schema-checked (present,
  positive) but not gated — the win scales with abort-history length,
  which the fixed benchmark log keeps modest.

With ``--datapath BENCH_datapath.json`` the gate additionally validates
the broker→device data-path benchmark (PR-7, DESIGN.md §10):

* **decode speedup** — the zero-copy framed decode must beat the
  per-record Python baseline by at least ``DATAPATH_MIN_DECODE_SPEEDUP``
  (10x); the statistic is the median within-pair ratio recomputed from
  the recorded (per_record, framed) timing pairs. The quiet-host reading
  is ~1000x+, so the 10x floor only trips on a real regression to
  per-record work.
* **overlap** — overlapped poll→device throughput vs the serial path,
  median within-pair ratio over slice-interleaved pairs. Host-aware: on
  a multi-core host (``overlap.host_cores >= 2``) overlap must beat
  serial by ``DATAPATH_MIN_OVERLAP_SPEEDUP`` (1.05x); on a single-core
  host the two legs timeshare one CPU — the theoretical ceiling is
  parity — so the gate instead holds the pipeline at
  ``DATAPATH_MIN_OVERLAP_RATIO_1CORE`` (0.90x: double buffering must
  cost nothing to leave on).
* **schema** — decode/overlap/step sections present with positive
  values, including the poll→kernel step measurement.

With ``--serving BENCH_serving.json`` the gate additionally validates
the continuous-batching LM serving benchmark (PR-10, DESIGN.md §13):

* **throughput floor** — continuous batching must beat the wave engine's
  tokens/s on the mixed-length workload; the statistic is the median
  within-pair ratio recomputed from the recorded slice-interleaved
  (wave, continuous) pairs. Host-aware: ``SERVING_MIN_SPEEDUP`` (1.3x)
  on a multi-core host, ``SERVING_MIN_SPEEDUP_1CORE`` (1.2x) on the
  single-core reference container where per-admission batch-1 prefills
  timeshare with decode (the quiet-host reading is ~2.8x — the floors
  only trip on a real regression to wave-like lane idling).
* **TTFT ceiling** — continuous p99 time-to-first-token must stay below
  ``SERVING_TTFT_MAX_RATIO`` (0.8x / 0.9x on 1 core) of the wave p99,
  both percentiles recomputed from the raw per-request TTFT samples
  stored in the pairs — never trusted from a stored percentile.
* **schema** — config/throughput/batch_sweep present, pairs non-empty
  with positive tokens/s and non-empty TTFT sample lists, every sweep
  point positive.

Exit code 0 on pass, 1 on any failure (the CI job fails on non-zero).

    python benchmarks/check_bench.py [BENCH_replication.json]
        [--baseline MSGS_PER_S] [--tolerance FRACTION]
        [--datapath BENCH_datapath.json]
        [--serving BENCH_serving.json]
"""

from __future__ import annotations

import argparse
import json
import sys

# Recorded PR-2 baseline for contended_t4_rf3_acksall (msgs/s) on the
# reference container; override with --baseline when gating on different
# hardware.
PR2_BASELINE_MSGS_PER_S = 553_112.33
TOLERANCE = 0.20
# hardware-independent floor: the concurrent data plane must stay at
# least this much faster than the same run's global-lock baseline
MIN_SPEEDUP_4T = 1.5
# exactly-once tax budget: idempotent rf3/acksall may cost at most this
# fraction vs the same run's non-idempotent baseline. Recalibrated in
# PR-6 when the pair estimator was tightened (slice-interleaved sides,
# median per-batch time): the PR-4 back-to-back estimator's ≈0% was
# drift-dominated. The true bookkeeping tax measures ~15% on a quiet
# host and inflates to ~30% per pair when co-tenant contention
# stretches the idempotent side's longer critical sections, so the
# budget absorbs the worst honest epoch while still catching any real
# regression (which would roughly double the median)
IDEM_MAX_OVERHEAD = 0.35
# transactional tax budget: committed-txn throughput may cost at most
# this fraction vs the same run's idempotent acks=all baseline
TXN_MAX_OVERHEAD = 0.25
# observability tax budget: a metrics-instrumented produce hot path may
# cost at most this fraction vs the same run's metrics-disabled baseline
OBS_MAX_OVERHEAD = 0.05
# restart-recovery floor: snapshot + suffix replay must beat a full log
# replay by at least this factor on the benchmark's 64-segment log (the
# quiet-host reading is ~50x; 2x only trips if snapshots stop pinning
# the replay suffix)
MIN_RECOVERY_SPEEDUP = 2.0

# broker→device data-path gates (BENCH_datapath.json, PR-7)
DATAPATH_MIN_DECODE_SPEEDUP = 10.0
DATAPATH_MIN_OVERLAP_SPEEDUP = 1.05
# single-core hosts can't run the host and device legs concurrently —
# the honest ceiling is parity, so gate "costs nothing to leave on"
DATAPATH_MIN_OVERLAP_RATIO_1CORE = 0.90

# continuous-vs-wave LM serving gates (BENCH_serving.json, PR-10)
SERVING_MIN_SPEEDUP = 1.3
# single-core hosts timeshare the continuous engine's per-admission
# batch-1 prefills with decode, shaving the algorithmic win's edge
SERVING_MIN_SPEEDUP_1CORE = 1.2
SERVING_TTFT_MAX_RATIO = 0.8
SERVING_TTFT_MAX_RATIO_1CORE = 0.9

ACCEPTANCE_KEY = "contended_t4_rf3_acksall"

REQUIRED_SECTIONS = ("config", "single", "contended", "speedup_4threads",
                     "idempotent", "transactions", "observability",
                     "controller", "storage")
REQUIRED_CONTENDED = (
    "contended_t1_rf3_acksall",
    "contended_t4_rf3_acksall",
    "contended_t4_rf3_acksall_globallock",
)


def _pair_overhead(section: dict, over_key: str) -> tuple[float, int] | None:
    """``(median overhead ratio, valid pair count)`` recomputed from the
    recorded throughput pairs — never trusted from a stored
    ``overhead_frac`` a hand-edit could detach from its inputs. Each pair
    ran back to back, so its ratio is immune to the shared host's
    absolute-speed drift. None when no valid pair exists (schema
    failure). ``over_key`` names the measured side of each pair
    (``idempotent_msgs_per_s`` / ``txn_msgs_per_s``)."""
    pairs = section.get("pairs")
    if not isinstance(pairs, list):
        return None
    ratios = sorted(
        p["baseline_msgs_per_s"] / p[over_key] - 1.0
        for p in pairs
        if isinstance(p, dict)
        and p.get("baseline_msgs_per_s", 0) > 0
        and p.get(over_key, 0) > 0
    )
    if not ratios:
        return None
    return ratios[len(ratios) // 2], len(ratios)


def _idempotent_overhead(idem: dict) -> tuple[float, int] | None:
    return _pair_overhead(idem, "idempotent_msgs_per_s")


def _txn_overhead(txn: dict) -> tuple[float, int] | None:
    return _pair_overhead(txn, "txn_msgs_per_s")


def _obs_overhead(obs: dict) -> tuple[float, int] | None:
    return _pair_overhead(obs, "instrumented_msgs_per_s")


def _pair_speedup(section: dict, slow_key: str,
                  fast_key: str) -> tuple[float, int] | None:
    """``(median slow/fast ratio, valid pair count)`` recomputed from a
    section's recorded timing pairs — never trusted from a stored
    ``speedup`` a hand-edit could detach from its inputs."""
    pairs = section.get("pairs")
    if not isinstance(pairs, list):
        return None
    ratios = sorted(
        p[slow_key] / p[fast_key]
        for p in pairs
        if isinstance(p, dict)
        and p.get(slow_key, 0) > 0
        and p.get(fast_key, 0) > 0
    )
    if not ratios:
        return None
    return ratios[len(ratios) // 2], len(ratios)


def _recovery_speedup(recovery: dict) -> tuple[float, int] | None:
    return _pair_speedup(recovery, "replay_s", "snapshot_s")


def _txnindex_speedup(txnindex: dict) -> tuple[float, int] | None:
    return _pair_speedup(txnindex, "fullscan_us", "indexed_us")


def _datapath_decode_speedup(decode: dict) -> tuple[float, int] | None:
    """Median framed-vs-per-record speedup recomputed from the recorded
    timing pairs (never trusted from the stored ``speedup``)."""
    pairs = decode.get("pairs")
    if not isinstance(pairs, list):
        return None
    ratios = sorted(
        p["per_record_us"] / p["framed_us"]
        for p in pairs
        if isinstance(p, dict)
        and p.get("per_record_us", 0) > 0
        and p.get("framed_us", 0) > 0
    )
    if not ratios:
        return None
    return ratios[len(ratios) // 2], len(ratios)


def _datapath_overlap_ratio(overlap: dict) -> tuple[float, int] | None:
    """Median overlap/serial throughput ratio recomputed from the
    recorded slice-interleaved pairs."""
    pairs = overlap.get("pairs")
    if not isinstance(pairs, list):
        return None
    ratios = sorted(
        p["overlap_records_per_s"] / p["serial_records_per_s"]
        for p in pairs
        if isinstance(p, dict)
        and p.get("overlap_records_per_s", 0) > 0
        and p.get("serial_records_per_s", 0) > 0
    )
    if not ratios:
        return None
    return ratios[len(ratios) // 2], len(ratios)


def check_datapath(results: dict) -> list[str]:
    """Return failure messages for a BENCH_datapath.json result set."""
    failures: list[str] = []
    for key in ("config", "decode", "overlap", "step"):
        if key not in results:
            failures.append(f"datapath schema: missing section {key!r}")

    decode = results.get("decode", {})
    decode = decode if isinstance(decode, dict) else {}
    for key in ("per_record", "framed_view", "fallback_copy",
                "matrix_copy"):
        row = decode.get(key)
        if not (isinstance(row, dict) and row.get("us_per_batch", 0) > 0
                and row.get("MB_per_s", 0) > 0):
            failures.append(
                f"datapath schema: decode[{key!r}] missing or non-positive"
            )
    if isinstance(decode.get("framed_view"), dict) and not decode[
        "framed_view"
    ].get("zero_copy", False):
        failures.append(
            "datapath schema: decode['framed_view'] did not take the "
            "zero-copy path"
        )
    measured = _datapath_decode_speedup(decode)
    if measured is None:
        failures.append(
            "datapath schema: decode['pairs'] missing or holds no valid "
            "(per_record, framed) timing pair"
        )
    else:
        speedup, n_pairs = measured
        if speedup < DATAPATH_MIN_DECODE_SPEEDUP:
            failures.append(
                f"regression: zero-copy framed decode is only "
                f"{speedup:.1f}x the per-record baseline (median across "
                f"{n_pairs} pairs), below the "
                f"{DATAPATH_MIN_DECODE_SPEEDUP:.0f}x floor"
            )

    overlap = results.get("overlap", {})
    overlap = overlap if isinstance(overlap, dict) else {}
    for key in ("serial", "overlap"):
        row = overlap.get(key)
        if not (isinstance(row, dict) and row.get("records_per_s", 0) > 0):
            failures.append(
                f"datapath schema: overlap[{key!r}] missing or non-positive"
            )
    cores = overlap.get("host_cores")
    if not isinstance(cores, int) or cores < 1:
        failures.append(
            "datapath schema: overlap['host_cores'] missing or non-positive"
        )
    measured = _datapath_overlap_ratio(overlap)
    if measured is None:
        failures.append(
            "datapath schema: overlap['pairs'] missing or holds no valid "
            "(serial, overlap) throughput pair"
        )
    elif isinstance(cores, int) and cores >= 1:
        ratio, n_pairs = measured
        if cores >= 2:
            if ratio < DATAPATH_MIN_OVERLAP_SPEEDUP:
                failures.append(
                    f"regression: overlapped poll→device throughput is "
                    f"{ratio:.2f}x the serial path (median across "
                    f"{n_pairs} pairs) on a {cores}-core host, below the "
                    f"{DATAPATH_MIN_OVERLAP_SPEEDUP:.2f}x floor"
                )
        elif ratio < DATAPATH_MIN_OVERLAP_RATIO_1CORE:
            failures.append(
                f"regression: overlapped poll→device throughput is "
                f"{ratio:.2f}x the serial path (median across {n_pairs} "
                f"pairs) on a single-core host, below the parity floor "
                f"{DATAPATH_MIN_OVERLAP_RATIO_1CORE:.2f}x (double "
                "buffering must cost nothing to leave on)"
            )

    st = results.get("step", {})
    st = st if isinstance(st, dict) else {}
    if not (st.get("records_per_s", 0) > 0 and st.get("kernel")):
        failures.append(
            "datapath schema: step['records_per_s']/'kernel' missing or "
            "non-positive (poll→kernel measurement absent)"
        )
    return failures


def _serving_speedup(throughput: dict) -> tuple[float, int] | None:
    """Median continuous/wave tokens/s ratio recomputed from the
    recorded slice-interleaved pairs (never trusted from the stored
    ``speedup``)."""
    pairs = throughput.get("pairs")
    if not isinstance(pairs, list):
        return None
    ratios = sorted(
        p["continuous_tokens_per_s"] / p["wave_tokens_per_s"]
        for p in pairs
        if isinstance(p, dict)
        and p.get("continuous_tokens_per_s", 0) > 0
        and p.get("wave_tokens_per_s", 0) > 0
    )
    if not ratios:
        return None
    return ratios[len(ratios) // 2], len(ratios)


def _serving_ttft_p99(throughput: dict, side_key: str) -> float | None:
    """p99 TTFT pooled over the raw per-request samples every pair
    stores — recomputed here, never trusted from a stored percentile."""
    pairs = throughput.get("pairs")
    if not isinstance(pairs, list):
        return None
    samples = sorted(
        t
        for p in pairs
        if isinstance(p, dict) and isinstance(p.get(side_key), list)
        for t in p[side_key]
        if isinstance(t, (int, float)) and t >= 0
    )
    if not samples:
        return None
    return samples[min(len(samples) - 1, int(0.99 * len(samples)))]


def check_serving(results: dict) -> list[str]:
    """Return failure messages for a BENCH_serving.json result set."""
    failures: list[str] = []
    for key in ("config", "throughput", "batch_sweep"):
        if key not in results:
            failures.append(f"serving schema: missing section {key!r}")

    thr = results.get("throughput", {})
    thr = thr if isinstance(thr, dict) else {}
    for key in ("wave", "continuous"):
        row = thr.get(key)
        if not (isinstance(row, dict) and row.get("tokens_per_s", 0) > 0):
            failures.append(
                f"serving schema: throughput[{key!r}] missing or non-positive"
            )
    cores = thr.get("host_cores")
    if not isinstance(cores, int) or cores < 1:
        failures.append(
            "serving schema: throughput['host_cores'] missing or non-positive"
        )

    measured = _serving_speedup(thr)
    if measured is None:
        failures.append(
            "serving schema: throughput['pairs'] missing or holds no valid "
            "(wave, continuous) tokens/s pair"
        )
    elif isinstance(cores, int) and cores >= 1:
        speedup, n_pairs = measured
        floor = SERVING_MIN_SPEEDUP if cores >= 2 else SERVING_MIN_SPEEDUP_1CORE
        if speedup < floor:
            failures.append(
                f"regression: continuous batching is only {speedup:.2f}x "
                f"wave tokens/s on the mixed-length workload (median "
                f"across {n_pairs} pairs) on a {cores}-core host, below "
                f"the {floor:.2f}x floor"
            )

    wave_p99 = _serving_ttft_p99(thr, "wave_ttft_s")
    cont_p99 = _serving_ttft_p99(thr, "continuous_ttft_s")
    if wave_p99 is None or cont_p99 is None:
        failures.append(
            "serving schema: pairs carry no raw TTFT samples "
            "(wave_ttft_s / continuous_ttft_s)"
        )
    elif isinstance(cores, int) and cores >= 1 and wave_p99 > 0:
        ceil = (SERVING_TTFT_MAX_RATIO if cores >= 2
                else SERVING_TTFT_MAX_RATIO_1CORE)
        if cont_p99 > ceil * wave_p99:
            failures.append(
                f"regression: continuous p99 TTFT {cont_p99 * 1e3:.0f} ms "
                f"exceeds {ceil:.2f}x the wave p99 "
                f"{wave_p99 * 1e3:.0f} ms (recomputed from stored "
                f"samples on a {cores}-core host) — continuous admission "
                "must cut first-token latency, not trade it away"
            )

    sweep = results.get("batch_sweep")
    if not (isinstance(sweep, list) and sweep):
        failures.append("serving schema: batch_sweep missing or empty")
    else:
        for row in sweep:
            if not (isinstance(row, dict) and row.get("n_slots", 0) > 0
                    and row.get("tokens_per_s", 0) > 0):
                failures.append(
                    "serving schema: batch_sweep row missing or non-positive"
                )
                break
    return failures


def check(results: dict, baseline: float, tolerance: float) -> list[str]:
    """Return a list of failure messages (empty == pass)."""
    failures: list[str] = []
    for key in REQUIRED_SECTIONS:
        if key not in results:
            failures.append(f"schema: missing top-level section {key!r}")
    contended = results.get("contended", {})
    for key in REQUIRED_CONTENDED:
        row = contended.get(key)
        if not isinstance(row, dict) or row.get("msgs_per_s", 0) <= 0:
            failures.append(f"schema: contended[{key!r}] missing or non-positive")
    single = results.get("single", {})
    if not isinstance(single.get("bare_streamlog"), dict):
        failures.append("schema: single['bare_streamlog'] missing")
    speedup = results.get("speedup_4threads")
    if not isinstance(speedup, (int, float)) or speedup <= 0:
        failures.append("schema: speedup_4threads missing or non-positive")
    elif speedup < MIN_SPEEDUP_4T:
        failures.append(
            f"regression: speedup_4threads {speedup:.2f}x below the "
            f"relative floor {MIN_SPEEDUP_4T:.1f}x (concurrent vs "
            "global-lock, same hardware)"
        )
    controller = results.get("controller", {})
    failover = controller.get("failover", {}) if isinstance(controller, dict) else {}
    if not isinstance(failover, dict) or failover.get("best_s", 0) <= 0:
        failures.append("schema: controller['failover']['best_s'] missing "
                        "or non-positive")

    idem = results.get("idempotent", {})
    idem = idem if isinstance(idem, dict) else {}
    base_row = idem.get("baseline_rf3_acksall")
    idem_row = idem.get("idempotent_rf3_acksall")
    if not (isinstance(base_row, dict) and base_row.get("msgs_per_s", 0) > 0):
        failures.append(
            "schema: idempotent['baseline_rf3_acksall'] missing or "
            "non-positive"
        )
    if not (isinstance(idem_row, dict) and idem_row.get("msgs_per_s", 0) > 0):
        failures.append(
            "schema: idempotent['idempotent_rf3_acksall'] missing or "
            "non-positive"
        )
    measured = _idempotent_overhead(idem)
    if measured is None:
        failures.append(
            "schema: idempotent['pairs'] missing or holds no valid "
            "(baseline, idempotent) throughput pair"
        )
    else:
        overhead, n_pairs = measured
        if overhead > IDEM_MAX_OVERHEAD:
            failures.append(
                f"regression: idempotent-producer overhead {overhead:.1%} "
                f"(median across {n_pairs} valid paired runs) exceeds "
                f"the {IDEM_MAX_OVERHEAD:.0%} budget vs the acks=all "
                "non-idempotent baseline"
            )

    txn = results.get("transactions", {})
    txn = txn if isinstance(txn, dict) else {}
    for key in ("baseline_idem_rf3_acksall", "txn_rf3_acksall"):
        row = txn.get(key)
        if not (isinstance(row, dict) and row.get("msgs_per_s", 0) > 0):
            failures.append(
                f"schema: transactions[{key!r}] missing or non-positive"
            )
    measured = _txn_overhead(txn)
    if measured is None:
        failures.append(
            "schema: transactions['pairs'] missing or holds no valid "
            "(baseline, txn) throughput pair"
        )
    else:
        overhead, n_pairs = measured
        if overhead > TXN_MAX_OVERHEAD:
            failures.append(
                f"regression: transactional overhead {overhead:.1%} "
                f"(median across {n_pairs} valid paired runs) exceeds "
                f"the {TXN_MAX_OVERHEAD:.0%} budget vs the acks=all "
                "idempotent baseline"
            )

    obs = results.get("observability", {})
    obs = obs if isinstance(obs, dict) else {}
    for key in ("baseline_nometrics_rf3_acksall", "instrumented_rf3_acksall"):
        row = obs.get(key)
        if not (isinstance(row, dict) and row.get("msgs_per_s", 0) > 0):
            failures.append(
                f"schema: observability[{key!r}] missing or non-positive"
            )
    measured = _obs_overhead(obs)
    if measured is None:
        failures.append(
            "schema: observability['pairs'] missing or holds no valid "
            "(baseline, instrumented) throughput pair"
        )
    else:
        overhead, n_pairs = measured
        if overhead > OBS_MAX_OVERHEAD:
            failures.append(
                f"regression: observability overhead {overhead:.1%} "
                f"(median across {n_pairs} valid paired runs) exceeds "
                f"the {OBS_MAX_OVERHEAD:.0%} budget vs the "
                "metrics-disabled baseline"
            )

    storage = results.get("storage", {})
    storage = storage if isinstance(storage, dict) else {}
    recovery = storage.get("recovery", {})
    recovery = recovery if isinstance(recovery, dict) else {}
    measured = _recovery_speedup(recovery)
    if measured is None:
        failures.append(
            "schema: storage['recovery']['pairs'] missing or holds no "
            "valid (replay_s, snapshot_s) timing pair"
        )
    else:
        rec_speedup, n_pairs = measured
        if rec_speedup < MIN_RECOVERY_SPEEDUP:
            failures.append(
                f"regression: snapshot+suffix restart recovery is only "
                f"{rec_speedup:.2f}x a full log replay (median across "
                f"{n_pairs} pairs), below the "
                f"{MIN_RECOVERY_SPEEDUP:.0f}x floor"
            )
    txnindex = storage.get("txnindex", {})
    txnindex = txnindex if isinstance(txnindex, dict) else {}
    if _txnindex_speedup(txnindex) is None:
        failures.append(
            "schema: storage['txnindex']['pairs'] missing or holds no "
            "valid (fullscan_us, indexed_us) timing pair"
        )

    row = contended.get(ACCEPTANCE_KEY)
    if isinstance(row, dict) and row.get("msgs_per_s", 0) > 0:
        got = row["msgs_per_s"]
        floor = (1.0 - tolerance) * baseline
        if got < floor:
            failures.append(
                f"regression: {ACCEPTANCE_KEY} = {got:,.0f} msgs/s is "
                f"{100 * (1 - got / baseline):.1f}% below the recorded "
                f"baseline {baseline:,.0f} (floor {floor:,.0f}, "
                f"tolerance {tolerance:.0%})"
            )
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("json_path", nargs="?", default="BENCH_replication.json")
    ap.add_argument("--baseline", type=float, default=PR2_BASELINE_MSGS_PER_S,
                    help="baseline msgs/s for the acceptance config "
                         "(default: recorded PR-2 value)")
    ap.add_argument("--tolerance", type=float, default=TOLERANCE,
                    help="allowed fractional regression (default 0.20)")
    ap.add_argument("--datapath", default=None, metavar="BENCH_datapath.json",
                    help="also validate + gate the broker→device "
                         "data-path benchmark result file")
    ap.add_argument("--serving", default=None, metavar="BENCH_serving.json",
                    help="also validate + gate the continuous-vs-wave "
                         "LM serving benchmark result file")
    args = ap.parse_args(argv)

    try:
        with open(args.json_path) as f:
            results = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_bench: FAIL — cannot read {args.json_path}: {e}")
        return 1

    failures = check(results, args.baseline, args.tolerance)

    dp_results = None
    if args.datapath is not None:
        try:
            with open(args.datapath) as f:
                dp_results = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            failures.append(f"cannot read {args.datapath}: {e}")
        else:
            failures.extend(check_datapath(dp_results))

    sv_results = None
    if args.serving is not None:
        try:
            with open(args.serving) as f:
                sv_results = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            failures.append(f"cannot read {args.serving}: {e}")
        else:
            failures.extend(check_serving(sv_results))

    if failures:
        for msg in failures:
            print(f"check_bench: FAIL — {msg}")
        return 1

    got = results["contended"][ACCEPTANCE_KEY]["msgs_per_s"]
    fo = results["controller"]["failover"]["best_s"]
    overhead, _ = _idempotent_overhead(results["idempotent"])
    txn_overhead, _ = _txn_overhead(results["transactions"])
    obs_overhead, _ = _obs_overhead(results["observability"])
    print(
        f"check_bench: OK — {ACCEPTANCE_KEY} {got:,.0f} msgs/s "
        f"(baseline {args.baseline:,.0f}, tolerance {args.tolerance:.0%}); "
        f"speedup_4threads {results['speedup_4threads']:.2f}x; "
        f"idempotent overhead {overhead:+.1%} (budget "
        f"{IDEM_MAX_OVERHEAD:.0%}); "
        f"transactional overhead {txn_overhead:+.1%} (budget "
        f"{TXN_MAX_OVERHEAD:.0%}); "
        f"observability overhead {obs_overhead:+.1%} (budget "
        f"{OBS_MAX_OVERHEAD:.0%}); "
        f"controller failover {fo * 1e3:.1f} ms"
    )
    rec_speedup, _ = _recovery_speedup(results["storage"]["recovery"])
    tix_speedup, _ = _txnindex_speedup(results["storage"]["txnindex"])
    print(
        f"check_bench: OK — storage recovery {rec_speedup:.1f}x vs full "
        f"replay (floor {MIN_RECOVERY_SPEEDUP:.0f}x); read_committed "
        f"txnindex prefilter {tix_speedup:.2f}x vs abort-list full scan "
        "(recorded, not gated)"
    )
    if dp_results is not None:
        dec, _ = _datapath_decode_speedup(dp_results["decode"])
        ovr, _ = _datapath_overlap_ratio(dp_results["overlap"])
        cores = dp_results["overlap"]["host_cores"]
        floor = (DATAPATH_MIN_OVERLAP_SPEEDUP if cores >= 2
                 else DATAPATH_MIN_OVERLAP_RATIO_1CORE)
        print(
            f"check_bench: OK — datapath decode {dec:.0f}x vs per-record "
            f"(floor {DATAPATH_MIN_DECODE_SPEEDUP:.0f}x); overlap "
            f"{ovr:.2f}x vs serial on {cores} core(s) (floor "
            f"{floor:.2f}x); poll→kernel "
            f"{dp_results['step']['records_per_s'] / 1e3:.0f} krec/s "
            f"({dp_results['step']['kernel']})"
        )
    if sv_results is not None:
        thr = sv_results["throughput"]
        sp, _ = _serving_speedup(thr)
        cores = thr["host_cores"]
        floor = (SERVING_MIN_SPEEDUP if cores >= 2
                 else SERVING_MIN_SPEEDUP_1CORE)
        wp99 = _serving_ttft_p99(thr, "wave_ttft_s")
        cp99 = _serving_ttft_p99(thr, "continuous_ttft_s")
        ceil = (SERVING_TTFT_MAX_RATIO if cores >= 2
                else SERVING_TTFT_MAX_RATIO_1CORE)
        print(
            f"check_bench: OK — serving continuous {sp:.2f}x wave tokens/s "
            f"on {cores} core(s) (floor {floor:.2f}x); p99 TTFT "
            f"{cp99 * 1e3:.0f} ms vs wave {wp99 * 1e3:.0f} ms "
            f"(ceiling {ceil:.2f}x); sweep to "
            f"{max(s['n_slots'] for s in sv_results['batch_sweep'])} slots"
        )
    return 0


if __name__ == "__main__":
    sys.exit(main())
