"""Paper §VI reproduction: Tables I and II.

The paper measures the COPD-MLP pipeline's latency in three modes:
  1. Normal                     — direct in-memory training / inference
  2. Data streams               — through Apache Kafka (here: the log)
  3. Streams + containerization — the full deployed pipeline components

Our three analogous modes:
  1. normal   — numpy arrays straight into the jitted step
  2. streams  — encode -> distributed log -> control message -> decode
  3. deployed — the full TrainingJob / InferenceDeployment machinery
                (registry, control plane, consumer groups, serialization
                both ways — the orchestrated-component overhead the
                paper's "containerization" column captures)

Paper reference values (MacBook Pro, 16 GB):
  Table I  (training, 1000 epochs batch 10): 27.37 / 29.61 / 31.44 s
  Table II (inference single batch):          0.079 / 0.374 / 0.335 s
"""

from __future__ import annotations

import time

import jax
import numpy as np

import repro.core as core
import repro.data as data
from repro.configs import copd_mlp
from repro.data.formats import AvroCodec, FieldSpec
from repro.serve import InferenceDeployment
from repro.train import TrainingJob, adamw
from repro.train.optimizer import Optimizer

EPOCHS = 60  # scaled from the paper's 1000 (same steps_per_epoch=22 shape)
BATCH = 10


def _codec():
    return AvroCodec(
        [FieldSpec("data", "float32", (copd_mlp.N_FEATURES,))],
        [FieldSpec("label", "int32", ())],
    )


def _train_steps(params, opt: Optimizer, arrays, epochs):
    state = {"params": params, "opt": opt.init(params)}

    @jax.jit
    def step(state, batch):
        (loss, m), g = jax.value_and_grad(copd_mlp.loss_fn, has_aux=True)(
            state["params"], batch
        )
        p2, o2 = opt.update(g, state["opt"], state["params"])
        return {"params": p2, "opt": o2}, m

    from repro.data.pipeline import BatchIterator

    it = BatchIterator(arrays, BATCH, seed=0, epochs=epochs)
    for batch in it:
        state, m = step(state, {k: jax.numpy.asarray(v) for k, v in batch.items()})
    jax.block_until_ready(m["loss"])
    return state


# ------------------------------------------------------------------- Table I
def table1_training_latency() -> dict[str, float]:
    ds = copd_mlp.synth_dataset()
    opt = adamw(1e-3)
    out = {}

    # 1. normal: in-memory arrays
    params = copd_mlp.init(jax.random.PRNGKey(0))
    t0 = time.perf_counter()
    _train_steps(params, opt, {k: v[: int(len(ds["label"]) * 0.8)] for k, v in ds.items()}, EPOCHS)
    out["normal"] = time.perf_counter() - t0

    # 2. data streams: ingest -> log -> control -> decode -> train
    log = core.StreamLog()
    log.create_topic("t1")
    t0 = time.perf_counter()
    msg = data.ingest(log, "t1", _codec(), ds, "bench-dep", validation_rate=0.2)
    got, _ = core.poll_control(log, "bench-dep")
    train_arrays, _ = data.StreamDataset(log, got).split()
    params = copd_mlp.init(jax.random.PRNGKey(0))
    _train_steps(params, opt, train_arrays, EPOCHS)
    out["streams"] = time.perf_counter() - t0

    # 3. full deployed pipeline (registry + control plane + job machinery)
    log2, reg = core.StreamLog(), core.Registry()
    spec = reg.register_model("copd-mlp")
    cfgc = reg.create_configuration([spec.model_id])
    dep = reg.deploy(cfgc.config_id, "train")
    log2.create_topic("t2")
    t0 = time.perf_counter()
    data.ingest(log2, "t2", _codec(), ds, dep.deployment_id, validation_rate=0.2)
    job = TrainingJob(log2, reg, dep.deployment_id, spec.model_id,
                      loss_fn=copd_mlp.loss_fn, init_fn=copd_mlp.init, opt=opt)
    job.run(batch_size=BATCH, epochs=EPOCHS)
    out["deployed"] = time.perf_counter() - t0
    return out


# ------------------------------------------------------------------ Table II
def table2_inference_latency(n_requests: int = 64) -> dict[str, float]:
    ds = copd_mlp.synth_dataset()
    params = copd_mlp.init(jax.random.PRNGKey(0))
    fwd = jax.jit(copd_mlp.forward)
    reqs = ds["data"][:n_requests]
    # warm every batch shape used below (full batch + per-partition halves)
    for shape in (reqs, reqs[: n_requests // 2]):
        jax.block_until_ready(fwd(params, shape))
    out = {}

    # 1. normal: direct predict
    t0 = time.perf_counter()
    jax.block_until_ready(fwd(params, reqs))
    out["normal"] = time.perf_counter() - t0

    # 2. streams: request topic -> decode -> predict -> response topic -> read
    log = core.StreamLog()
    log.create_topic("in")
    log.create_topic("out")
    t0 = time.perf_counter()
    log.produce_batch("in", [r.tobytes() for r in reqs])
    batch = log.read("in", 0, 0, n_requests)
    mat = batch.to_matrix()
    x = np.ascontiguousarray(mat).view(np.float32).reshape(n_requests, -1)
    preds = np.asarray(jax.block_until_ready(fwd(params, x)))
    log.produce_batch("out", [p.tobytes() for p in preds])
    _ = log.read("out", 0, 0, n_requests).to_matrix()
    out["streams"] = time.perf_counter() - t0

    # 3. deployed: full InferenceDeployment (consumer group, replicas)
    log2, reg = core.StreamLog(), core.Registry()
    spec = reg.register_model("copd-mlp")
    cfgc = reg.create_configuration([spec.model_id])
    dep = reg.deploy(cfgc.config_id, "train")
    res = reg.upload_result(dep.deployment_id, spec.model_id, {"loss": 0.0},
                            input_format="AVRO",
                            input_config=_codec().input_config())
    log2.create_topic("requests", core.LogConfig(num_partitions=2))
    infer = InferenceDeployment(
        log2, reg, res.result_id,
        predict_fn=lambda d: np.asarray(fwd(params, d["data"])),
        input_topic="requests", output_topic="preds", replicas=2,
    )
    t0 = time.perf_counter()
    log2.produce_batch("requests", [r.tobytes() for r in reqs[: n_requests // 2]], partition=0)
    log2.produce_batch("requests", [r.tobytes() for r in reqs[n_requests // 2 :]], partition=1)
    served = infer.drain()
    assert served == n_requests
    _ = log2.read("preds", 0, 0, n_requests)
    out["deployed"] = time.perf_counter() - t0
    return out


# ------------------------------------------- log/substrate micro-benchmarks
def log_throughput(n: int = 50_000, size: int = 256) -> dict[str, float]:
    log = core.StreamLog()
    log.create_topic("tp", core.LogConfig(num_partitions=1))
    payloads = [bytes(size)] * 1000
    t0 = time.perf_counter()
    for i in range(n // 1000):
        log.produce_batch("tp", payloads)
    dt_w = time.perf_counter() - t0
    t0 = time.perf_counter()
    got = 0
    off = 0
    while got < n:
        b = log.read("tp", 0, off, 4096)
        got += len(b)
        off = b.next_offset
    dt_r = time.perf_counter() - t0
    return {
        "produce_msgs_per_s": n / dt_w,
        "produce_MB_per_s": n * size / dt_w / 1e6,
        "consume_msgs_per_s": n / dt_r,
        "consume_MB_per_s": n * size / dt_r / 1e6,
    }


def stream_reuse_cost(n: int = 10_000) -> dict[str, float]:
    """§V: replaying a stream costs a control message, not the stream."""
    log = core.StreamLog()
    log.create_topic("big")
    ds = {"data": np.zeros((n, 5), np.float32), "label": np.zeros((n,), np.int32)}
    t0 = time.perf_counter()
    msg = data.ingest(log, "big", _codec(), ds, "D1")
    t_ingest = time.perf_counter() - t0
    logger = core.ControlLogger(log)
    t0 = time.perf_counter()
    logger.replay(msg, "D2")
    t_reuse = time.perf_counter() - t0
    return {
        "ingest_s": t_ingest,
        "reuse_s": t_reuse,
        "reuse_speedup": t_ingest / max(t_reuse, 1e-9),
        "control_msg_bytes": len(msg.to_bytes()),
    }
