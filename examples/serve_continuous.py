"""Continuous-batching LM serving smoke — the streaming-arrival workload.

A tiny LM behind a 2-worker serving group on a replicated cluster:
mixed-length requests stream onto a per-tenant-keyed request topic, the
continuous engines admit them into in-flight decode batches (DESIGN.md
§13), and keyed completions land on the response topic under
transactional publish. Run by the fast CI tier (scripts/ci.sh).

Run:  PYTHONPATH=src python examples/serve_continuous.py
"""

import jax
import numpy as np

import repro.configs as C
import repro.core as core
from repro.models.model import StreamModel
from repro.models.policy import Policy
from repro.serve import (
    ContinuousLMEngine,
    LMServingGroup,
    Request,
    decode_completion,
    encode_request,
    tenant_key,
)


def main():
    cfg = C.get_reduced("yi-6b")
    model = StreamModel(cfg, Policy(param_dtype="float32", compute_dtype="float32"))
    params = model.init(jax.random.PRNGKey(0))

    log = core.BrokerCluster(3)
    log.create_topic("lm-requests", core.LogConfig(num_partitions=2))
    log.create_topic("lm-responses", core.LogConfig(num_partitions=2))

    group = LMServingGroup(
        log,
        [
            ContinuousLMEngine(
                model, params, n_slots=4, n_blocks=32, block_size=8, max_blocks=8
            )
            for _ in range(2)
        ],
        input_topic="lm-requests",
        response_topic="lm-responses",
        transactional=True,
    )

    rng = np.random.default_rng(0)
    reqs = []
    for rid in range(10):
        plen = int(rng.choice([6, 10, 14]))
        prompt = rng.integers(0, cfg.vocab, plen).astype(np.int32)
        reqs.append(Request(rid, prompt, int(rng.integers(2, 7)), tenant=rid % 3))
    for r in reqs:
        log.produce("lm-requests", encode_request(r), key=tenant_key(r.tenant))

    served = group.drain()
    got = {}
    for part in range(2):
        end = log.end_offset("lm-responses", part)
        off = 0
        while off < end:
            batch = log.read("lm-responses", part, off, 64, isolation="read_committed")
            for buf in batch.values:
                rid, tenant, gen = decode_completion(buf)
                got[rid] = (tenant, gen)
            off = batch.next_offset

    assert served == len(reqs), f"served {served} != {len(reqs)}"
    assert sorted(got) == [r.req_id for r in reqs], sorted(got)
    for r in reqs:
        tenant, gen = got[r.req_id]
        assert tenant == r.tenant and len(gen) <= r.max_new, (r.req_id, tenant, gen)
    util = [w.engine.lane_utilization for w in group.workers if w.engine.lane_steps]
    print(
        f"served {served} completions via {len(group.workers)} workers; "
        f"lane utilization {', '.join(f'{u:.2f}' for u in util)}"
    )


if __name__ == "__main__":
    # CI smoke-step watchdog (same shape as examples/quickstart.py): a
    # hang must become a fast, loud failure. SERVE_TIMEOUT_S overrides.
    import os
    import threading

    timeout_s = float(os.environ.get("SERVE_TIMEOUT_S", "180"))

    def _watchdog():
        print(f"serve_continuous: exceeded {timeout_s:.0f}s watchdog — aborting",
              flush=True)
        os._exit(124)  # hard-exit: a hung thread can't block the failure

    timer = threading.Timer(timeout_s, _watchdog)
    timer.daemon = True
    timer.start()
    main()
    timer.cancel()
