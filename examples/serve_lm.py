"""Serve a small LM with batched streaming requests (paper Algorithm 2).

Requests (token prompts) arrive on an input topic across partitions; N
replicas in one consumer group pick them up, run prefill + greedy decode
with a KV cache, and stream completions to the output topic. Killing a
replica mid-stream demonstrates consumer-group failover.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
from repro.models.model import ArchConfig, StreamModel
from repro.models.policy import Policy
from repro.serve import InferenceDeployment

PROMPT, GEN = 24, 8


def tiny_lm() -> ArchConfig:
    return ArchConfig(
        name="lm-tiny", d_model=256, n_layers=4, n_heads=8, n_kv_heads=4,
        d_ff=768, vocab=4096, q_block=64,
    )


def main():
    cfg = tiny_lm()
    model = StreamModel(cfg, Policy())
    params = model.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name} ({cfg.param_count()/1e6:.1f}M params), "
          f"prompt={PROMPT} gen={GEN}")

    prefill = jax.jit(lambda p, b: model.prefill(p, b, PROMPT + GEN))
    decode = jax.jit(model.decode_step)

    def generate(d: dict) -> np.ndarray:
        toks = jnp.asarray(d["prompt"].astype(np.int32))
        logits, cache = prefill(params, {"tokens": toks})
        out = []
        tok = jnp.argmax(logits, -1)[:, None]
        for i in range(GEN):
            out.append(tok)
            lg, cache = decode(params, cache, tok, jnp.int32(PROMPT + i))
            tok = jnp.argmax(lg[:, 0], -1)[:, None]
        return np.asarray(jnp.concatenate(out, axis=1)).astype(np.int32)

    log, registry = core.StreamLog(), core.Registry()
    spec = registry.register_model("lm-tiny")
    config = registry.create_configuration([spec.model_id])
    dep = registry.deploy(config.config_id, "train")
    result = registry.upload_result(
        dep.deployment_id, spec.model_id, {"loss": 0.0},
        input_format="RAW",
        input_config={"data_type": "int32", "data_reshape": [PROMPT],
                      "label_type": "int32", "label_reshape": []},
    )

    log.create_topic("prompts", core.LogConfig(num_partitions=4))
    t = [0.0]  # controllable clock: we advance it to trigger failover
    infer = InferenceDeployment(
        log, registry, result.result_id,
        predict_fn=lambda d: generate({"prompt": d["data"]}),
        input_topic="prompts", output_topic="completions", replicas=2,
        session_timeout_s=30.0, clock=lambda: t[0],
    )

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (32, PROMPT)).astype(np.int32)
    for part in range(4):
        chunk = prompts[part * 8 : (part + 1) * 8]
        log.produce_batch("prompts", [r.tobytes() for r in chunk], partition=part)
    served = infer.drain()
    print(f"served {served} prompts; per-replica:",
          {r.replica_id: r.stats.processed for r in infer.replicas})

    # failover: kill replica 0, stream more prompts, replica 1 takes over
    infer.kill_replica(0)
    t[0] += 60.0  # session timeout elapses; replica-1 heartbeats on poll
    for part in range(4):
        chunk = prompts[part * 8 : (part + 1) * 8]
        log.produce_batch("prompts", [r.tobytes() for r in chunk], partition=part)
    served2 = infer.drain()
    print(f"after killing replica-0: served {served2} more; per-replica:",
          {r.replica_id: r.stats.processed for r in infer.replicas})

    n_out = log.end_offset("completions", 0)
    comp = log.read("completions", 0, 0, 4).to_matrix().view(np.int32)
    print(f"{n_out} completions on output topic; first: {comp[0].tolist()}")


if __name__ == "__main__":
    main()
