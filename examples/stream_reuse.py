"""Stream reuse — the paper's §V contribution, Fig. 8 re-enacted.

One dataset is streamed into the distributed log ONCE. Three deployed
configurations train from it; the second and third receive only a
control message (~250 bytes) pointing at [topic:partition:offset:length].
Then the retention policy expires the stream and a fourth deployment's
replay correctly fails with OffsetOutOfRange.

Run:  PYTHONPATH=src python examples/stream_reuse.py
"""

import numpy as np

import repro.core as core
import repro.data as data
from repro.configs import copd_mlp
from repro.data.formats import AvroCodec, FieldSpec
from repro.train import TrainingJob, adamw


def main():
    log, registry = core.StreamLog(), core.Registry()
    log.create_topic("shared", core.LogConfig(retention_bytes=65_536,
                                              segment_bytes=8_192))
    codec = AvroCodec(
        [FieldSpec("data", "float32", (copd_mlp.N_FEATURES,))],
        [FieldSpec("label", "int32", ())],
    )
    dataset = copd_mlp.synth_dataset()

    def new_deployment():
        spec = registry.register_model("copd-mlp")
        cfg = registry.create_configuration([spec.model_id])
        dep = registry.deploy(cfg.config_id, "train")
        return spec, dep

    # ---- D1: full ingestion (the green stream entering the log, Fig. 8)
    spec1, d1 = new_deployment()
    msg = data.ingest(log, "shared", codec, dataset, d1.deployment_id,
                      validation_rate=0.2)
    stream_bytes = log.size_bytes("shared")
    print(f"D1: ingested {msg.total_msg} records "
          f"({stream_bytes} bytes in the log) as {[str(r) for r in msg.ranges]}")
    r1 = TrainingJob(log, registry, d1.deployment_id, spec1.model_id,
                     loss_fn=copd_mlp.loss_fn, init_fn=copd_mlp.init,
                     opt=adamw(1e-2)).run(batch_size=10, epochs=10)
    print(f"D1 trained: loss {r1.metrics['loss']:.4f}")

    # ---- D2, D3: reuse via control messages only (tens of bytes)
    logger = core.ControlLogger(log)
    for name in ("D2", "D3"):
        spec_n, dn = new_deployment()
        replayed = logger.replay(msg, dn.deployment_id)
        sent = len(replayed.to_bytes())
        assert log.size_bytes("shared") == stream_bytes  # nothing re-streamed
        rn = TrainingJob(log, registry, dn.deployment_id, spec_n.model_id,
                         loss_fn=copd_mlp.loss_fn, init_fn=copd_mlp.init,
                         opt=adamw(1e-2)).run(batch_size=10, epochs=10)
        print(f"{name}: reused stream with a {sent}-byte control message "
              f"(vs {stream_bytes} bytes of data); loss {rn.metrics['loss']:.4f}")

    # ---- expiry: flood the topic so retention evicts the original stream
    filler = {"data": np.zeros((4000, copd_mlp.N_FEATURES), np.float32),
              "label": np.zeros((4000,), np.int32)}
    data.ingest(log, "shared", codec, filler, "filler-dep")
    print(f"log start offset now {log.start_offset('shared', 0)} "
          f"(original stream evicted by retention)")
    spec4, d4 = new_deployment()
    logger.replay(msg, d4.deployment_id)
    try:
        TrainingJob(log, registry, d4.deployment_id, spec4.model_id,
                    loss_fn=copd_mlp.loss_fn, init_fn=copd_mlp.init,
                    opt=adamw(1e-2)).run(batch_size=10, epochs=1)
        raise AssertionError("should have failed")
    except core.OffsetOutOfRange as e:
        print(f"D4: replay after expiry correctly fails: {e}")


if __name__ == "__main__":
    main()
