"""End-to-end driver: train a ~100M LM for a few hundred steps through the
stream pipeline, with checkpoint/restart fault tolerance.

The model is a 12-layer / d=768 llama-style decoder (~112M params) built
from the same ArchConfig machinery as the assigned architectures. Token
sequences are streamed into the distributed log as RAW records; the
training job reads them via a control message and checkpoints (step +
stream offsets) as it goes.

Run:
    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 200 --kill-at 80
        # trains 80 steps, "crashes", restarts from the checkpoint, finishes
"""

import argparse
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
import repro.data as data
from repro.data.formats import RawCodec
from repro.models.model import ArchConfig, StreamModel
from repro.models.policy import Policy
from repro.train import TrainingJob, adamw, cosine_schedule

SEQ = 256


def lm_100m() -> ArchConfig:
    return ArchConfig(
        name="lm-100m",
        d_model=768,
        n_layers=12,
        n_heads=12,
        n_kv_heads=4,
        d_ff=3072,
        vocab=8192,
        rope_theta=10000.0,
        q_block=128,
    )


def synth_corpus(n_seqs: int, vocab: int, seed=0) -> np.ndarray:
    """Markov-chain token streams — learnable structure, no dataset files."""
    rng = np.random.default_rng(seed)
    trans = rng.dirichlet(np.full(64, 0.1), size=64)
    states = np.zeros((n_seqs, SEQ), np.int32)
    s = rng.integers(0, 64, n_seqs)
    for t in range(SEQ):
        states[:, t] = s
        u = rng.random(n_seqs)
        s = (trans[s].cumsum(1) > u[:, None]).argmax(1)
    return (states * (vocab // 64) + rng.integers(0, 4, states.shape)).astype(np.int32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--kill-at", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = lm_100m()
    model = StreamModel(cfg, Policy())
    n_params = cfg.param_count()
    print(f"model: {cfg.name}, {n_params/1e6:.1f}M params")

    log, registry = core.StreamLog(), core.Registry()
    spec = registry.register_model("lm-100m")
    config = registry.create_configuration([spec.model_id])
    dep = registry.deploy(config.config_id, "train")

    # stream the corpus into the log (RAW int32 sequences)
    corpus = synth_corpus(2048, cfg.vocab)
    codec = RawCodec("int32", (SEQ,), "int32", ())
    log.create_topic("corpus", core.LogConfig(num_partitions=4))
    msg = data.ingest(
        log, "corpus", codec,
        {"data": corpus, "label": np.zeros(len(corpus), np.int32)},
        dep.deployment_id,
    )
    print(f"corpus in log: {msg.total_msg} seqs, ranges {[str(r) for r in msg.ranges]}")

    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(), "train_lm_ckpt")
    opt = adamw(cosine_schedule(3e-4, warmup_steps=20, total_steps=args.steps))

    def make_job():
        return TrainingJob(
            log, registry, dep.deployment_id, spec.model_id,
            loss_fn=lambda p, b: model.loss(p, {"tokens": b["data"]}, loss_chunk=SEQ),
            init_fn=model.init, opt=opt, ckpt_dir=ckpt_dir, ckpt_every=40, seed=0,
        )

    if args.kill_at:
        try:
            make_job().run(batch_size=args.batch, max_steps=args.steps,
                           crash_after=args.kill_at)
        except RuntimeError as e:
            print(f"!! {e} — restarting from checkpoint")
        res = make_job().run(batch_size=args.batch, max_steps=args.steps, resume=True)
    else:
        res = make_job().run(batch_size=args.batch, max_steps=args.steps)
    print(f"done at step {res.steps}: {res.metrics}")

    # greedy generation sanity check
    job_params = None
    for r in registry.results_for(dep.deployment_id):
        print(f"registry result {r.result_id}: loss={r.metrics.get('loss'):.4f}")


if __name__ == "__main__":
    main()
