"""Quickstart — the paper's own validation (§VI), end to end in ~30 lines.

Define a model -> create a configuration -> deploy for training -> stream
the (synthetic) HCOPD dataset through a replicated 3-broker cluster with
exactly-once idempotent producers -> train -> deploy the trained model ->
stream inference requests -> read predictions.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

import repro.core as core
import repro.data as data
from repro.configs import copd_mlp
from repro.data.formats import AvroCodec, FieldSpec
from repro.serve import InferenceDeployment
from repro.train import TrainingJob, adamw


def main():
    # a replicated cluster (rf=3, acks=all) — the same StreamBackend
    # surface as a bare StreamLog, with broker failover underneath
    log, registry = core.BrokerCluster(3), core.Registry()
    # background reporter: snapshots of the whole registry flow onto the
    # replicated __metrics topic while the pipeline runs (DESIGN §9)
    reporter = log.start_metrics_reporter(interval_s=0.25)

    # A) define the ML model (paper Listing 1/2: just the model definition)
    spec = registry.register_model("copd-mlp", description="HCOPD classifier")
    # B) a configuration = models trained from the same stream
    config = registry.create_configuration([spec.model_id])
    # C) deploy it for training
    deployment = registry.deploy(config.config_id, "train",
                                 training_kwargs={"batch_size": 10, "epochs": 25})

    # D) ingest the training stream (AVRO multi-input schema, §III-D)
    codec = AvroCodec(
        [FieldSpec("data", "float32", (copd_mlp.N_FEATURES,))],
        [FieldSpec("label", "int32", ())],
    )
    # training data is append-only; keyed state (e.g. a feature or
    # model-version topic) would use cleanup="compact" instead — the
    # storage engine keeps the latest record per key at a stable offset
    # and drops superseded history (DESIGN §11)
    log.create_topic("copd", core.LogConfig(num_partitions=2))
    dataset = copd_mlp.synth_dataset()
    # two idempotent producer threads, one per partition: client retries
    # after a lost ack can never duplicate a training record (DESIGN §7)
    msg = data.ingest(log, "copd", codec, dataset, deployment.deployment_id,
                      validation_rate=0.2, num_threads=2, idempotent=True)
    print(f"streamed {msg.total_msg} records as {[str(r) for r in msg.ranges]}")

    # the training Job (paper Algorithm 1)
    job = TrainingJob(log, registry, deployment.deployment_id, spec.model_id,
                      loss_fn=copd_mlp.loss_fn, init_fn=copd_mlp.init,
                      opt=adamw(1e-2))
    result = job.run(batch_size=10, epochs=25)
    print(f"trained: {result.metrics}  eval: {result.eval_metrics}")

    # E) deploy the trained model for inference (2 replicas, Algorithm 2)
    trained = registry.results_for(deployment.deployment_id)[0]
    params = job._final_state["params"]
    log.create_topic("requests", core.LogConfig(num_partitions=2))
    infer = InferenceDeployment(
        log, registry, trained.result_id,
        predict_fn=lambda d: np.asarray(jax.nn.softmax(
            copd_mlp.forward(params, d["data"]), axis=-1)),
        input_topic="requests", output_topic="predictions", replicas=2,
    )

    # F) stream data for inference
    reqs = dataset["data"][:16]
    log.produce_batch("requests", [r.tobytes() for r in reqs[:8]], partition=0)
    log.produce_batch("requests", [r.tobytes() for r in reqs[8:]], partition=1)
    served = infer.drain()
    preds = (log.read("predictions", 0, 0, 16).to_matrix()
             .view(np.float32).reshape(-1, copd_mlp.N_CLASSES))
    acc = (preds.argmax(1) == dataset["label"][:16]).mean()
    print(f"served {served} predictions via {len(infer.replicas)} replicas; "
          f"accuracy {acc:.2f}")

    # G) end-of-run observability summary — every number comes from the
    # cluster's own metrics registry (DESIGN §9), not ad-hoc bookkeeping
    log.stop_metrics_reporter()
    ingest_rate = log.metrics.gauge_value("ingest_records_per_s", topic="copd")
    lag = sum(sum(r.consumer.lag().values())
              for r in infer.replicas if r.alive)
    snap = log.metrics_snapshot()
    elections = sum(v for k, v in snap["counters"].items()
                    if k.startswith("partition_elections_total"))
    published = log.end_offset(core.METRICS_TOPIC, 0)
    print(f"metrics: ingest {ingest_rate:,.0f} records/s; inference "
          f"consumer lag {lag}; partition elections {elections}; "
          f"{published} snapshots on {core.METRICS_TOPIC} "
          f"({reporter.published} published by the reporter)")
    assert lag == 0, f"inference group should have drained to lag 0, got {lag}"


if __name__ == "__main__":
    # CI smoke-step watchdog: the fast CI tier runs this example on every
    # push (scripts/ci.sh), so a hang must become a fast, loud failure
    # instead of stalling the workflow until the job-level timeout.
    # ~7 s is the healthy runtime; QUICKSTART_TIMEOUT_S overrides.
    import os
    import threading

    timeout_s = float(os.environ.get("QUICKSTART_TIMEOUT_S", "120"))

    def _watchdog():
        print(f"quickstart: exceeded {timeout_s:.0f}s watchdog — aborting",
              flush=True)
        os._exit(124)  # hard-exit: a hung thread can't block the failure

    timer = threading.Timer(timeout_s, _watchdog)
    timer.daemon = True
    timer.start()
    main()
    timer.cancel()
