#!/usr/bin/env sh
# CI entrypoint — the exact gates run by .github/workflows/ci.yml, exposed
# as one script so local runs and CI cannot drift (scripts/test.sh
# delegates here).
#
#   scripts/ci.sh          # fast tier: syntax gate -> pytest -m "not slow"
#                          #            -> quickstart smoke (watchdogged)
#   scripts/ci.sh --full   # fast tier, then the full tier (@slow system
#                          #            tests + the chaos suite)
#
# Frozen environment: this script installs NOTHING. The interpreter must
# already provide python3 + pytest (+ numpy/jax for the ML layers);
# tests/conftest.py stubs the optional extras (hypothesis) so collection
# never errors on a stdlib+pytest interpreter.
set -e
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== syntax gate (compileall) =="
python -m compileall -q src tests benchmarks examples

# -p no:cacheprovider: no .pytest_cache/ bytecode-adjacent artifacts in the tree
echo "== fast tier (pytest -m 'not slow') =="
python -m pytest -x -q -m "not slow" -p no:cacheprovider

echo "== quickstart smoke (examples/quickstart.py, watchdog-guarded) =="
QUICKSTART_TIMEOUT_S="${QUICKSTART_TIMEOUT_S:-120}" python examples/quickstart.py

if [ "$1" = "--full" ]; then
    echo "== full tier (slow system tests + chaos suite) =="
    python -m pytest -q -m "slow" -p no:cacheprovider
fi
