#!/usr/bin/env sh
# CI entrypoint — the exact gates run by .github/workflows/ci.yml, exposed
# as one script so local runs and CI cannot drift (scripts/test.sh
# delegates here).
#
#   scripts/ci.sh          # fast tier: syntax gate -> pytest -m "not slow"
#                          #            -> quickstart smoke (watchdogged)
#   scripts/ci.sh --full   # fast tier, then the full tier (@slow system
#                          #            tests + the chaos suite)
#
# Frozen environment: this script installs NOTHING. The interpreter must
# already provide python3 + pytest (+ numpy/jax for the ML layers);
# tests/conftest.py stubs the optional extras (hypothesis) so collection
# never errors on a stdlib+pytest interpreter.
set -e
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== syntax gate (compileall) =="
python -m compileall -q src tests benchmarks examples

# static lock-hierarchy analyzer (DESIGN.md §12): exits nonzero on any
# unjustified lock-order / blocking-under-lock / unbalanced-acquire /
# silent-except finding, and on stale or justification-less allowlist
# entries (src/repro/analysis/lockcheck_allowlist.py)
echo "== lockcheck (static lock-hierarchy gate) =="
python -m repro.analysis.lockcheck src/repro

# -p no:cacheprovider: no .pytest_cache/ bytecode-adjacent artifacts in the tree
# --durations=15: name the slowest tests, so fast-tier creep is visible in
# every CI log before it trips the budget below
# FAST_BUDGET_S: the fast tier must stay fast as the suite grows — if the
# not-slow pytest run exceeds this wall-clock budget (default 5 min), the
# tier fails even though every test passed; move the offenders to @slow.
FAST_BUDGET_S="${FAST_BUDGET_S:-300}"
echo "== fast tier (pytest -m 'not slow', budget ${FAST_BUDGET_S}s) =="
fast_t0=$(date +%s)
python -m pytest -x -q -m "not slow" --durations=15 -p no:cacheprovider
fast_elapsed=$(( $(date +%s) - fast_t0 ))
if [ "$fast_elapsed" -gt "$FAST_BUDGET_S" ]; then
    echo "FAIL: fast tier took ${fast_elapsed}s, over the ${FAST_BUDGET_S}s budget" \
         "- move the slowest tests (see --durations above) to @pytest.mark.slow"
    exit 1
fi
echo "== fast tier wall clock: ${fast_elapsed}s (budget ${FAST_BUDGET_S}s) =="

echo "== quickstart smoke (examples/quickstart.py, watchdog-guarded) =="
QUICKSTART_TIMEOUT_S="${QUICKSTART_TIMEOUT_S:-120}" python examples/quickstart.py

# continuous-batching LM serving end to end (DESIGN.md §13): tiny model,
# 2-worker transactional serving group on a replicated cluster, streamed
# mixed-length requests drained through the continuous engines; its
# __main__ watchdog turns a hang into a fast failure like quickstart's
echo "== serving smoke (examples/serve_continuous.py, watchdog-guarded) =="
SERVE_TIMEOUT_S="${SERVE_TIMEOUT_S:-180}" python examples/serve_continuous.py

if [ "$1" = "--full" ]; then
    echo "== full tier (slow system tests + chaos suite) =="
    python -m pytest -q -m "slow" -p no:cacheprovider

    # runtime lock-order witness (DESIGN.md §12): re-run the fast tier
    # and the chaos suite with every lock witnessed; the session fixture
    # in tests/conftest.py fails either run on any rank violation or
    # observed-graph cycle, and dumps the observed acquisition-order
    # graph as JSON (uploaded as a nightly CI artifact)
    echo "== lock-order witness tier (fast tier, REPRO_LOCK_WITNESS=1) =="
    REPRO_LOCK_WITNESS=1 REPRO_LOCK_GRAPH="lock_order_graph_fast.json" \
        python -m pytest -q -m "not slow" -p no:cacheprovider
    echo "== lock-order witness tier (chaos suite, REPRO_LOCK_WITNESS=1) =="
    REPRO_LOCK_WITNESS=1 REPRO_LOCK_GRAPH="lock_order_graph_chaos.json" \
        python -m pytest -q -p no:cacheprovider tests/test_cluster_chaos.py \
        tests/test_transactions.py

    # serving benchmark + gate (DESIGN.md §13): continuous vs wave
    # batching under the tuned-host profile, then the host-aware
    # regression gate recomputing speedup/TTFT from the stored pairs
    echo "== serving benchmark (continuous vs wave) + gate =="
    . scripts/profile_env.sh
    python -m benchmarks.serving
    python benchmarks/check_bench.py BENCH_replication.json \
        --serving BENCH_serving.json
fi
