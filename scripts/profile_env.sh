#!/usr/bin/env sh
# Host-side perf knobs for the benchmark entrypoints (SNIPPETS.md items
# 2-3: the olmax / HomebrewNLP run.sh recipes). SOURCE this file — it
# only exports environment variables:
#
#   . scripts/profile_env.sh
#   PYTHONPATH=src python -m benchmarks.datapath
#
# Everything degrades gracefully on hosts without the optional pieces
# (frozen container policy: nothing is installed, knobs that need a
# missing library are skipped):
#
# * tcmalloc LD_PRELOAD — thread-caching malloc speeds up the
#   allocation-heavy host path (batch assembly, decode fallbacks) and
#   removes glibc-malloc arena contention under the prefetch threads.
#   Only set when the library is actually present.
# * TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD — silence tcmalloc's
#   large-alloc warnings for big numpy buffers (60 GB threshold).
# * TF_CPP_MIN_LOG_LEVEL=4 — mute the XLA/TSL C++ banner noise that
#   otherwise pollutes benchmark CSV output.
# * XLA_FLAGS --xla_force_host_platform_device_count — pin the CPU
#   platform's device count to the host's actual core budget instead of
#   XLA's default, so intra-op threading doesn't oversubscribe the
#   benchmark's own prefetch threads. Appends to (never clobbers) any
#   caller-provided XLA_FLAGS.

# tcmalloc, when the host has it (checked at the usual multiarch paths)
for _tcm in \
    /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
    /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
    /usr/lib/libtcmalloc.so.4; do
    if [ -r "$_tcm" ]; then
        export LD_PRELOAD="$_tcm${LD_PRELOAD:+:$LD_PRELOAD}"
        export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000
        break
    fi
done
unset _tcm

# mute XLA/TSL C++ logging so CSV rows stay machine-parseable
export TF_CPP_MIN_LOG_LEVEL=4

# one XLA host device per available core (sched_getaffinity respects
# container CPU limits where nproc may not)
_cores="$(python -c 'import os; print(len(os.sched_getaffinity(0)))' \
          2>/dev/null || echo 1)"
export XLA_FLAGS="--xla_force_host_platform_device_count=${_cores}${XLA_FLAGS:+ $XLA_FLAGS}"
unset _cores
