#!/usr/bin/env sh
# Two-tier test runner — delegates to scripts/ci.sh so a local run executes
# the identical gates CI does (syntax gate, fast tier, quickstart smoke,
# optionally the full tier); the two can't drift.
#   scripts/test.sh          # fast tier + smoke, then full suite
#   scripts/test.sh --fast   # fast tier + smoke only
set -e
if [ "$1" = "--fast" ]; then
    exec "$(dirname "$0")/ci.sh"
fi
exec "$(dirname "$0")/ci.sh" --full
