#!/usr/bin/env sh
# Two-tier test runner: fail fast on the quick tier, then run everything.
#   scripts/test.sh          # fast tier, then full suite
#   scripts/test.sh --fast   # fast tier only
set -e
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# -p no:cacheprovider: no .pytest_cache/ bytecode-adjacent artifacts in the tree
echo "== fast tier (pytest -m 'not slow') =="
python -m pytest -x -q -m "not slow" -p no:cacheprovider

if [ "$1" = "--fast" ]; then
    exit 0
fi

echo "== full suite (slow tests included) =="
python -m pytest -q -m "slow" -p no:cacheprovider
