"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Spins up an InferenceDeployment (paper Algorithm 2) for a (reduced)
architecture: N replicas on a consumer group, prompts streamed through the
input topic, greedy completions to the output topic.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
import repro.core as core
from repro.models.model import StreamModel
from repro.models.policy import Policy
from repro.serve import InferenceDeployment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.names())
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--prompts", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    args = ap.parse_args()

    cfg = configs.get_reduced(args.arch)
    if cfg.enc_dec or cfg.frontend != "none":
        raise SystemExit(f"{args.arch}: serve launcher supports text decoders; "
                         "see examples/serve_lm.py for frontend stubs")
    model = StreamModel(cfg, Policy())
    params = model.init(jax.random.PRNGKey(0))
    s_cache = args.prompt_len + args.gen
    prefill = jax.jit(lambda p, b: model.prefill(p, b, s_cache))
    decode = jax.jit(model.decode_step)

    def generate(d):
        toks = jnp.asarray(d["data"].astype(np.int32))
        logits, cache = prefill(params, {"tokens": toks})
        tok = jnp.argmax(logits, -1)[:, None]
        outs = [tok]
        for i in range(args.gen - 1):
            lg, cache = decode(params, cache, tok, jnp.int32(args.prompt_len + i))
            tok = jnp.argmax(lg[:, 0], -1)[:, None]
            outs.append(tok)
        return np.asarray(jnp.concatenate(outs, 1)).astype(np.int32)

    log, registry = core.StreamLog(), core.Registry()
    spec = registry.register_model(args.arch)
    c = registry.create_configuration([spec.model_id])
    dep = registry.deploy(c.config_id, "train")
    res = registry.upload_result(
        dep.deployment_id, spec.model_id, {"loss": 0.0},
        input_format="RAW",
        input_config={"data_type": "int32", "data_reshape": [args.prompt_len],
                      "label_type": "int32", "label_reshape": []},
    )
    log.create_topic("prompts", core.LogConfig(num_partitions=args.replicas * 2))
    infer = InferenceDeployment(
        log, registry, res.result_id, predict_fn=generate,
        input_topic="prompts", output_topic="completions",
        replicas=args.replicas,
    )
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.prompts, args.prompt_len)).astype(np.int32)
    per = max(args.prompts // (args.replicas * 2), 1)
    for p in range(args.replicas * 2):
        chunk = prompts[p * per : (p + 1) * per]
        if len(chunk):
            log.produce_batch("prompts", [r.tobytes() for r in chunk], partition=p)
    try:
        served = infer.drain()
    finally:
        infer.close()
    print(f"served {served} prompts across "
          f"{ {r.replica_id: r.stats.processed for r in infer.replicas} }")
    print(f"{log.end_offset('completions', 0)} completions on the output topic")


if __name__ == "__main__":
    main()
