"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
must set XLA_FLAGS before any jax initialization.

Mesh shapes (TPU v5e):
  single-pod: (16, 16)   axes ("data", "model")   = 256 chips
  multi-pod : (2, 16, 16) axes ("pod", "data", "model") = 512 chips
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "mesh_axis_sizes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
