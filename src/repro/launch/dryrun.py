import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
SPMD-partitions, and compiles on the production mesh — and extract the
memory/cost/collective numbers the roofline analysis (EXPERIMENTS.md
§Roofline) reads.

MUST be run as its own process (``python -m repro.launch.dryrun``): the
XLA_FLAGS line above executes before any other import so jax sees 512
placeholder CPU devices. Smoke tests and benches run in normal processes
and see 1 device.

Usage:
    python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    python -m repro.launch.dryrun --all --out experiments/dryrun
    python -m repro.launch.dryrun --all --mesh multi
"""

import argparse
import json
import re
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import repro.configs as configs
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes
from repro.models.model import ArchConfig, StreamModel
from repro.models.policy import Policy
from repro.train.optimizer import adamw, adamw8bit
from repro.train.trainer import build_train_step, state_pspecs

# archs whose parameter+optimizer state needs ZeRO-3 over the data axis
FSDP_ARCHS = {
    "qwen3-moe-30b-a3b",
    "arctic-480b",
    "qwen2-7b",
    "yi-6b",
    "mistral-large-123b",
    "pixtral-12b",
    "recurrentgemma-9b",
    "gemma2-2b",   # attention params don't TP-shard (8 heads); ZeRO them
    "mamba2-2.7b",
}
# archs whose optimizer moments must be 8-bit to fit (DESIGN.md §4)
OPT8BIT_ARCHS = {"arctic-480b", "mistral-large-123b", "qwen3-moe-30b-a3b"}
# archs whose *serving* weights must be int8-PTQ to fit 16 GB/chip; they
# also replicate the (tiny) decode token batch so the KV cache and expert
# d_ff can shard over the data axis too (flash-decode + 2D EP)
SERVE_INT8_ARCHS = {"arctic-480b", "mistral-large-123b"}
# pad query heads up to a multiple of the model axis so attention runs the
# collective-free "heads" strategy instead of context parallelism. Measured
# (EXPERIMENTS.md §Perf it-A2): a clear win ONLY for arctic (halved its
# collective bytes); on qwen2/gemma2 the seq-strategy reshard bytes merely
# became TP all-reduce bytes while temp memory regressed ~2x, so those keep
# context parallelism (hypothesis partially refuted — recorded).
HEAD_PAD_ARCHS = {"arctic-480b": 64}
# gradient-accumulation microbatch count for train_4k: bounds the
# per-device activation checkpoints (n_layers x B_micro x S x d) to fit
# 16 GB HBM (EXPERIMENTS.md §Perf it-8)
MICROBATCH_ARCHS = {
    "mistral-large-123b": 16,
    "arctic-480b": 8,
    "pixtral-12b": 8,
    "yi-6b": 4,
    "qwen3-moe-30b-a3b": 4,
    "mamba2-2.7b": 4,
    "recurrentgemma-9b": 4,
    "qwen2-7b": 2,
    "whisper-tiny": 2,
}
# remat policy per arch family for train_4k
REMAT_ARCHS = {
    # 'full' = nothing_saveable: per-group backward recompute. 'block'
    # (dots_with_no_batch_dims_saveable) stacks every projection output
    # across scan groups in fp32 — measured +20 GB/dev on gemma2
    # (EXPERIMENTS.md §Perf it-3); 'full' trades ~30% more flops for it.
    "arctic-480b": "full",
    "mistral-large-123b": "full",
    "qwen3-moe-30b-a3b": "full",
    "qwen2-7b": "full",
    "yi-6b": "full",
    "pixtral-12b": "full",
    "recurrentgemma-9b": "full",
    "gemma2-2b": "full",
    "mamba2-2.7b": "full",
    "whisper-tiny": "full",
}

COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*=?"
)
SHAPE_RE = re.compile(r"(f8e4m3fn|f8e5m2|f32|bf16|f16|s32|u32|s8|u8|pred|s64|u64|f64)\[([\d,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def _shape_bytes(m: re.Match) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-device bytes moved by each collective kind, from optimized HLO.

    For each collective instruction we count the *result* shapes on the
    line (the per-device tensor that transits the interconnect); -start/
    -done pairs are counted once via the -start line.
    """
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        rhs = ls.split("=", 1)[1]
        # "<result type> <op-name>(operands...)": match op name before '('
        head = rhs.split("(", 1)[0]
        m = COLLECTIVE_RE.search(head)
        if not m or "-done" in head:
            continue
        kind = m.group(1)
        # result type(s): every shape token in the head (covers tuples)
        size = sum(_shape_bytes(sm) for sm in SHAPE_RE.finditer(head))
        out[kind] = out.get(kind, 0) + size
    return out


def policy_for(cfg: ArchConfig, shape: configs.ShapeCell, mesh: Mesh) -> Policy:
    sizes = mesh_axis_sizes(mesh)
    batch_axes = tuple(a for a in ("pod", "data") if a in sizes)
    fsdp = ("data",) if (cfg.name in FSDP_ARCHS and shape.kind == "train") else ()
    seq_axis = None
    weights_int8 = False
    ep_inner: tuple = ()
    big_serve = cfg.name in SERVE_INT8_ARCHS and shape.kind in ("prefill", "decode")
    if big_serve:
        weights_int8 = True
    if shape.kind == "decode":
        dp = 1
        for a in batch_axes:
            dp *= sizes[a]
        # flash-decode (shard_map) streams a seq-sharded cache everywhere a
        # cache exists; batch stays on data axes when it covers them
        if shape.global_batch < dp or big_serve:
            batch_axes = ()
            seq_axis = tuple(a for a in ("pod", "data", "model") if a in sizes)
            if big_serve and cfg.moe is not None:
                ep_inner = tuple(a for a in ("pod", "data") if a in sizes)
        else:
            seq_axis = "model"
        if cfg.n_heads == 0:  # attention-free (mamba2): no kv cache to shard
            seq_axis = None
    if big_serve and shape.kind == "prefill" and cfg.moe is not None:
        # arctic prefill: int8 expert weights still need the data axis
        fsdp = ("data",)
    remat = REMAT_ARCHS.get(cfg.name, "none") if shape.kind == "train" else "none"
    # arctic/mistral-large need full ZeRO (even 8-bit moments of TP-sharded
    # leaves overflow HBM); everyone else ZeROs only non-TP-shardable params
    selective = cfg.name not in OPT8BIT_ARCHS
    return Policy(
        mesh_axes=sizes,
        batch_axes=batch_axes,
        tp_axis="model",
        fsdp_axes=fsdp,
        fsdp_selective=selective,
        seq_axis=seq_axis,
        remat=remat,
        weights_int8=weights_int8,
        ep_inner_axes=ep_inner,
        kv_cache_dtype="float8_e4m3fn" if (big_serve and shape.kind == "decode") else "bfloat16",
    )


def _optimizer(cfg: ArchConfig):
    return adamw8bit(1e-4) if cfg.name in OPT8BIT_ARCHS else adamw(1e-4)


def _serving_params(model: StreamModel, mesh: Mesh):
    """(ShapeDtypeStruct tree, shardings) for prefill/decode — int8-PTQ'd
    when the policy says so."""
    from repro.models.model import quantize_params, quantized_pspecs

    raw_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = model.param_pspecs()
    if model.policy.weights_int8:
        params_sds = jax.eval_shape(quantize_params, raw_sds)
        pspecs = quantized_pspecs(raw_sds, pspecs)
    else:
        params_sds = raw_sds
    pshard = jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), pspecs, is_leaf=lambda x: isinstance(x, P)
    )
    return params_sds, pshard


def effective_config(arch_id: str):
    import dataclasses as dc

    cfg = configs.get(arch_id)
    if arch_id in HEAD_PAD_ARCHS:
        cfg = dc.replace(cfg, n_heads=HEAD_PAD_ARCHS[arch_id])
    return cfg


def lower_cell(arch_id: str, shape_name: str, mesh: Mesh):
    """Lower + compile one cell. Returns (compiled, lowered, meta)."""
    cfg = effective_config(arch_id)
    shape = configs.SHAPES[shape_name]
    pol = policy_for(cfg, shape, mesh)
    model = StreamModel(cfg, pol, mesh)
    in_specs = configs.input_specs(cfg, shape)
    batch_sharding = {
        k: NamedSharding(mesh, P(pol.batch_spec(v.shape[0])))
        for k, v in in_specs.items()
    }

    with mesh:
        if shape.kind == "train":
            opt = _optimizer(cfg)
            state_sds = jax.eval_shape(
                lambda: {
                    "params": model.init(jax.random.PRNGKey(0)),
                    "opt": opt.init(
                        jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
                    ),
                }
            )
            specs = state_pspecs(model, opt)
            shardings = jax.tree.map(
                lambda sp: NamedSharding(mesh, sp), specs,
                is_leaf=lambda x: isinstance(x, P),
            )
            step_fn, _ = build_train_step(
                model, opt, mesh=None,
                microbatches=MICROBATCH_ARCHS.get(cfg.name, 1),
            )
            jitted = jax.jit(
                lambda s, b: step_fn(s, b),
                in_shardings=(shardings, batch_sharding),
                out_shardings=(shardings, None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_sds, in_specs)
        elif shape.kind == "prefill":
            params_sds, pshard = _serving_params(model, mesh)
            fn = jax.jit(
                lambda p, b: model.prefill(p, b, shape.seq_len),
                in_shardings=(pshard, batch_sharding),
            )
            lowered = fn.lower(params_sds, in_specs)
        else:  # decode
            params_sds, pshard = _serving_params(model, mesh)
            cache_sds = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len)
            )
            cshard = jax.tree.map(
                lambda sp: NamedSharding(mesh, sp),
                model.cache_pspecs(shape.global_batch),
                is_leaf=lambda x: isinstance(x, P),
            )
            fn = jax.jit(
                lambda p, c, t, pos: model.decode_step(p, c, t, pos),
                in_shardings=(
                    pshard,
                    cshard,
                    batch_sharding["tokens"],
                    NamedSharding(mesh, P()),
                ),
                out_shardings=(None, cshard),
                donate_argnums=(1,),
            )
            lowered = fn.lower(
                params_sds,
                cache_sds,
                in_specs["tokens"],
                jax.ShapeDtypeStruct((), jnp.int32),
            )
        compiled = lowered.compile()
    return compiled, lowered, {"cfg": cfg, "shape": shape, "policy": pol}


def analyze(compiled, mesh: Mesh) -> dict:
    n_dev = mesh.devices.size
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {
                k: int(getattr(ma, k))
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                )
                if hasattr(ma, k)
            }
    except Exception:
        pass
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    return {
        "devices": n_dev,
        "flops_per_device": float(cost.get("flops", -1)) if cost else -1,
        "bytes_accessed_per_device": float(cost.get("bytes accessed", -1)) if cost else -1,
        "transcendentals": float(cost.get("transcendentals", -1)) if cost else -1,
        "memory_analysis": mem,
        "collective_bytes_per_device": coll,
        "hlo_collective_counts": {
            k: hlo.count(f" {k}") for k in
            ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
        },
    }


def measure_cell(arch_id: str, shape_name: str, multi_pod: bool, out_dir: str | None):
    """Depth-extrapolated cost measurement (EXPERIMENTS.md §Roofline).

    XLA's HloCostAnalysis visits a while-loop body ONCE, so the scanned
    full-depth lowering under-reports flops/bytes/collectives by the trip
    counts. This lowers 1-group and 2-group variants with every scan
    UNROLLED (policy.unroll) and microbatching off, then extrapolates
    linearly in depth:  cost(L) = c1 + (c2 - c1) * (L/p - 1).
    The full-depth record keeps the authoritative memory_analysis.
    """
    import dataclasses as dc

    ok, why = configs.cell_supported(arch_id, shape_name)
    mesh_name = "multi" if multi_pod else "single"
    tag = f"{arch_id}__{shape_name}__{mesh_name}"
    if not ok:
        return None
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg_full = effective_config(arch_id)
    shape = configs.SHAPES[shape_name]
    p = len(cfg_full.pattern)
    t0 = time.time()

    def one(groups: int) -> dict:
        cfg = dc.replace(cfg_full, n_layers=groups * p)
        pol = dc.replace(policy_for(cfg, shape, mesh), unroll=True)
        model = StreamModel(cfg, pol, mesh)
        in_specs = configs.input_specs(cfg, shape)
        bshard = {
            k: NamedSharding(mesh, P(pol.batch_spec(v.shape[0])))
            for k, v in in_specs.items()
        }
        with mesh:
            if shape.kind == "train":
                opt = _optimizer(cfg)
                raw = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
                state_sds = {"params": raw, "opt": jax.eval_shape(opt.init, raw)}
                specs = state_pspecs(model, opt)
                sh = jax.tree.map(
                    lambda sp: NamedSharding(mesh, sp), specs,
                    is_leaf=lambda x: isinstance(x, P),
                )
                step_fn, _ = build_train_step(model, opt, mesh=None, microbatches=1)
                c = jax.jit(
                    step_fn, in_shardings=(sh, bshard), out_shardings=(sh, None)
                ).lower(state_sds, in_specs).compile()
            elif shape.kind == "prefill":
                params_sds, pshard = _serving_params(model, mesh)
                c = jax.jit(
                    lambda pp, b: model.prefill(pp, b, shape.seq_len),
                    in_shardings=(pshard, bshard),
                ).lower(params_sds, in_specs).compile()
            else:
                params_sds, pshard = _serving_params(model, mesh)
                cache_sds = jax.eval_shape(
                    lambda: model.init_cache(shape.global_batch, shape.seq_len)
                )
                cshard = jax.tree.map(
                    lambda sp: NamedSharding(mesh, sp),
                    model.cache_pspecs(shape.global_batch),
                    is_leaf=lambda x: isinstance(x, P),
                )
                c = jax.jit(
                    model.decode_step,
                    in_shardings=(pshard, cshard, bshard["tokens"], NamedSharding(mesh, P())),
                    out_shardings=(None, cshard),
                ).lower(
                    params_sds, cache_sds, in_specs["tokens"],
                    jax.ShapeDtypeStruct((), jnp.int32),
                ).compile()
        ca = c.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        return {
            "flops": float(ca.get("flops", 0)),
            "bytes": float(ca.get("bytes accessed", 0)),
            "coll": collective_bytes(c.as_text()),
        }

    try:
        c1 = one(1)
        c2 = one(2)
        g_full = cfg_full.n_layers / p

        def extra(a, b):
            return max(a + (b - a) * (g_full - 1), 0.0)

        coll_kinds = set(c1["coll"]) | set(c2["coll"])
        rec = {
            "cell": tag,
            "status": "OK",
            "measure_s": round(time.time() - t0, 1),
            "groups_full": g_full,
            "flops_per_device": extra(c1["flops"], c2["flops"]),
            "bytes_accessed_per_device": extra(c1["bytes"], c2["bytes"]),
            "collective_bytes_per_device": {
                k: extra(c1["coll"].get(k, 0), c2["coll"].get(k, 0))
                for k in coll_kinds
            },
            "raw": {"g1": c1, "g2": c2},
        }
        print(f"** measured {tag}: flops/dev {rec['flops_per_device']:.3e} "
              f"bytes/dev {rec['bytes_accessed_per_device']:.3e} ({rec['measure_s']}s)")
    except Exception as e:
        rec = {"cell": tag, "status": "FAIL", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-3000:]}
        print(f"** measured {tag}: FAIL {rec['error']}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, tag + ".measured.json"), "w") as f:
            json.dump(rec, f, indent=2)
    return rec


def run_cell(arch_id: str, shape_name: str, multi_pod: bool, out_dir: str | None):
    ok, why = configs.cell_supported(arch_id, shape_name)
    mesh_name = "multi" if multi_pod else "single"
    tag = f"{arch_id}__{shape_name}__{mesh_name}"
    if not ok:
        rec = {"cell": tag, "status": "SKIP", "reason": why}
        print(json.dumps(rec))
        if out_dir:
            with open(os.path.join(out_dir, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=2)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        compiled, lowered, meta = lower_cell(arch_id, shape_name, mesh)
        stats = analyze(compiled, mesh)
        rec = {
            "cell": tag,
            "status": "OK",
            "compile_s": round(time.time() - t0, 1),
            "mesh": list(mesh.devices.shape),
            **stats,
        }
        mem = stats.get("memory_analysis") or {}
        print(f"== {tag}: OK in {rec['compile_s']}s")
        print(f"   memory_analysis: {mem}")
        print(
            f"   cost: flops/dev={stats['flops_per_device']:.3e} "
            f"bytes/dev={stats['bytes_accessed_per_device']:.3e}"
        )
        print(f"   collectives: {stats['collective_bytes_per_device']}")
    except Exception as e:
        rec = {
            "cell": tag,
            "status": "FAIL",
            "compile_s": round(time.time() - t0, 1),
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
        print(f"== {tag}: FAIL {rec['error']}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--measure", action="store_true",
                    help="depth-extrapolated cost measurement instead of full lowering")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = []
    archs = configs.names() if (args.all or args.arch is None) else [args.arch]
    shapes = list(configs.SHAPES) if (args.all or args.shape is None) else [args.shape]
    fails = 0
    for mp in meshes:
        for a in archs:
            for s in shapes:
                if args.measure:
                    rec = measure_cell(a, s, mp, args.out)
                    if rec is None:
                        continue
                else:
                    rec = run_cell(a, s, mp, args.out)
                cells.append(rec)
                fails += rec["status"] == "FAIL"
    print(f"\n{len(cells)} cells: "
          f"{sum(r['status']=='OK' for r in cells)} OK, "
          f"{sum(r['status']=='SKIP' for r in cells)} SKIP, {fails} FAIL")
    sys.exit(1 if fails else 0)


if __name__ == "__main__":
    main()
