"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Builds the (reduced or full) architecture, streams a synthetic corpus into
the distributed log, and runs the pjit training job on the local device
mesh with checkpoint/restart. On a real TPU pod slice this same entry
point runs under ``jax.distributed.initialize()`` with the production mesh
(``--mesh production``); on this CPU container use the default local mesh
and ``--reduced``.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as configs
import repro.core as core
import repro.data as data
from repro.data.formats import RawCodec
from repro.launch.mesh import make_production_mesh
from repro.models.model import StreamModel
from repro.models.policy import Policy
from repro.train import adamw, checkpoint as ck, cosine_schedule
from repro.train.trainer import build_train_step, make_state, state_pspecs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.names())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", choices=["local", "production", "production-multi"],
                    default="local")
    args = ap.parse_args()

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get(args.arch)
    if args.mesh == "local":
        n = jax.device_count()
        mesh = jax.make_mesh((n,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "production-multi")
    pol = Policy.for_mesh(mesh)
    model = StreamModel(cfg, pol, mesh)
    print(f"arch={cfg.name} params={cfg.param_count():,} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    # stream a synthetic corpus through the log (the paper's pipeline)
    log, registry = core.StreamLog(), core.Registry()
    spec = registry.register_model(args.arch)
    config = registry.create_configuration([spec.model_id])
    dep = registry.deploy(config.config_id, "train")
    rng = np.random.default_rng(0)
    corpus = rng.integers(0, cfg.vocab, (max(args.batch * 8, 64), args.seq)).astype(np.int32)
    codec = RawCodec("int32", (args.seq,), "int32", ())
    log.create_topic("corpus")
    msg = data.ingest(log, "corpus", codec,
                      {"data": corpus, "label": np.zeros(len(corpus), np.int32)},
                      dep.deployment_id)
    got, _ = core.poll_control(log, dep.deployment_id)
    train_arrays, _ = data.StreamDataset(log, got).split()

    opt = adamw(cosine_schedule(3e-4, 10, args.steps))
    step_fn, shardings = build_train_step(
        model, opt, mesh=mesh, microbatches=args.microbatches
    )
    with mesh:
        state = make_state(model, opt, jax.random.PRNGKey(0))
        if shardings is not None:
            state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings)
        start = 0
        mgr = ck.CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        if args.resume and mgr and mgr.latest() is not None:
            state, offsets, meta = ck.restore(args.ckpt_dir, state, shardings=shardings)
            start = int(meta.get("next_step", 0))
            print(f"resumed from step {start}")
        it = iter(data.BatchIterator(train_arrays, args.batch, seed=0, epochs=None))
        feeder = data.ShardedFeeder(mesh, pol.batch_axes or ("data",))
        for i in range(start, args.steps):
            host = next(it)
            batch = feeder.place({"tokens": host["data"]})
            state, metrics = step_fn(state, batch)
            if (i + 1) % 10 == 0 or i + 1 == args.steps:
                print(f"step {i+1}: loss {float(metrics['loss']):.4f}")
                if mgr:
                    mgr.save_async(i + 1, state,
                                   offsets={str(r): r.end for r in msg.ranges},
                                   meta={"next_step": i + 1})
        if mgr:
            mgr.wait()
    registry.upload_result(dep.deployment_id, spec.model_id,
                           {"loss": float(metrics["loss"])},
                           artifact_path=args.ckpt_dir)
    print("done; result registered")


if __name__ == "__main__":
    main()
