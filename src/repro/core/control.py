"""Control plane — Kafka-ML control topic, control messages, control logger.

Paper §III-D: the *data* topics carry only encoded tensors; a separate
*control* topic tells deployed training jobs **when and where** a training
stream is available. A control message carries::

    deployment_id    which deployed configuration the stream targets
    topic            data topic holding the stream
    input_format     RAW | AVRO
    input_config     codec configuration (dtype/shape or schemes)
    validation_rate  fraction of the stream reserved for evaluation
    total_msg        number of messages in the stream

plus (paper §V) the exact log coordinates of the stream as a list of
``[topic:partition:offset:length]`` ranges, so a stream already in the
distributed log can be *re-used* by any later deployment by resending only
this tens-of-bytes message.

The :class:`ControlLogger` mirrors the paper's control-logger component: it
consumes every control message and records it in the registry so that
(1) streams can be replayed to new deployments, and (2) inference
deployments auto-configure their input format from the training stream's
metadata (paper §IV-E).

The control plane accepts any :class:`~repro.core.log.StreamBackend`. On a
:class:`~repro.core.cluster.BrokerCluster` the control topic is created at
the cluster's default replication factor, so control messages — and with
them the §V stream-replay capability — survive broker loss alongside the
data they describe.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Any

from repro.core.log import OffsetOutOfRange, StreamBackend, TopicPartition

__all__ = [
    "CONTROL_TOPIC",
    "ControlLogger",
    "ControlMessage",
    "StreamRange",
]

CONTROL_TOPIC = "__kafka_ml_control"

_RANGE_RE = re.compile(r"^\[?([^:\[\]]+):(\d+):(\d+):(\d+)\]?$")


@dataclass(frozen=True)
class StreamRange:
    """``[topic:partition:offset:length]`` — the paper's §V coordinate format.

    Matches the TensorFlow/IO KafkaDataset connector string the paper uses,
    e.g. ``[kafka-ml:0:0:70000]`` = topic ``kafka-ml``, partition 0, offsets
    0..70000.
    """

    topic: str
    partition: int
    offset: int
    length: int

    def __str__(self) -> str:
        return f"[{self.topic}:{self.partition}:{self.offset}:{self.length}]"

    @property
    def tp(self) -> TopicPartition:
        return TopicPartition(self.topic, self.partition)

    @property
    def end(self) -> int:
        return self.offset + self.length

    @classmethod
    def parse(cls, s: str) -> "StreamRange":
        m = _RANGE_RE.match(s.strip())
        if not m:
            raise ValueError(f"bad stream range {s!r}; want [topic:partition:offset:length]")
        return cls(m.group(1), int(m.group(2)), int(m.group(3)), int(m.group(4)))


@dataclass
class ControlMessage:
    """One control-topic message (paper §III-D field list, verbatim)."""

    deployment_id: str
    topic: str
    input_format: str  # "RAW" | "AVRO"
    input_config: dict[str, Any]
    validation_rate: float
    total_msg: int
    ranges: list[StreamRange] = field(default_factory=list)

    def __post_init__(self) -> None:
        if not 0.0 <= self.validation_rate <= 1.0:
            raise ValueError(f"validation_rate {self.validation_rate} not in [0, 1]")
        if self.input_format not in ("RAW", "AVRO"):
            raise ValueError(f"unsupported input_format {self.input_format!r}")
        if self.ranges and sum(r.length for r in self.ranges) != self.total_msg:
            raise ValueError(
                f"total_msg={self.total_msg} != sum of range lengths "
                f"{sum(r.length for r in self.ranges)}"
            )

    # --------------------------------------------------------------- encoding
    def to_bytes(self) -> bytes:
        d = {
            "deployment_id": self.deployment_id,
            "topic": self.topic,
            "input_format": self.input_format,
            "input_config": self.input_config,
            "validation_rate": self.validation_rate,
            "total_msg": self.total_msg,
            "ranges": [str(r) for r in self.ranges],
        }
        return json.dumps(d, separators=(",", ":")).encode()

    @classmethod
    def from_bytes(cls, b: bytes | memoryview) -> "ControlMessage":
        d = json.loads(bytes(b).decode())
        return cls(
            deployment_id=d["deployment_id"],
            topic=d["topic"],
            input_format=d["input_format"],
            input_config=d["input_config"],
            validation_rate=float(d["validation_rate"]),
            total_msg=int(d["total_msg"]),
            ranges=[StreamRange.parse(r) for r in d.get("ranges", [])],
        )

    def retarget(self, deployment_id: str) -> "ControlMessage":
        """The §V reuse trick: same stream coordinates, new deployment."""
        return ControlMessage(
            deployment_id=deployment_id,
            topic=self.topic,
            input_format=self.input_format,
            input_config=self.input_config,
            validation_rate=self.validation_rate,
            total_msg=self.total_msg,
            ranges=list(self.ranges),
        )


def send_control(log: StreamBackend, msg: ControlMessage, producer=None) -> None:
    """Publish ``msg`` to the control topic.

    ``producer`` (an idempotent
    :class:`~repro.core.cluster.ClusterProducer`) makes the send
    exactly-once: a duplicated control message is not just log noise — a
    retry after a lost ack would re-announce the stream and re-trigger
    training on every job watching the deployment."""
    log.ensure_topic(CONTROL_TOPIC)
    if producer is not None:
        producer.send(
            CONTROL_TOPIC, msg.to_bytes(), key=msg.deployment_id.encode()
        )
    else:
        log.produce(CONTROL_TOPIC, msg.to_bytes(), key=msg.deployment_id.encode())


def poll_control(
    log: StreamBackend,
    deployment_id: str,
    from_offset: int = 0,
    isolation: str | None = None,
) -> tuple[ControlMessage | None, int]:
    """Scan the control topic for the first message targeting ``deployment_id``.

    Returns ``(msg_or_None, next_offset)`` — the training Job's
    ``readControlStreams`` loop from the paper's Algorithm 1.

    ``isolation="read_committed"`` hides control messages of uncommitted
    (or aborted) transactions — with a transactional ``ingest`` the
    stream announce becomes visible only once every record it names is
    durably committed, so a job can never train on a half-published
    stream.
    """
    log.ensure_topic(CONTROL_TOPIC)
    end = log.end_offset(CONTROL_TOPIC, 0)
    off = from_offset
    while off < end:
        batch = log.read(CONTROL_TOPIC, 0, off, 256, isolation=isolation)
        if not len(batch):
            if (batch.scanned or 0) == 0:
                # nothing visible: HW regression, or read_committed
                # blocked at the LSO by an open transaction
                break
            off = batch.next_offset  # marker-only span: skip past it
            continue
        for i, v in enumerate(batch.values):
            msg = ControlMessage.from_bytes(v)
            if msg.deployment_id == deployment_id:
                if batch.offsets is not None:
                    return msg, batch.offsets[i] + 1
                return msg, batch.first_offset + i + 1
        off = batch.next_offset
    return None, off


class ControlLogger:
    """Paper §IV-E: consumes control messages into the back-end registry.

    Keeps every control message ever seen so that (a) the Web-UI/API can
    list historical streams and replay them to new deployments, and (b)
    inference deployments inherit ``input_format``/``input_config`` from the
    stream their model was trained on.
    """

    def __init__(self, log: StreamBackend, isolation: str | None = None):
        self._log = log
        self._isolation = isolation
        self._next_offset = 0
        self._history: list[ControlMessage] = []

    def poll(self) -> list[ControlMessage]:
        self._log.ensure_topic(CONTROL_TOPIC)
        end = self._log.end_offset(CONTROL_TOPIC, 0)
        fresh: list[ControlMessage] = []
        while self._next_offset < end:
            batch = self._log.read(
                CONTROL_TOPIC, 0, self._next_offset, 256,
                isolation=self._isolation,
            )
            if not len(batch):
                if (batch.scanned or 0) == 0:
                    break  # HW regression or LSO-blocked open transaction
                self._next_offset = batch.next_offset
                continue
            fresh.extend(ControlMessage.from_bytes(v) for v in batch.values)
            self._next_offset = batch.next_offset
        self._history.extend(fresh)
        return fresh

    @property
    def history(self) -> list[ControlMessage]:
        self.poll()
        return list(self._history)

    def latest_for(self, deployment_id: str) -> ControlMessage | None:
        self.poll()
        for msg in reversed(self._history):
            if msg.deployment_id == deployment_id:
                return msg
        return None

    def _stream_committed(self, msg: ControlMessage) -> bool:
        """Whether every record ``msg`` names is visible at
        ``read_committed`` — i.e. the stream's ingest transaction (if
        any) durably committed.

        ``ingest`` emits only offset-contiguous ranges, so a range is
        committed iff exactly ``length`` records of ``[offset, end)``
        survive a read_committed scan: an aborted transaction's records
        are filtered out of such a read (count comes up short) and an
        *open* transaction blocks it at the LSO (no progress). Ranges
        that cannot be inspected at all (topic unknown to this backend,
        offsets already retention-expired) are skipped rather than
        failed: §V stream reuse is a metadata operation and replaying a
        coordinates-only announce predates this check — only a
        *provable* isolation violation vetoes the replay.
        """
        for r in msg.ranges:
            seen = 0
            off = r.offset
            while off < r.end:
                try:
                    batch = self._log.read(
                        r.topic, r.partition, off, r.end - off,
                        isolation="read_committed",
                    )
                except (KeyError, IndexError, OffsetOutOfRange):
                    seen = r.length  # uninspectable, not provably aborted
                    break
                if not len(batch) and (batch.scanned or 0) == 0:
                    return False  # LSO-blocked: transaction still open
                if batch.offsets is not None:
                    seen += sum(
                        1 for o in batch.offsets if r.offset <= o < r.end
                    )
                else:
                    seen += sum(
                        1 for i in range(len(batch))
                        if r.offset <= batch.first_offset + i < r.end
                    )
                if batch.next_offset <= off:
                    return False  # no progress: nothing visible here
                off = batch.next_offset
            if seen != r.length:
                return False  # aborted records were filtered out
        return True

    def replay(self, msg: ControlMessage, new_deployment_id: str) -> ControlMessage:
        """Re-send an historical stream to another deployment (§V, Fig. 8).

        Honors transactional isolation regardless of the logger's own
        isolation level: a logger polling at default isolation can hold
        an announce from an *aborted* transactional ingest in its
        history, and replaying it would hand a new deployment stream
        coordinates whose records no committed reader can see (a
        read_committed trainer hangs waiting for data that is filtered
        forever). Every range is therefore re-verified at
        ``read_committed`` before the announce is re-sent; replaying an
        aborted or still-open stream raises ``ValueError``.
        """
        if not self._stream_committed(msg):
            raise ValueError(
                f"cannot replay stream for deployment {msg.deployment_id!r}: "
                "its records are not fully visible at read_committed "
                "(aborted or still-open ingest transaction)"
            )
        retargeted = msg.retarget(new_deployment_id)
        send_control(self._log, retargeted)
        return retargeted
