"""Back-end registry — models, configurations, deployments, results.

Paper §IV-B: the back-end stores ML models, *configurations* (logical
groups of models trained from the **same single stream**, §III-B),
deployments, and — after training — the trained artifacts plus their
metrics, which can then be deployed for inference.

The registry is the single source of truth the other components talk to
(training jobs fetch their model from here and upload results here, the
control logger files stream metadata here, inference deployments pull
trained artifacts from here). State is in-memory with optional JSON+npz
persistence so a restarted control plane recovers.
"""

from __future__ import annotations

import itertools
import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping

import numpy as np

from repro.analysis.witness import make_rlock

__all__ = [
    "Configuration",
    "Deployment",
    "ModelSpec",
    "Registry",
    "TrainedResult",
]


@dataclass
class ModelSpec:
    """A registered model definition (paper §III-A).

    In Kafka-ML the user pastes TensorFlow/Keras source; here the
    definition is a named builder from :mod:`repro.configs` plus override
    kwargs — the JAX analogue of "only the model definition is needed".
    """

    model_id: str
    arch: str  # key into repro.configs registry (e.g. "qwen2-7b", "copd-mlp")
    overrides: dict[str, Any] = field(default_factory=dict)
    description: str = ""


@dataclass
class Configuration:
    """A logical set of models trained from one shared stream (§III-B)."""

    config_id: str
    model_ids: list[str]
    description: str = ""


@dataclass
class TrainedResult:
    """Uploaded by a training job on completion (paper Algorithm 1, last step)."""

    result_id: str
    deployment_id: str
    model_id: str
    metrics: dict[str, float]
    eval_metrics: dict[str, float]
    # control-message metadata captured during training; used to
    # auto-configure inference decode (paper §IV-E)
    input_format: str = "RAW"
    input_config: dict[str, Any] = field(default_factory=dict)
    artifact_path: str | None = None  # checkpoint on disk

    def params_available(self) -> bool:
        return self.artifact_path is not None and os.path.exists(self.artifact_path)


@dataclass
class Deployment:
    """One deployed configuration: training kwargs + lifecycle state."""

    deployment_id: str
    config_id: str
    kind: str  # "train" | "infer"
    training_kwargs: dict[str, Any] = field(default_factory=dict)
    status: str = "deployed"  # deployed -> running -> finished | failed
    replicas: int = 1
    input_topic: str | None = None
    output_topic: str | None = None
    result_ids: list[str] = field(default_factory=list)


class Registry:
    """Thread-safe in-memory store with JSON snapshot persistence."""

    def __init__(self, snapshot_dir: str | None = None):
        self._lock = make_rlock("registry")
        self._models: dict[str, ModelSpec] = {}
        self._configs: dict[str, Configuration] = {}
        self._deployments: dict[str, Deployment] = {}
        self._results: dict[str, TrainedResult] = {}
        self._counter = itertools.count(1)
        self.snapshot_dir = snapshot_dir
        if snapshot_dir:
            os.makedirs(snapshot_dir, exist_ok=True)
            self._maybe_load()

    def _next_id(self, prefix: str) -> str:
        return f"{prefix}-{next(self._counter)}"

    # ----------------------------------------------------------------- models
    def register_model(
        self, arch: str, overrides: Mapping[str, Any] | None = None, description: str = ""
    ) -> ModelSpec:
        with self._lock:
            spec = ModelSpec(
                model_id=self._next_id("model"),
                arch=arch,
                overrides=dict(overrides or {}),
                description=description,
            )
            self._models[spec.model_id] = spec
            self._snapshot()
            return spec

    def model(self, model_id: str) -> ModelSpec:
        with self._lock:
            return self._models[model_id]

    # ----------------------------------------------------------- configuration
    def create_configuration(self, model_ids: list[str], description: str = "") -> Configuration:
        with self._lock:
            missing = [m for m in model_ids if m not in self._models]
            if missing:
                raise KeyError(f"unknown model ids {missing}")
            cfg = Configuration(self._next_id("config"), list(model_ids), description)
            self._configs[cfg.config_id] = cfg
            self._snapshot()
            return cfg

    def configuration(self, config_id: str) -> Configuration:
        with self._lock:
            return self._configs[config_id]

    # -------------------------------------------------------------- deployment
    def deploy(
        self,
        config_id: str,
        kind: str = "train",
        *,
        training_kwargs: Mapping[str, Any] | None = None,
        replicas: int = 1,
        input_topic: str | None = None,
        output_topic: str | None = None,
    ) -> Deployment:
        with self._lock:
            if config_id not in self._configs:
                raise KeyError(f"unknown configuration {config_id}")
            dep = Deployment(
                deployment_id=self._next_id("deploy"),
                config_id=config_id,
                kind=kind,
                training_kwargs=dict(training_kwargs or {}),
                replicas=replicas,
                input_topic=input_topic,
                output_topic=output_topic,
            )
            self._deployments[dep.deployment_id] = dep
            self._snapshot()
            return dep

    def deployment(self, deployment_id: str) -> Deployment:
        with self._lock:
            return self._deployments[deployment_id]

    def set_status(self, deployment_id: str, status: str) -> None:
        with self._lock:
            self._deployments[deployment_id].status = status
            self._snapshot()

    # ----------------------------------------------------------------- results
    def upload_result(
        self,
        deployment_id: str,
        model_id: str,
        metrics: Mapping[str, float],
        eval_metrics: Mapping[str, float] | None = None,
        *,
        input_format: str = "RAW",
        input_config: Mapping[str, Any] | None = None,
        artifact_path: str | None = None,
    ) -> TrainedResult:
        with self._lock:
            res = TrainedResult(
                result_id=self._next_id("result"),
                deployment_id=deployment_id,
                model_id=model_id,
                metrics=dict(metrics),
                eval_metrics=dict(eval_metrics or {}),
                input_format=input_format,
                input_config=dict(input_config or {}),
                artifact_path=artifact_path,
            )
            self._results[res.result_id] = res
            dep = self._deployments.get(deployment_id)
            if dep is not None:
                dep.result_ids.append(res.result_id)
            self._snapshot()
            return res

    def result(self, result_id: str) -> TrainedResult:
        with self._lock:
            return self._results[result_id]

    def results_for(self, deployment_id: str) -> list[TrainedResult]:
        with self._lock:
            return [r for r in self._results.values() if r.deployment_id == deployment_id]

    def compare(self, deployment_id: str, metric: str = "loss") -> list[tuple[str, float]]:
        """Rank a configuration's models by a metric (the Web-UI compare view)."""
        rows = [
            (r.model_id, r.eval_metrics.get(metric, r.metrics.get(metric, float("nan"))))
            for r in self.results_for(deployment_id)
        ]
        return sorted(rows, key=lambda x: x[1])

    # ------------------------------------------------------------- persistence
    def _snapshot(self) -> None:
        if not self.snapshot_dir:
            return
        state = {
            "models": {k: vars(v) for k, v in self._models.items()},
            "configs": {k: vars(v) for k, v in self._configs.items()},
            "deployments": {k: vars(v) for k, v in self._deployments.items()},
            "results": {k: vars(v) for k, v in self._results.items()},
        }
        path = os.path.join(self.snapshot_dir, "registry.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(state, f, default=str)
        os.replace(tmp, path)  # atomic: a crash mid-write never corrupts

    def _maybe_load(self) -> None:
        path = os.path.join(self.snapshot_dir, "registry.json")
        if not os.path.exists(path):
            return
        with open(path) as f:
            state = json.load(f)
        self._models = {k: ModelSpec(**v) for k, v in state["models"].items()}
        self._configs = {k: Configuration(**v) for k, v in state["configs"].items()}
        self._deployments = {k: Deployment(**v) for k, v in state["deployments"].items()}
        self._results = {k: TrainedResult(**v) for k, v in state["results"].items()}
        # resume id counter past anything loaded
        mx = 0
        for pool in (self._models, self._configs, self._deployments, self._results):
            for key in pool:
                try:
                    mx = max(mx, int(key.rsplit("-", 1)[1]))
                except (IndexError, ValueError):
                    pass
        self._counter = itertools.count(mx + 1)
