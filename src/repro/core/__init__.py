"""Core: the paper's contribution — stream-driven ML pipeline management."""
from repro.core.cluster import (
    METRICS_TOPIC,
    Broker,
    BrokerCluster,
    BrokerUnavailable,
    ClusterConsumer,
    ClusterError,
    ClusterProducer,
    InvalidTxnState,
    MetricsReporter,
    NotEnoughReplicasError,
    NotLeaderError,
    PartitionMeta,
    PartitionOffline,
    ReplicationService,
)
from repro.core.controller import (
    ControllerNode,
    ControllerUnavailable,
    LogEntry,
    MetadataCommand,
    QuorumController,
)
from repro.core.control import (
    CONTROL_TOPIC,
    ControlLogger,
    ControlMessage,
    StreamRange,
    poll_control,
    send_control,
)
from repro.core.consumer import (
    ConsumerGroup,
    GroupConsumer,
    RebalanceError,
    range_assign,
)
from repro.core.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Span,
    series_key,
)
from repro.core.log import (
    METADATA_TOPIC,
    LogConfig,
    OffsetOutOfRange,
    OutOfOrderSequence,
    ProducerFenced,
    Record,
    RecordBatch,
    StreamBackend,
    StreamLog,
    TopicPartition,
)
from repro.core.registry import (
    Configuration,
    Deployment,
    ModelSpec,
    Registry,
    TrainedResult,
)
from repro.core.supervisor import JobOutcome, Supervisor
