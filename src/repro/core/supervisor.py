"""Back-end supervisor — the paper's Kubernetes-facing control loop (§IV-B).

In Kafka-ML the back-end asks Kubernetes to run one training Job per model
of a deployed configuration and relies on the orchestrator to restart
failures. This supervisor is that loop, JAX-side: it watches the registry
for `deployed` training deployments, spawns a TrainingJob per model,
restarts crashed jobs from their offset-coupled checkpoints (bounded
retries), and marks deployment status through
``deployed -> running -> finished | failed``.

Jobs run in-process (sequentially or via a thread pool); on a real cluster
each job maps to one pod-slice process group — the lifecycle/restart logic
is identical.
"""

from __future__ import annotations

import dataclasses
import os
import traceback
from typing import Any, Callable

from repro.core.log import StreamBackend
from repro.core.registry import Registry

__all__ = ["JobOutcome", "Supervisor"]


@dataclasses.dataclass
class JobOutcome:
    deployment_id: str
    model_id: str
    attempts: int
    ok: bool
    error: str | None = None


class Supervisor:
    """Deploy-loop for training jobs with bounded restart.

    ``job_factory(deployment, model_spec, ckpt_dir)`` must return an object
    with ``run(batch_size=..., resume=..., **kwargs) -> TrainResult`` —
    normally :class:`repro.train.trainer.TrainingJob`.
    """

    def __init__(
        self,
        log: StreamBackend,
        registry: Registry,
        job_factory: Callable[..., Any],
        *,
        ckpt_root: str,
        max_restarts: int = 2,
    ):
        self.log = log
        self.registry = registry
        self.job_factory = job_factory
        self.ckpt_root = ckpt_root
        self.max_restarts = max_restarts
        self.outcomes: list[JobOutcome] = []

    # ------------------------------------------------------------------ loop
    def pending_deployments(self) -> list[str]:
        return [
            d.deployment_id
            for d in self.registry._deployments.values()  # read-only scan
            if d.kind == "train" and d.status == "deployed"
        ]

    def reconcile(self, **run_kwargs) -> list[JobOutcome]:
        """One pass: run every pending training deployment to completion,
        restarting crashed jobs from their checkpoints."""
        new: list[JobOutcome] = []
        for dep_id in self.pending_deployments():
            dep = self.registry.deployment(dep_id)
            cfg = self.registry.configuration(dep.config_id)
            self.registry.set_status(dep_id, "running")
            all_ok = True
            for model_id in cfg.model_ids:
                outcome = self._run_one(dep_id, model_id, run_kwargs)
                new.append(outcome)
                all_ok &= outcome.ok
            self.registry.set_status(dep_id, "finished" if all_ok else "failed")
        self.outcomes.extend(new)
        return new

    def _run_one(self, dep_id: str, model_id: str, run_kwargs) -> JobOutcome:
        ckpt_dir = os.path.join(self.ckpt_root, f"{dep_id}__{model_id}")
        spec = self.registry.model(model_id)
        dep = self.registry.deployment(dep_id)
        attempts = 0
        last_err: str | None = None
        while attempts <= self.max_restarts:
            attempts += 1
            job = self.job_factory(dep, spec, ckpt_dir)
            try:
                job.run(resume=attempts > 1, **{**dep.training_kwargs, **run_kwargs})
                return JobOutcome(dep_id, model_id, attempts, True)
            except Exception as e:  # noqa: BLE001 — the orchestrator catches all
                last_err = f"{type(e).__name__}: {e}"
                traceback.format_exc()
        return JobOutcome(dep_id, model_id, attempts, False, last_err)
