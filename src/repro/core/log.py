"""Distributed log — the Kafka-ML data substrate, JAX-host-native.

Implements the semantics Kafka-ML relies on (paper §II, §V):

* topics split into **partitions**; each partition is an append-only log of
  records addressed by a monotonically increasing **offset**;
* records are retained after consumption (the *distributed log*), so
  consumers can re-read ranges — this is what lets Kafka-ML replay a
  training stream to a new deployment with a tens-of-bytes control message
  instead of re-sending the data;
* **retention policies**: ``delete`` with ``retention_bytes`` /
  ``retention_ms`` (paper §V lists exactly these two knobs) and, since
  storage engine v2 (DESIGN.md §11), ``compact`` for keyed topics — a
  cleaner rewrites sealed segments keeping the latest record per key
  (tombstones are empty-valued keyed records, removed after a grace
  window), while surviving records keep their original offsets;
* **per-segment sparse indexes**: offset/timestamp index entries every
  ``index_interval_bytes`` (``offset_for_timestamp`` lookups) and an
  aborted-transaction index (Kafka's ``.txnindex``) so read_committed's
  abort prefilter touches only the segments a read actually spans;
* **state snapshots**: each partition snapshots its producer/transaction
  state at segment rolls and compaction horizons, so post-truncation
  rebuilds restore the newest snapshot at or below the truncation point
  and replay only the suffix — byte-identical to a full replay, and the
  only correct rebuild on a compacted log (cleaned records no longer
  replay);
* message-set (batched) appends amortize per-record overhead — the paper's
  "message set abstraction";
* zero-copy reads: records are returned as memoryviews into segment
  buffers ("zero-copy optimizations" in paper §II);
* **idempotent producers** (exactly-once across client retries): each
  partition keeps a producer-state table (pid → epoch, last sequence,
  recent batch runs) derived from (pid, epoch, seq) stamps embedded in
  the records themselves, so ``producer_append`` resolves a retried
  batch to its *original* offsets instead of re-appending, the table
  replicates with the records, and it is rebuilt from the retained log
  after truncation (see DESIGN.md §7);
* **transactions** (DESIGN.md §8): transactional records carry a txn
  flag next to their producer stamp, and COMMIT/ABORT **control
  records** (markers) written by the transaction coordinator resolve
  them. Each partition tracks its open transactions (pid → first
  offset) and its aborted ranges — both, like producer state, derived
  purely from the records in the log, so replicas and post-truncation
  rebuilds agree. ``last_stable_offset`` (LSO) is the first offset of
  the earliest still-open transaction; ``read(...,
  isolation="read_committed")`` caps at the LSO and filters out
  markers and aborted records.

The log is an in-process, host-memory structure (segments are bytearrays)
with optional disk spill. On a TPU pod the broker is colocated with the
host, so a network hop becomes a RAM hop; every *semantic* (offsets,
retention, replay, consumer groups) is preserved — see DESIGN.md §2.
"""

from __future__ import annotations

import bisect
import itertools
import os
import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Callable, Iterator, Protocol, Sequence

import numpy as np

from repro.analysis.witness import make_rlock

__all__ = [
    "METADATA_TOPIC",
    "LogConfig",
    "OffsetOutOfRange",
    "OutOfOrderSequence",
    "ProducerFenced",
    "Record",
    "RecordBatch",
    "StreamBackend",
    "StreamLog",
    "TopicPartition",
]

# The cluster-metadata topic (KRaft's ``@metadata``): each controller
# node's replicated metadata log is an ordinary StreamLog topic of this
# name — offsets are Raft log indexes and ``truncate_to`` is Raft's
# conflict-suffix truncation. See repro.core.controller.
METADATA_TOPIC = "__cluster_metadata"


class OffsetOutOfRange(LookupError):
    """Requested offset is below the log start (evicted) or past the end."""


class ProducerFenced(RuntimeError):
    """An idempotent append carried a producer epoch older than the one the
    partition (or cluster) has seen — a *zombie*: a prior incarnation of a
    producer whose id was re-initialized with a bumped epoch. Fatal to the
    producer instance (Kafka's PRODUCER_FENCED); deliberately NOT a
    ``ClusterError`` subclass, so client retry loops never re-send a fenced
    batch."""


class OutOfOrderSequence(RuntimeError):
    """An idempotent append's sequence number is neither the next expected
    one, a retry resolvable inside the dedup window, nor a fresh epoch —
    either a gap (records lost between producer and broker) or a duplicate
    too old for the bounded window (Kafka's OUT_OF_ORDER_SEQUENCE_NUMBER /
    DUPLICATE_SEQUENCE_NUMBER). Fatal: acking it could hide loss or
    re-append data."""


# Per-producer dedup window: how many distinct (non-mergeable) batch runs
# each partition remembers per producer id. A synchronous producer has one
# batch in flight, so its retry always hits the newest run; 8 leaves slack
# for pipelined producers (Kafka keeps 5 batch metadata entries).
_MAX_PRODUCER_RUNS = 8

# Producer-state snapshots retained per partition (beyond the pinned
# snapshot at the compaction point, which is load-bearing and never
# evicted — see _Partition._trim_snapshots).
_MAX_PRODUCER_SNAPSHOTS = 8

# Per-record control/transaction flag values (the ``ctrls`` arrays):
# 0 = plain record, 1 = transactional data record, 2 = COMMIT marker,
# 3 = ABORT marker. Markers are control records: they occupy offsets and
# replicate like data, but consumers never see them.
CTRL_NONE = 0
CTRL_TXN_DATA = 1
CTRL_COMMIT = 2
CTRL_ABORT = 3

# marker payloads (self-describing; never delivered to consumers)
_COMMIT_MARKER = b"\x00txn:commit"
_ABORT_MARKER = b"\x00txn:abort"


class _ProducerState:
    """Dedup state for one producer id on one partition.

    ``runs`` is a bounded list of ``[first_seq, last_seq, first_offset]``
    spans that are contiguous in *both* sequence and offset, so a retried
    batch fully inside a run maps back to its original offsets by
    arithmetic (``first_offset + (seq - first_seq)``). Because runs are
    derived purely from the records in the log (in log order), a leader
    and its followers — and a truncated log after a rebuild — always agree
    on the same table without shipping snapshots.
    """

    __slots__ = ("epoch", "last_seq", "runs", "last_ts")

    def __init__(self, epoch: int):
        self.epoch = epoch
        self.last_seq = -1
        self.runs: list[list[int]] = []
        # newest record timestamp this pid appended — the retention-clock
        # expiry key (record timestamps replicate verbatim, so every
        # replica ages the same pid out at the same stream time)
        self.last_ts = 0

    def note(
        self, first_seq: int, last_seq: int, first_offset: int, ts: int = 0
    ) -> None:
        """Record an appended span (contiguous in seq and offset)."""
        if ts > self.last_ts:
            self.last_ts = ts
        if self.runs:
            r = self.runs[-1]
            if (
                first_seq == r[1] + 1
                and first_offset == r[2] + (r[1] - r[0]) + 1
            ):
                r[1] = last_seq  # extends the newest run
                self.last_seq = max(self.last_seq, last_seq)
                return
        self.runs.append([first_seq, last_seq, first_offset])
        del self.runs[:-_MAX_PRODUCER_RUNS]
        self.last_seq = max(self.last_seq, last_seq)

    def find(self, seq: int, n: int) -> tuple[int, int] | None:
        """Original (first, last) offsets of a retried batch ``[seq,
        seq+n)``, or None if it is not fully inside a cached run."""
        for r in reversed(self.runs):
            if r[0] <= seq and seq + n - 1 <= r[1]:
                first = r[2] + (seq - r[0])
                return first, first + n - 1
        return None

    def clone(self) -> "_ProducerState":
        """Deep copy for producer-state snapshots (runs are mutable)."""
        c = _ProducerState(self.epoch)
        c.last_seq = self.last_seq
        c.last_ts = self.last_ts
        c.runs = [list(r) for r in self.runs]
        return c


def default_partition(
    keys: Sequence[bytes | None] | None, nparts: int, now_ms: int
) -> int:
    """Default partitioner shared by every backend: key-hash when the batch
    is keyed, else a time-slot (sticky round-robin-ish). Keeping one
    implementation means a key maps to the same partition on a bare
    StreamLog and on a BrokerCluster.

    The key hash is CRC32, not Python's ``hash()``: ``hash(bytes)`` is
    salted per process (PYTHONHASHSEED), so the same key would land on
    different partitions across producer processes and restarts. A stable
    hash is what makes key→partition routing a durable contract (Kafka
    uses murmur2 for the same reason).
    """
    if keys is not None and keys and keys[0] is not None:
        return zlib.crc32(bytes(keys[0])) % nparts
    return now_ms % nparts


@dataclass(frozen=True)
class TopicPartition:
    """Identifies one partition of one topic (Kafka's TopicPartition)."""

    topic: str
    partition: int

    def __str__(self) -> str:  # [topic:partition] per the paper's format
        return f"{self.topic}:{self.partition}"


@dataclass(frozen=True)
class Record:
    """One record as seen by a consumer."""

    topic: str
    partition: int
    offset: int
    value: memoryview  # zero-copy view into the segment buffer
    key: bytes | None
    timestamp_ms: int

    def value_bytes(self) -> bytes:
        return bytes(self.value)


@dataclass
class LogConfig:
    """Per-topic configuration (mirrors Kafka topic configs)."""

    num_partitions: int = 1
    # delete-retention knobs (paper §V): None ⇒ not applicable
    retention_bytes: int | None = None
    retention_ms: int | None = None
    segment_bytes: int = 8 * 1024 * 1024  # roll segments at this size
    # cleanup policy: "delete" evicts whole head segments by size/age;
    # "compact" (keyed topics, DESIGN.md §11) rewrites sealed segments
    # keeping the latest record per key — offsets stay stable, reads skip
    # the holes. Size/age eviction is disabled under compact.
    cleanup: str = "delete"
    # compact only: how long a tombstone (empty value, non-None key)
    # survives after it becomes the latest record for its key, measured
    # in *stream time* (the max retained record timestamp below the
    # compaction horizon) so every replica cleans identically
    tombstone_retention_ms: int = 24 * 60 * 60 * 1000
    # compact only: dirty (newly appended) bytes that trigger the inline
    # cleaner on a bare log; None ⇒ one segment's worth
    min_cleanable_bytes: int | None = None
    # sparse index granularity: one offset/time index entry per this many
    # payload bytes in a segment (Kafka's index.interval.bytes)
    index_interval_bytes: int = 4096
    # replication: honored by repro.core.cluster.BrokerCluster; a bare
    # single-host StreamLog keeps these as bookkeeping only. None means
    # "backend default" (1 on a bare log; the cluster's configured defaults
    # on a BrokerCluster) — so a config written for partitioning/retention
    # never silently opts a cluster topic out of replication.
    replication_factor: int | None = None
    min_insync_replicas: int | None = None  # acks=all needs this many in ISR
    # disk spill: sealed (rolled) segments move their payload to an
    # mmap-backed file under spill_dir; reads stay zero-copy (memoryview
    # over the map). Host RAM then holds only the active segment + indexes.
    spill_dir: str | None = None


class _Segment:
    """A contiguous chunk of the partition log.

    Layout: one shared ``bytearray`` holding concatenated record payloads;
    numpy index arrays map relative record index -> (start, length, key
    range, timestamp). Batched appends write once into the buffer.
    """

    __slots__ = (
        "base_offset",
        "buf",
        "buf_len",
        "key_buf",
        "starts",
        "lengths",
        "key_starts",
        "key_lengths",
        "timestamps",
        "pids",
        "peps",
        "pseqs",
        "ctrls",
        "markers",
        "count",
        "created_ms",
        "_spill_file",
        "logical_bytes",
        "offsets",
        "index_every",
        "index_offsets",
        "index_times",
        "_index_next",
        "max_ts",
        "txn_index",
    )

    def __init__(
        self, base_offset: int, created_ms: int, index_every: int = 4096
    ):
        self.base_offset = base_offset
        # the payload buffer over-allocates (doubling growth) and tracks the
        # written prefix in buf_len: appends are a single in-place slice
        # assignment instead of a resize, so a hot 8 MiB segment doesn't
        # re-memcpy itself every few batches (bytearray's native growth
        # factor is ~1.125x) and appends can't hit BufferError from a
        # consumer's outstanding zero-copy view (equal-length slice writes
        # never resize an exported buffer)
        self.buf = bytearray()
        self.buf_len = 0
        self.key_buf = bytearray()
        # python lists while hot; frozen to numpy on roll
        self.starts: list[int] = []
        self.lengths: list[int] = []
        self.key_starts: list[int] = []
        self.key_lengths: list[int] = []
        self.timestamps: list[int] = []
        # per-record producer metadata (pid < 0 ⇒ non-idempotent record):
        # batches carry their (pid, epoch, seq) into the log itself, so a
        # replica — or a rebuild after truncation — derives exactly the
        # same producer-state table the leader built incrementally.
        # Lazily allocated (None until the segment's first stamped
        # record, backfilled with sentinels then), so purely
        # non-idempotent partitions pay nothing per record.
        self.pids: list[int] | None = None
        self.peps: list[int] | None = None
        self.pseqs: list[int] | None = None
        # per-record control/transaction flags (CTRL_*), lazily allocated
        # like the producer metadata: None until the segment holds its
        # first transactional or marker record. ``markers`` counts the
        # control markers among them, so reads of marker-free spans keep
        # the contiguous fast path even on fully-transactional topics
        # (whose every record carries a ctrl flag).
        self.ctrls: list[int] | None = None
        self.markers = 0
        self.count = 0
        self.created_ms = created_ms
        self._spill_file = None
        # retained payload bytes when the physical buffers can't shrink
        # (truncation inside a sealed mmap-backed segment); None = physical
        self.logical_bytes: int | None = None
        # per-record logical offsets; None ⇒ contiguous from base_offset.
        # Materialized the first time a compaction rewrite (or a replica
        # fetch of compacted records) leaves holes in the offset sequence.
        self.offsets: list[int] | None = None
        # sparse offset/time index (DESIGN.md §11): one entry per
        # ~index_every payload bytes. index_offsets holds (rel_record,
        # byte_pos); index_times holds (timestamp_ms, rel_record), kept
        # non-decreasing in timestamp (out-of-order stamps are skipped,
        # Kafka's .timeindex rule).
        self.index_every = index_every
        self.index_offsets: list[tuple[int, int]] = []
        self.index_times: list[tuple[int, int]] = []
        self._index_next = index_every
        self.max_ts = 0  # newest record timestamp (segment-skip key)
        # aborted-transaction index (Kafka's .txnindex): (pid, first,
        # marker) ranges overlapping this segment, stamped when an ABORT
        # marker lands — read_committed's prefilter consults only the
        # segments a read spans instead of the partition-wide abort list
        self.txn_index: list[tuple[int, int, int]] = []

    @property
    def size_bytes(self) -> int:
        if self.logical_bytes is not None:
            return self.logical_bytes
        return self.buf_len + len(self.key_buf)

    @property
    def last_offset(self) -> int:
        if self.offsets:
            return self.offsets[-1]
        return self.base_offset + self.count - 1

    @property
    def next_offset(self) -> int:
        return self.last_offset + 1

    def off(self, rel: int) -> int:
        """Logical offset of relative record ``rel``."""
        if self.offsets is not None:
            return self.offsets[rel]
        return self.base_offset + rel

    def rel_range(self, lo_off: int, hi_off: int) -> tuple[int, int]:
        """Relative record window covering logical offsets
        ``[lo_off, hi_off)`` — bisect on the offsets array when the
        segment has holes, arithmetic when it is contiguous."""
        if self.offsets is None:
            lo = max(lo_off - self.base_offset, 0)
            hi = max(min(hi_off - self.base_offset, self.count), lo)
            return lo, hi
        lo = bisect.bisect_left(self.offsets, lo_off)
        hi = bisect.bisect_left(self.offsets, hi_off)
        return lo, hi

    def append_batch(
        self,
        values: Sequence[bytes | bytearray | memoryview],
        keys: Sequence[bytes | None] | None,
        timestamp_ms: int | Sequence[int],
        prods: tuple[Sequence[int], Sequence[int], Sequence[int]] | None = None,
        offsets: Sequence[int] | None = None,
    ) -> None:
        """Append one message set in bulk: one ``join`` into the shared
        buffer plus list extends, instead of a per-record Python loop —
        the hot path of every produce and every replica push.

        ``prods`` is per-record producer metadata ``(pids, epochs, seqs)``
        (parallel sequences); None extends the non-idempotent sentinel.
        ``offsets`` assigns explicit (ascending) logical offsets — the
        compaction rewrite / gapped-replica-fetch path; a contiguous run
        starting at the segment's next offset stays on the dense layout."""
        n = len(values)
        if n == 0:
            return
        if offsets is not None:
            if (
                self.offsets is None
                and offsets[0] == self.next_offset
                and offsets[-1] - offsets[0] + 1 == n
            ):
                offsets = None  # contiguous continuation: stay dense
            elif self.offsets is None:
                # first hole: materialize the dense prefix
                self.offsets = list(
                    range(self.base_offset, self.base_offset + self.count)
                )
        if self.offsets is not None:
            if offsets is None:
                start = self.next_offset
                self.offsets.extend(range(start, start + n))
            else:
                self.offsets.extend(offsets)
        pos = self.buf_len
        lens = list(map(len, values))
        starts = list(itertools.accumulate(lens, initial=pos))
        end = starts.pop()  # accumulate also yields the end position
        if end > len(self.buf):
            # preallocate with doubling growth (O(log) total re-copies)
            grow = bytes(max(end - len(self.buf), len(self.buf)))
            try:
                self.buf += grow
            except BufferError:
                # a consumer's zero-copy view pins the current buffer:
                # rebuild instead of resizing (old views stay valid on the
                # old buffer; appends continue on the new one)
                self.buf = self.buf[:] + grow
        self.buf[pos:end] = b"".join(values)
        self.buf_len = end
        self.starts.extend(starts)
        self.lengths.extend(lens)
        kpos = len(self.key_buf)
        if keys is None:
            self.key_starts.extend([kpos] * n)
            self.key_lengths.extend([-1] * n)
        else:
            for k in keys:
                if k is None:
                    self.key_starts.append(kpos)
                    self.key_lengths.append(-1)
                else:
                    self.key_starts.append(kpos)
                    self.key_lengths.append(len(k))
                    self.key_buf += k
                    kpos += len(k)
        if isinstance(timestamp_ms, int):
            self.timestamps.extend([timestamp_ms] * n)
            if timestamp_ms > self.max_ts:
                self.max_ts = timestamp_ms
        else:
            self.timestamps.extend(timestamp_ms)
            m = max(timestamp_ms)
            if m > self.max_ts:
                self.max_ts = m
        # sparse offset/time index entries: one per ~index_every payload
        # bytes. Amortized — between crossings there is zero per-record
        # work, and a crossing costs one bisect per entry, not a scan.
        if starts and starts[-1] >= self._index_next:
            ts_all = self.timestamps
            while self._index_next <= starts[-1]:
                i = bisect.bisect_left(starts, self._index_next)
                rel = self.count + i
                self.index_offsets.append((rel, starts[i]))
                t = ts_all[rel]
                if not self.index_times or t >= self.index_times[-1][0]:
                    self.index_times.append((t, rel))
                self._index_next = starts[i] + self.index_every
        ctrls = prods[3] if prods is not None and len(prods) > 3 else None
        if prods is not None:
            if self.pids is None:
                # first stamped record: backfill the unstamped prefix
                self.pids = [-1] * self.count
                self.peps = [-1] * self.count
                self.pseqs = [-1] * self.count
            self.pids.extend(prods[0])
            self.peps.extend(prods[1])
            self.pseqs.extend(prods[2])
        elif self.pids is not None:
            self.pids.extend(itertools.repeat(-1, n))
            self.peps.extend(itertools.repeat(-1, n))
            self.pseqs.extend(itertools.repeat(-1, n))
        if ctrls is not None and (self.ctrls is not None or any(ctrls)):
            if self.ctrls is None:
                self.ctrls = [CTRL_NONE] * self.count
            self.ctrls.extend(ctrls)
            self.markers += sum(1 for x in ctrls if x >= CTRL_COMMIT)
        elif self.ctrls is not None:
            self.ctrls.extend(itertools.repeat(CTRL_NONE, n))
        self.count += n

    def record(self, topic: str, partition: int, rel: int) -> Record:
        start = self.starts[rel]
        length = self.lengths[rel]
        klen = self.key_lengths[rel]
        key = (
            None
            if klen < 0
            else bytes(self.key_buf[self.key_starts[rel] : self.key_starts[rel] + klen])
        )
        return Record(
            topic=topic,
            partition=partition,
            offset=self.off(rel),
            value=memoryview(self.buf)[start : start + length],
            key=key,
            timestamp_ms=self.timestamps[rel],
        )

    def spill(self, path: str) -> None:
        """Seal this segment's payload to an mmap-backed file (zero-copy
        reads continue through the map); frees the heap buffer."""
        import mmap

        with open(path, "wb") as f:
            f.write(bytes(memoryview(self.buf)[: self.buf_len]))
            f.flush()
        if self.buf_len == 0:
            return
        fh = open(path, "rb")
        mm = mmap.mmap(fh.fileno(), 0, access=mmap.ACCESS_READ)
        self.buf = mm  # memoryview(mmap) slices stay zero-copy
        self._spill_file = (fh, path)

    def drop_spill(self) -> None:
        sp = getattr(self, "_spill_file", None)
        if sp is not None:
            fh, path = sp
            try:
                self.buf.close() if hasattr(self.buf, "close") else None
            except BufferError:
                pass  # outstanding zero-copy views keep the map alive
            try:
                fh.close()
                os.unlink(path)
            except OSError:
                pass


@dataclass
class RecordBatch:
    """A batch of records read from one partition — supports vectorized decode.

    ``values`` are zero-copy memoryviews; ``to_matrix`` stacks fixed-size
    payloads into a single (n, record_bytes) uint8 array in one pass, the
    fast path used by the training data pipeline.
    """

    topic: str
    partition: int
    first_offset: int
    values: list[memoryview]
    timestamps: list[int]
    # read_committed reads skip control markers and aborted records, so
    # the delivered records may be non-contiguous: ``offsets`` then holds
    # each record's true offset and ``scanned`` how many raw offsets the
    # read consumed (next_offset = first_offset + scanned, so a poll
    # advances past a marker-only span instead of re-reading it forever).
    # Both stay None on the contiguous (raw) read path.
    offsets: list[int] | None = None
    scanned: int | None = None
    # zero-copy framing (DESIGN.md §10): records of one segment are always
    # tightly packed, so the contiguous read path also hands out one
    # ``(payload_view, record_count)`` memoryview per segment span covering
    # the delivered records back to back. Fixed-layout decoders
    # (repro.data.formats) turn a span directly into per-field strided
    # ndarray views — no per-record Python, no copy. None on filtered
    # (marker/aborted-skipping) reads, where delivery is non-contiguous.
    spans: list[tuple[memoryview, int]] | None = None

    def __len__(self) -> int:
        return len(self.values)

    @property
    def next_offset(self) -> int:
        if self.scanned is not None:
            return self.first_offset + self.scanned
        return self.first_offset + len(self.values)

    def framed(self, record_bytes: int) -> list[tuple[memoryview, int]] | None:
        """The batch's contiguous spans, validated for fixed-layout decode
        at ``record_bytes`` per record: every delivered record accounted
        for, every span exactly ``count * record_bytes`` long. None when
        the batch came off a filtered read (no spans) or the records are
        not the expected fixed size — callers then fall back to the
        copying :meth:`to_matrix` path."""
        if self.spans is None or record_bytes <= 0:
            return None
        if sum(n for _, n in self.spans) != len(self.values):
            return None
        for mv, n in self.spans:
            if mv.nbytes != n * record_bytes:
                return None
        return self.spans

    def to_matrix(self) -> np.ndarray:
        if not self.values:
            return np.zeros((0, 0), dtype=np.uint8)
        n = len(self.values[0])
        if any(len(v) != n for v in self.values):
            raise ValueError("to_matrix requires fixed-size records")
        spans = self.framed(n)
        if spans is not None:
            # contiguous fixed-size records: bulk row-block copies (one
            # per segment span) instead of a per-record loop
            out = np.empty((len(self.values), n), dtype=np.uint8)
            row = 0
            for mv, cnt in spans:
                out[row : row + cnt] = np.frombuffer(mv, np.uint8).reshape(cnt, n)
                row += cnt
            return out
        out = np.empty((len(self.values), n), dtype=np.uint8)
        for i, v in enumerate(self.values):
            out[i] = np.frombuffer(v, dtype=np.uint8)
        return out


class _Partition:
    def __init__(self, topic: str, index: int, cfg: LogConfig, clock: Callable[[], int],
                 lock_class: str = "log-part"):
        self.topic = topic
        self.index = index
        self.cfg = cfg
        self.clock = clock
        self.segments: list[_Segment] = [
            _Segment(0, clock(), index_every=cfg.index_interval_bytes)
        ]
        self.log_start_offset = 0  # first retained offset
        # pid -> dedup state; derived purely from the records in the log
        # (their embedded (pid, epoch, seq) metadata), kept incrementally
        # on every append and rebuilt from the retained log after
        # truncation — so leader, followers and a reconciled rejoiner all
        # hold the same table. The window is additionally bounded by
        # retention: a pid whose records were all evicted starts fresh
        # (Kafka's producer-id expiry).
        self.producers: dict[int, _ProducerState] = {}
        # transaction state, derived purely from the records (txn flags +
        # control markers), exactly like the producer table above:
        #   txn_open: pid -> (first offset of its open txn, producer epoch)
        #   aborted:  [(pid, first_offset, marker_offset), ...] — records
        #             of `pid` in [first, marker) belong to an aborted
        #             transaction and are invisible at read_committed
        self.txn_open: dict[int, tuple[int, int]] = {}
        self.aborted: list[tuple[int, int, int]] = []
        # earliest time the retention-clock pid expiry could next fire
        # (min last_ts + retention_ms, recomputed by each sweep): keeps
        # the expiry scan off the per-append hot path
        self._pid_deadline = 0
        # producer-state snapshots (DESIGN.md §11): sorted list of
        # (offset, producers, txn_open, aborted) — the state derived from
        # records strictly below ``offset``. Taken at every segment roll
        # and at every compaction horizon; _rebuild_producer_state
        # restores the newest snapshot at or below the rebuild point and
        # replays only the suffix.
        self.snapshots: list[tuple] = []
        # everything below this offset has been compacted (latest-per-key
        # holds); the leader propagates it so followers clean identically
        self.compact_point = 0
        self._dirty_bytes = 0  # appended since the last cleaner pass
        # _derive_state_at replays history against swapped-in state; the
        # flag suppresses side effects (txn_index stamping) during it
        self._derive_mode = False
        self.lock = make_rlock(lock_class, name=f"{lock_class}:{topic}:{index}")

    # ------------------------------------------------------------------ write
    def append_batch(
        self,
        values: Sequence[bytes],
        keys: Sequence[bytes | None] | None,
        timestamps: Sequence[int] | None = None,
        prods: tuple | None = None,
        producer: tuple[int, int, int] | None = None,
        txn: bool = False,
        offsets: Sequence[int] | None = None,
        seg_base: int | None = None,
    ) -> tuple[int, int]:
        """Append a message set; returns (first_offset, last_offset).

        ``timestamps`` is passed by replication only: a follower re-appends
        leader records with their original timestamps so replicas agree on
        time-based retention and on what consumers observe after failover.

        Producer metadata rides the same way: ``producer=(pid, epoch,
        base_seq)`` stamps one batch (leader append / direct ISR push —
        sequences run ``base_seq..base_seq+n-1``), while ``prods`` carries
        per-record metadata fetched from another replica's log. Either
        path updates this partition's dedup table as a side effect; the
        *checks* (fencing, dedup, gap detection) live in
        :meth:`idempotent_append` — replication never re-validates, leader
        order is law.

        ``offsets`` (replication only) re-appends records at their
        leader-assigned logical offsets — non-contiguous when the leader
        compacted the fetched range; the segment then tracks explicit
        per-record offsets and reads skip the holes. ``seg_base`` is the
        source segment's base offset (replication only): a batch from a
        segment beyond the local tail rolls a new local segment at that
        base, keeping replica segment layouts convergent.
        """
        with self.lock:
            now = self.clock()
            n = len(values)
            if producer is not None:
                pid, pep, seq = producer
                # lazy C-level iterables: the segment extends consume them
                # without materializing intermediate lists (hot path);
                # the ctrl column is only materialized for transactional
                # batches, so plain idempotent produce stays flag-free
                prods = (
                    itertools.repeat(pid, n),
                    itertools.repeat(pep, n),
                    range(seq, seq + n),
                    [CTRL_TXN_DATA] * n if txn else None,
                )
            seg = self.segments[-1]
            first_new = offsets[0] if offsets else None
            # the source segment's base, when replicating: replica
            # fetches never span leader segments, so a batch from a
            # segment beyond the local tail IS a leader roll boundary —
            # rolling with it keeps replica segment layouts (and thereby
            # compact_to horizons, clamped to local bases) convergent
            boundary = None
            if seg_base is not None and seg_base > seg.last_offset:
                boundary = seg_base
            elif first_new is not None and first_new > seg.last_offset + 1:
                # gapped batch jumping past the tail (compaction hole)
                boundary = first_new
            if seg.count == 0 and boundary is not None:
                # empty active segment behind the boundary (a reset
                # follower re-fetching a cleaned range): re-base it so
                # the hole isn't charged to this segment's raw window
                seg.base_offset = boundary
            elif seg.count > 0 and (
                seg.size_bytes >= self.cfg.segment_bytes
                or boundary is not None
            ):
                if self.cfg.spill_dir is not None:  # seal -> mmap-backed file
                    os.makedirs(self.cfg.spill_dir, exist_ok=True)
                    seg.spill(os.path.join(
                        self.cfg.spill_dir,
                        f"{self.topic}-{self.index}-{seg.base_offset}.seg",
                    ))
                new_base = boundary
                if new_base is None:
                    new_base = (
                        first_new if first_new is not None
                        else seg.last_offset + 1
                    )
                # the producer/txn state at a roll is exactly the state
                # derived from records below the new segment: snapshot it,
                # so rebuilds replay at most one segment's worth of suffix
                self._take_snapshot_locked(new_base)
                seg = _Segment(
                    new_base, now, index_every=self.cfg.index_interval_bytes
                )
                self.segments.append(seg)
            first = offsets[0] if offsets else seg.next_offset
            seg.append_batch(
                values, keys, now if timestamps is None else timestamps,
                prods, offsets=offsets,
            )
            if producer is not None:
                # one contiguous batch: a single run merge, off the
                # per-record path (the acks=all hot path pushes batches)
                ts = timestamps if timestamps is None or isinstance(
                    timestamps, int
                ) else (timestamps[-1] if len(timestamps) else None)
                self._note_producer_run(
                    pid, pep, seq, seq + n - 1, first,
                    now if ts is None else ts,
                )
                if txn:
                    self._open_txn(pid, pep, first)
            elif prods is not None:
                self._note_producer_records(
                    prods, first, now if timestamps is None else timestamps,
                    offsets=offsets,
                )
            self._enforce_retention(now)
            if self.cfg.cleanup == "compact":
                self._dirty_bytes += sum(map(len, values))
                thresh = self.cfg.min_cleanable_bytes
                if thresh is None:
                    thresh = self.cfg.segment_bytes
                if self._dirty_bytes >= thresh and len(self.segments) > 1:
                    self._dirty_bytes = 0
                    self._compact_locked(self.segments[-1].base_offset)
            return first, seg.last_offset

    # ------------------------------------------------------ producer state
    def _producer_state(self, pid: int, epoch: int) -> _ProducerState | None:
        """State for ``pid`` at ``epoch``; a newer epoch resets the dedup
        window (an epoch bump restarts sequence numbering), an older one
        returns None (the record predates the current incarnation)."""
        st = self.producers.get(pid)
        if st is None or epoch > st.epoch:
            st = _ProducerState(epoch)
            self.producers[pid] = st
        elif epoch < st.epoch:
            return None
        return st

    def _note_producer_run(
        self,
        pid: int,
        epoch: int,
        first_seq: int,
        last_seq: int,
        first_off: int,
        ts: int = 0,
    ) -> None:
        st = self._producer_state(pid, epoch)
        if st is not None:
            st.note(first_seq, last_seq, first_off, ts)

    def _note_producer_records(
        self,
        prods: tuple,
        first_off: int,
        timestamps: Sequence[int] | int = 0,
        offsets: Sequence[int] | None = None,
    ) -> None:
        """Replication path: fold per-record metadata into the table.
        Consecutive records merge into the same runs the source built, so
        replica tables converge on the leader's. Control flags replay the
        transaction state machine the same way: a txn-flagged record
        opens its pid's transaction, a marker closes (or aborts) it.
        ``offsets`` carries explicit per-record offsets when the fetched
        range had compaction holes (records are then not at
        ``first_off + i``)."""
        pids, peps, pseqs = prods[0], prods[1], prods[2]
        ctrls = prods[3] if len(prods) > 3 else None
        scalar_ts = timestamps if isinstance(timestamps, int) else None
        for i, pid in enumerate(pids):
            if pid < 0:
                continue
            off = offsets[i] if offsets is not None else first_off + i
            ctrl = ctrls[i] if ctrls is not None else CTRL_NONE
            if ctrl >= CTRL_COMMIT:
                self._close_txn(
                    pid, peps[i], off, abort=ctrl == CTRL_ABORT
                )
                continue
            ts = scalar_ts if scalar_ts is not None else timestamps[i]
            self._note_producer_run(
                pid, peps[i], pseqs[i], pseqs[i], off, ts
            )
            if ctrl == CTRL_TXN_DATA:
                self._open_txn(pid, peps[i], off)

    def _rebuild_producer_state(self) -> None:
        """Re-derive the dedup table — and the transaction state — after
        ``truncate_to``: state for truncated records must disappear —
        their batches are gone, so a retry must re-append, not dedup
        against offsets that no longer hold them, and a truncated marker
        must re-open the transaction it closed.

        Storage engine v2 (DESIGN.md §11): instead of replaying the full
        retained log, restore the newest producer-state snapshot at or
        below the new end and replay only the suffix — equivalent by
        construction (a snapshot *is* the replay state at its offset),
        and the only correct rebuild once compaction has physically
        removed stamped records below the compaction point (the pinned
        snapshot at ``compact_point`` covers them)."""
        end = self.end_offset
        # snapshots describing truncated-away state are no longer valid
        self._drop_snapshots(lambda off: off > end)
        start, self.producers, self.txn_open, self.aborted = (
            self._state_from_snapshot(end)
        )
        self._pid_deadline = 0  # rebuilt state may hold older timestamps
        # re-derive the per-segment aborted-txn index alongside the state
        for seg in self.segments:
            seg.txn_index.clear()
        for ent in self.aborted:
            self._stamp_txn_index(*ent)
        self._replay_records(start, end)
        # trim state below the log start exactly like incremental
        # retention would have: a restored snapshot may predate evictions
        self._expire_producers()

    def _replay_records(self, start: int, stop: int) -> None:
        """Replay producer/txn metadata of records in ``[start, stop)``
        into the current state (the shared engine of rebuilds and
        point-in-time derivations)."""
        for seg, lo, hi in self._iter_spans(start, stop - start):
            pids = seg.pids
            if pids is None:
                continue  # segment never saw a stamped record
            ctrls = seg.ctrls
            for r in range(lo, hi):
                if pids[r] < 0:
                    continue
                off = seg.off(r)
                ctrl = ctrls[r] if ctrls is not None else CTRL_NONE
                if ctrl >= CTRL_COMMIT:
                    self._close_txn(
                        pids[r], seg.peps[r], off, abort=ctrl == CTRL_ABORT
                    )
                    continue
                self._note_producer_run(
                    pids[r], seg.peps[r], seg.pseqs[r], seg.pseqs[r],
                    off, seg.timestamps[r],
                )
                if ctrl == CTRL_TXN_DATA:
                    self._open_txn(pids[r], seg.peps[r], off)

    # ------------------------------------------------- producer snapshots
    def _snapshot_file(self, offset: int) -> str | None:
        if self.cfg.spill_dir is None:
            return None
        return os.path.join(
            self.cfg.spill_dir,
            f"{self.topic}-{self.index}-{offset:020d}.snapshot",
        )

    def _take_snapshot_locked(self, offset: int) -> None:
        """Snapshot the producer/transaction state as of ``offset`` (the
        state derived from records strictly below it). Called at segment
        rolls; compaction inserts interior snapshots via
        :meth:`_snapshot_state_at`."""
        snap = (
            offset,
            {pid: st.clone() for pid, st in self.producers.items()},
            dict(self.txn_open),
            list(self.aborted),
        )
        i = bisect.bisect_left([s[0] for s in self.snapshots], offset)
        if i < len(self.snapshots) and self.snapshots[i][0] == offset:
            self.snapshots[i] = snap
        else:
            self.snapshots.insert(i, snap)
        self._write_snapshot_file(snap)
        self._trim_snapshots()

    def _write_snapshot_file(self, snap: tuple) -> None:
        """Durable snapshot format (DESIGN.md §11) — best-effort JSON
        sidecar next to the spilled segments; the in-memory copy is
        authoritative for this in-process broker."""
        path = self._snapshot_file(snap[0])
        if path is None:
            return
        offset, producers, txn_open, aborted = snap
        payload = {
            "offset": offset,
            "producers": {
                str(pid): {
                    "epoch": st.epoch,
                    "last_seq": st.last_seq,
                    "last_ts": st.last_ts,
                    "runs": [list(r) for r in st.runs],
                }
                for pid, st in producers.items()
            },
            "txn_open": {
                str(pid): list(v) for pid, v in txn_open.items()
            },
            "aborted": [list(a) for a in aborted],
        }
        try:
            import json

            os.makedirs(self.cfg.spill_dir, exist_ok=True)
            with open(path, "w") as f:
                json.dump(payload, f, sort_keys=True)
        except OSError:
            pass  # snapshot files are an optimization, never correctness

    def _drop_snapshots(self, drop: Callable[[int], bool]) -> None:
        kept = []
        for snap in self.snapshots:
            if not drop(snap[0]):
                kept.append(snap)
                continue
            path = self._snapshot_file(snap[0])
            if path is not None:
                try:
                    os.unlink(path)
                except OSError:
                    pass
        self.snapshots = kept

    def _trim_snapshots(self) -> None:
        """Bound the snapshot list. Snapshots below the newest one at or
        below the compaction point are unreachable (cluster truncation
        never targets below the compact point — the horizon is capped at
        the LSO ≤ HW, and every truncation target is ≥ the HW the
        snapshot's replica had); the one AT the compaction point is
        load-bearing (records below it no longer replay) and is never
        evicted by the size cap."""
        pin = None
        for snap in reversed(self.snapshots):
            if snap[0] <= self.compact_point:
                pin = snap[0]
                break
        if pin is not None:
            self._drop_snapshots(lambda off: off < pin)
        while len(self.snapshots) > _MAX_PRODUCER_SNAPSHOTS:
            victim = None
            for snap in self.snapshots:
                if snap[0] != pin:
                    victim = snap[0]
                    break
            if victim is None:
                break
            self._drop_snapshots(lambda off: off == victim)

    def _state_from_snapshot(self, upto: int) -> tuple[int, dict, dict, list]:
        """Newest snapshot at or below ``upto`` as freshly cloned state:
        ``(start_offset, producers, txn_open, aborted)``; empty state at
        the log start when no snapshot qualifies."""
        for snap in reversed(self.snapshots):
            if snap[0] <= upto:
                offset, producers, txn_open, aborted = snap
                return (
                    offset,
                    {pid: st.clone() for pid, st in producers.items()},
                    dict(txn_open),
                    list(aborted),
                )
        return self.log_start_offset, {}, {}, []

    def _derive_state_at(self, upto: int) -> tuple[dict, dict, list]:
        """Producer/txn state as of ``upto`` (records strictly below it),
        computed from the nearest snapshot plus suffix replay — without
        disturbing the live state."""
        saved = (
            self.producers, self.txn_open, self.aborted, self._pid_deadline
        )
        self._derive_mode = True
        try:
            start, self.producers, self.txn_open, self.aborted = (
                self._state_from_snapshot(upto)
            )
            self._replay_records(start, upto)
            derived = (self.producers, self.txn_open, self.aborted)
        finally:
            self._derive_mode = False
            (
                self.producers, self.txn_open, self.aborted,
                self._pid_deadline,
            ) = saved
        return derived

    def _snapshot_state_at(self, offset: int) -> None:
        """Ensure a snapshot exists at exactly ``offset`` — compaction
        calls this for its horizon BEFORE cleaning, because the cleaned
        records' producer stamps are what a later full replay would have
        needed."""
        for snap in self.snapshots:
            if snap[0] == offset:
                return
        producers, txn_open, aborted = self._derive_state_at(offset)
        snap = (offset, producers, txn_open, aborted)
        i = bisect.bisect_left([s[0] for s in self.snapshots], offset)
        self.snapshots.insert(i, snap)
        self._write_snapshot_file(snap)

    # ------------------------------------------------------ transactions
    def _open_txn(self, pid: int, epoch: int, offset: int) -> None:
        """First transactional record of a (pid, epoch) transaction pins
        the partition's LSO at its offset until a marker resolves it."""
        cur = self.txn_open.get(pid)
        if cur is None:
            self.txn_open[pid] = (offset, epoch)
        elif epoch > cur[1]:
            # a newer incarnation appended before the old txn's marker
            # arrived (abnormal interleaving): keep the earliest offset —
            # the LSO must not advance past unresolved records
            self.txn_open[pid] = (cur[0], epoch)

    def _close_txn(
        self, pid: int, epoch: int, marker_off: int, *, abort: bool
    ) -> None:
        cur = self.txn_open.get(pid)
        if cur is None or epoch < cur[1]:
            return  # stale marker: never resolves a newer incarnation
        del self.txn_open[pid]
        # the pid is no longer pinned: re-arm the retention-clock expiry
        # sweep so a long-pinned idle pid is reconsidered promptly
        self._pid_deadline = 0
        if abort:
            self.aborted.append((pid, cur[0], marker_off))
            if not self._derive_mode:
                self._stamp_txn_index(pid, cur[0], marker_off)

    def _stamp_txn_index(self, pid: int, first: int, marker: int) -> None:
        """Record an aborted range on every segment it overlaps (the
        per-segment ``.txnindex``): read_committed's prefilter then
        consults only the spanned segments, not the partition-wide list."""
        ent = (pid, first, marker)
        for si in range(self._segment_for(first), len(self.segments)):
            seg = self.segments[si]
            if seg.base_offset > marker:
                break
            if seg.last_offset >= first and ent not in seg.txn_index:
                seg.txn_index.append(ent)

    def append_control(
        self, pid: int, epoch: int, *, abort: bool
    ) -> int | None:
        """Write a COMMIT/ABORT marker resolving ``pid``'s open
        transaction; returns the marker's offset, or None when the pid
        has no open transaction at ``epoch`` or newer here — which makes
        coordinator-recovery re-drives idempotent (the second marker
        write for an already-resolved partition is a no-op, not a
        duplicate marker)."""
        with self.lock:
            cur = self.txn_open.get(pid)
            if cur is None or cur[1] > epoch:
                return None
            value = _ABORT_MARKER if abort else _COMMIT_MARKER
            ctrl = CTRL_ABORT if abort else CTRL_COMMIT
            first, _last = self.append_batch(
                [value], None, prods=([pid], [epoch], [-1], [ctrl])
            )
            return first

    def last_stable_offset(self) -> int:
        """First offset of the earliest open transaction (Kafka's LSO):
        records at or above it are not yet stable — their transaction may
        still abort — so read_committed consumers stop here."""
        with self.lock:
            if not self.txn_open:
                return self.end_offset
            return min(first for first, _ in self.txn_open.values())

    def idempotent_append(
        self,
        values: Sequence[bytes],
        keys: Sequence[bytes | None] | None,
        timestamps: Sequence[int] | int | None,
        pid: int,
        epoch: int,
        seq: int,
        txn: bool = False,
    ) -> tuple[int, int, bool]:
        """Leader-side idempotent append: dedup + fencing + gap detection.

        Returns ``(first, last, duplicate)``. A retried batch whose
        sequences are already in the log returns the **original** offsets
        with ``duplicate=True`` instead of re-appending — the exactly-once
        contract across client retries. Raises :class:`ProducerFenced` for
        a stale epoch and :class:`OutOfOrderSequence` for a gap or a
        duplicate older than the dedup window.
        """
        with self.lock:
            n = len(values)
            st = self.producers.get(pid)
            if st is not None:
                if epoch < st.epoch:
                    raise ProducerFenced(
                        f"{self.topic}:{self.index} producer {pid} epoch "
                        f"{epoch} fenced by newer epoch {st.epoch}"
                    )
                if epoch == st.epoch and st.last_seq >= 0:
                    hit = st.find(seq, n)
                    if hit is not None:
                        return hit[0], hit[1], True
                    if seq <= st.last_seq:
                        raise OutOfOrderSequence(
                            f"{self.topic}:{self.index} producer {pid} "
                            f"sequence {seq} already appended but outside "
                            f"the dedup window (last_seq {st.last_seq})"
                        )
                    if seq != st.last_seq + 1:
                        raise OutOfOrderSequence(
                            f"{self.topic}:{self.index} producer {pid} "
                            f"sequence gap: expected {st.last_seq + 1}, "
                            f"got {seq}"
                        )
            first, last = self.append_batch(
                values, keys, timestamps, producer=(pid, epoch, seq), txn=txn
            )
            return first, last, False

    # ------------------------------------------------------------------- read
    @property
    def end_offset(self) -> int:
        # taken under the partition lock so a concurrent append's segment
        # roll can't be observed half-applied (the lock is reentrant, so
        # read paths that already hold it are unaffected)
        with self.lock:
            return self.segments[-1].next_offset

    def _bounded_count(self, offset: int, max_records: int) -> int:
        """Validate ``offset`` against [log start, end]; return how many
        *raw* offsets a read starting there may cover. On a compacted
        partition the window may contain holes, so the delivered record
        count can be smaller."""
        if offset < self.log_start_offset:
            raise OffsetOutOfRange(
                f"{self.topic}:{self.index} offset {offset} < log start "
                f"{self.log_start_offset} (evicted by retention)"
            )
        end = self.end_offset
        if offset > end:
            raise OffsetOutOfRange(
                f"{self.topic}:{self.index} offset {offset} > end {end}"
            )
        return min(max_records, end - offset)

    def _iter_spans(self, offset: int, n: int):
        """Yield ``(segment, rel_start, rel_stop)`` spans covering the raw
        offset window ``[offset, offset + n)`` — the one segment walk
        shared by consumer reads, replication fetches, and state replay.
        Compacted segments contribute only the records they still hold
        (``rel_range`` bisects their explicit offsets array)."""
        hi_off = offset + n
        if n <= 0:
            return
        for si in range(self._segment_for(offset), len(self.segments)):
            seg = self.segments[si]
            if seg.base_offset >= hi_off:
                break
            lo, hi = seg.rel_range(offset, hi_off)
            if hi > lo:
                yield seg, lo, hi

    def read(
        self, offset: int, max_records: int, isolation: str | None = None
    ) -> RecordBatch:
        if isolation == "read_committed":
            return self._read_committed(offset, max_records)
        with self.lock:
            n = self._bounded_count(offset, max_records)
            spans = list(self._iter_spans(offset, n))
            expect = offset  # raw-contiguity check: a dropped or re-based
            contiguous = True  # segment leaves a hole no span covers
            for seg, lo, hi in spans:
                if seg.off(lo) != expect:
                    contiguous = False
                    break
                expect = seg.off(hi - 1) + 1
            if not contiguous or any(
                seg.markers or seg.offsets is not None
                for seg, _, _ in spans
            ):
                # a control marker may sit in range — consumers never see
                # control records at ANY isolation level (a raw reader
                # handed marker bytes as a data record would crash on
                # them); read_uncommitted still delivers not-yet-resolved
                # and aborted transactional data. Compacted (gapped)
                # segments also take this path: their records need
                # explicit per-record offsets. Marker-free dense spans
                # (the overwhelming majority) stay on the contiguous
                # fast path below.
                return self._read_filtered(
                    offset, n, spans, skip_aborted=False
                )
            values: list[memoryview] = []
            timestamps: list[int] = []
            payload_spans: list[tuple[memoryview, int]] = []
            for seg, lo, hi in spans:
                mv = memoryview(seg.buf)
                for r in range(lo, hi):
                    start = seg.starts[r]
                    values.append(mv[start : start + seg.lengths[r]])
                    timestamps.append(seg.timestamps[r])
                # records of one segment are tightly packed (starts are
                # cumulative lengths), so the whole [lo, hi) span is ONE
                # contiguous byte range — exported as a single view for
                # zero-copy fixed-layout decode (RecordBatch.framed)
                end = seg.starts[hi - 1] + seg.lengths[hi - 1]
                payload_spans.append((mv[seg.starts[lo] : end], hi - lo))
            return RecordBatch(
                topic=self.topic,
                partition=self.index,
                first_offset=offset,
                values=values,
                timestamps=timestamps,
                spans=payload_spans,
            )

    def _read_committed(self, offset: int, max_records: int) -> RecordBatch:
        """Read capped at the LSO, with control markers and aborted
        records filtered out."""
        with self.lock:
            n = self._bounded_count(offset, max_records)
            n = min(n, max(self.last_stable_offset() - offset, 0))
            return self._read_filtered(
                offset, n, list(self._iter_spans(offset, n)),
                skip_aborted=True,
            )

    def _read_filtered(
        self, offset: int, n: int, spans: list, skip_aborted: bool
    ) -> RecordBatch:
        """Read with control markers filtered out — plus, at
        read_committed (``skip_aborted``), aborted transactions' records.
        The returned batch carries explicit per-record ``offsets`` and
        the raw ``scanned`` count, so the consumer's next position
        advances past filtered spans. Caller holds the partition lock."""
        values: list[memoryview] = []
        timestamps: list[int] = []
        offsets: list[int] = []
        abort_ranges: dict[int, list[tuple[int, int]]] = {}
        if skip_aborted:
            hi_off = offset + n
            # per-segment aborted-txn index (Kafka's .txnindex): only the
            # segments this read spans are consulted, so the prefilter
            # cost is bounded by the window — not by the partition's full
            # abort history. A range spanning several segments is stamped
            # on each; the ``seen`` set dedupes it.
            seen: set[tuple[int, int, int]] = set()
            for seg, _, _ in spans:
                for ent in seg.txn_index:
                    if (
                        ent[1] < hi_off
                        and ent[2] > offset
                        and ent not in seen
                    ):
                        seen.add(ent)
                        abort_ranges.setdefault(ent[0], []).append(
                            (ent[1], ent[2])
                        )
        for seg, lo, hi in spans:
            mv = memoryview(seg.buf)
            ctrls = seg.ctrls
            for r in range(lo, hi):
                ctrl = ctrls[r] if ctrls is not None else CTRL_NONE
                if ctrl >= CTRL_COMMIT:
                    continue  # control marker: never delivered
                if skip_aborted and ctrl == CTRL_TXN_DATA:
                    off = seg.off(r)
                    ab = abort_ranges.get(seg.pids[r])
                    if ab is not None and any(a <= off < b for a, b in ab):
                        continue  # aborted transaction's record
                start = seg.starts[r]
                values.append(mv[start : start + seg.lengths[r]])
                timestamps.append(seg.timestamps[r])
                offsets.append(seg.off(r))
        return RecordBatch(
            topic=self.topic,
            partition=self.index,
            first_offset=offset,
            values=values,
            timestamps=timestamps,
            offsets=offsets,
            scanned=n,
        )

    def offset_for_timestamp(self, ts_ms: int) -> int | None:
        """First retained offset with timestamp >= ``ts_ms`` via the
        sparse time index: segments whose ``max_ts`` is too old are
        skipped whole; within a candidate segment the index entry just
        below the target bounds a short forward scan."""
        with self.lock:
            for seg in self.segments:
                if seg.count == 0 or seg.max_ts < ts_ms:
                    continue
                lo = 0
                i = bisect.bisect_left(seg.index_times, (ts_ms,)) - 1
                if i >= 0:
                    lo = seg.index_times[i][1]
                tss = seg.timestamps
                for r in range(lo, seg.count):
                    if tss[r] >= ts_ms:
                        return seg.off(r)
            return None

    def _segment_for(self, offset: int) -> int:
        bases = [s.base_offset for s in self.segments]
        i = bisect.bisect_right(bases, offset) - 1
        return max(i, 0)

    def fetch_raw(
        self, offset: int, max_records: int
    ) -> tuple[
        list[bytes],
        list[bytes | None],
        list[int],
        tuple[list[int], list[int], list[int], list[int]] | None,
        list[int] | None,
        int,
        int | None,
    ]:
        """Replication fetch: materialized ``(values, keys, timestamps,
        producer metadata, offsets, next_offset, seg_base)`` so a follower can
        re-append them verbatim to its copy of the partition — including
        the (pid, epoch, seq) stamps its dedup table is derived from, and
        the control flags its transaction state is derived from.

        ``offsets`` is None for a dense window and the per-record logical
        offsets when the window has compaction holes; ``next_offset`` is
        the raw end of the covered window (the follower's next fetch
        position — it can advance past a fully-compacted gap even when no
        records were returned); ``seg_base`` the base offset of the
        segment the window came from (None for a pure-hole window).

        Like Kafka's fetch protocol, one response never spans segment
        files: the window is capped at the end of the first spanned
        segment. The follower rolls its own segments at the fetched
        ``seg_base`` boundaries (see :meth:`append_batch`), so replica
        segment layouts converge — which keeps ``compact_to`` horizons
        (clamped to local segment bases) in step across replicas."""
        with self.lock:
            n = self._bounded_count(offset, max_records)
            wbase: int | None = None
            if n > 0:
                i = self._segment_for(offset)
                seg0 = self.segments[i]
                if seg0.base_offset > offset:
                    # fully-compacted hole before the first retained
                    # segment: cover the hole only, so next_offset lands
                    # exactly on that segment's base
                    n = min(n, seg0.base_offset - offset)
                elif seg0.last_offset < offset:
                    # hole at this segment's raw tail: advance to the
                    # next segment's base
                    nxt = (
                        self.segments[i + 1].base_offset
                        if i + 1 < len(self.segments)
                        else offset + n
                    )
                    n = min(n, nxt - offset)
                elif seg0.last_offset < offset + n - 1:
                    n = seg0.last_offset - offset + 1
                    wbase = seg0.base_offset
                else:
                    wbase = seg0.base_offset
            values: list[bytes] = []
            keys: list[bytes | None] = []
            timestamps: list[int] = []
            pids: list[int] = []
            peps: list[int] = []
            pseqs: list[int] = []
            ctrls: list[int] = []
            spans = list(self._iter_spans(offset, n))
            # None unless some record in range is stamped, so followers of
            # purely non-idempotent partitions append lazily too
            stamped = any(seg.pids is not None for seg, _, _ in spans)
            gapped = any(seg.offsets is not None for seg, _, _ in spans)
            offs: list[int] | None = [] if gapped else None
            for seg, lo, hi in spans:
                for r in range(lo, hi):
                    start = seg.starts[r]
                    values.append(bytes(seg.buf[start : start + seg.lengths[r]]))
                    klen = seg.key_lengths[r]
                    ks = seg.key_starts[r]
                    keys.append(
                        None if klen < 0 else bytes(seg.key_buf[ks : ks + klen])
                    )
                    timestamps.append(seg.timestamps[r])
                if offs is not None:
                    offs.extend(seg.off(r) for r in range(lo, hi))
                if not stamped:
                    continue
                if seg.pids is None:
                    pids.extend(itertools.repeat(-1, hi - lo))
                    peps.extend(itertools.repeat(-1, hi - lo))
                    pseqs.extend(itertools.repeat(-1, hi - lo))
                else:
                    pids.extend(seg.pids[lo:hi])
                    peps.extend(seg.peps[lo:hi])
                    pseqs.extend(seg.pseqs[lo:hi])
                if seg.ctrls is None:
                    ctrls.extend(itertools.repeat(CTRL_NONE, hi - lo))
                else:
                    ctrls.extend(seg.ctrls[lo:hi])
            return (
                values, keys, timestamps,
                (pids, peps, pseqs, ctrls) if stamped else None,
                offs, offset + n, wbase,
            )

    def reset_to(self, offset: int) -> int:
        """Discard the entire partition contents and restart the log at
        ``offset`` (a follower that fell behind the leader's retention point
        re-fetches from the leader's log start)."""
        with self.lock:
            for s in self.segments:
                s.drop_spill()
            self.segments = [
                _Segment(offset, self.clock(), index_every=self.cfg.index_interval_bytes)
            ]
            self.log_start_offset = offset
            # the log is empty: dedup and transaction state rebuild as
            # records re-fetch (replica_append carries their metadata)
            self.producers = {}
            self.txn_open = {}
            self.aborted = []
            self._pid_deadline = 0
            self._drop_snapshots(lambda _off: True)
            self.compact_point = 0
            self._dirty_bytes = 0
            return offset

    def truncate_to(self, offset: int) -> int:
        """Discard every record at ``offset`` and beyond (post-failover log
        reconciliation: a deposed leader truncates to the new leader's end
        before re-fetching). Returns the new end offset — which on a
        compacted partition may sit below ``offset`` when the records just
        under the truncation point were compacted away."""
        with self.lock:
            if offset >= self.end_offset:
                return self.end_offset
            if offset < self.log_start_offset:
                # nothing retained below the truncation point — reset the
                # partition; the follower re-fetches from `offset` upward
                return self.reset_to(offset)
            while self.segments and self.segments[-1].base_offset >= offset:
                self.segments.pop().drop_spill()
            if not self.segments:
                self.segments = [
                    _Segment(
                        offset, self.clock(),
                        index_every=self.cfg.index_interval_bytes,
                    )
                ]
                self._rebuild_producer_state()
                return offset
            seg = self.segments[-1]
            if seg.offsets is not None:
                rel = bisect.bisect_left(seg.offsets, offset)
            else:
                rel = offset - seg.base_offset
            if rel < seg.count:
                if isinstance(seg.buf, bytearray):
                    # drop the truncated records' payload too, or it stays
                    # resident and skews size_bytes/retention accounting.
                    # Rebuild rather than resize in place: outstanding
                    # zero-copy reads may hold memoryview exports of the
                    # old buffer, and resizing an exported bytearray raises
                    # BufferError. The old buffer lives until those views
                    # are dropped; new appends go to the rebuilt one.
                    seg.buf = seg.buf[: seg.starts[rel]]
                    seg.buf_len = seg.starts[rel]
                    seg.key_buf = seg.key_buf[: seg.key_starts[rel]]
                else:
                    # sealed mmap segment: can't shrink the map — record the
                    # retained payload so size_bytes/retention stay honest
                    seg.logical_bytes = seg.starts[rel] + seg.key_starts[rel]
                del seg.starts[rel:]
                del seg.lengths[rel:]
                del seg.key_starts[rel:]
                del seg.key_lengths[rel:]
                del seg.timestamps[rel:]
                if seg.pids is not None:
                    del seg.pids[rel:]
                    del seg.peps[rel:]
                    del seg.pseqs[rel:]
                if seg.ctrls is not None:
                    seg.markers -= sum(
                        1 for x in seg.ctrls[rel:] if x >= CTRL_COMMIT
                    )
                    del seg.ctrls[rel:]
                if seg.offsets is not None:
                    del seg.offsets[rel:]
                # the sparse indexes cover only retained records; the next
                # index entry re-arms off the last survivor's byte position
                seg.index_offsets = [e for e in seg.index_offsets if e[0] < rel]
                seg.index_times = [e for e in seg.index_times if e[1] < rel]
                seg._index_next = (
                    seg.index_offsets[-1][1] + seg.index_every
                    if seg.index_offsets
                    else seg.index_every
                )
                seg.max_ts = max(seg.timestamps[:rel], default=0)
                seg.count = rel
            if seg._spill_file is not None:
                # sealed/spilled segments are read-only maps — appendable
                # writes need a fresh heap-backed active segment
                self.segments.append(
                    _Segment(
                        offset, self.clock(),
                        index_every=self.cfg.index_interval_bytes,
                    )
                )
            # dedup state for the truncated suffix must not survive it: a
            # deposed leader that rejoins (leader-epoch reconciliation)
            # re-derives the table from what the log still holds, so its
            # table converges with the new leader's as it re-fetches
            self._rebuild_producer_state()
            return self.end_offset

    # -------------------------------------------------------------- compaction
    def compact(self, horizon: int | None = None) -> dict:
        """Run the cleaner up to ``horizon`` (default: everything below
        the active segment). Returns the cleaner stats dict."""
        with self.lock:
            if horizon is None:
                horizon = self.segments[-1].base_offset
            return self._compact_locked(horizon)

    def compact_to(self, horizon: int) -> dict:
        """Follower-side cleaning: apply the leader's compact point. The
        keep rule is a pure function of (retained records, horizon,
        config), so replicas with the same log prefix converge on the
        same surviving records — idempotent and monotone (a lower or
        repeated horizon is a no-op)."""
        with self.lock:
            return self._compact_locked(horizon)

    def _compact_locked(self, horizon: int) -> dict:
        """One cleaner pass: rewrite every sealed segment wholly below
        ``horizon`` keeping only (a) keyless records and control markers,
        (b) the newest record of each key, (c) unexpired tombstones.
        Logical offsets are preserved (the rewritten segments carry
        explicit ``offsets`` arrays with holes); the producer/txn state
        the removed records would have replayed into is pinned by a
        snapshot at the horizon first."""
        stats = {
            "horizon": self.compact_point,
            "removed_records": 0,
            "removed_bytes": 0,
            "rewritten_segments": 0,
        }
        if self.cfg.cleanup != "compact" or len(self.segments) < 2:
            return stats
        # never clean unstable records (their txn may abort) nor the
        # active segment; then clamp down to a segment boundary so the
        # latest-per-key guarantee below the compact point is exact
        horizon = min(
            horizon, self.last_stable_offset(), self.segments[-1].base_offset
        )
        bound = self.log_start_offset
        for seg in self.segments:
            if seg.base_offset <= horizon:
                bound = seg.base_offset
            else:
                break
        horizon = bound
        if horizon <= self.compact_point:
            return stats
        # the cleaned records' producer stamps must survive their removal:
        # pin the replay state at the horizon before touching anything
        self._snapshot_state_at(horizon)
        # pass 1: newest offset per key below the horizon, and the stream
        # clock (newest record timestamp) the tombstone grace runs on —
        # both derived from replicated record data only, so every replica
        # computes the same keep set
        latest: dict[bytes, int] = {}
        stream_ts = 0
        for seg, lo, hi in self._iter_spans(
            self.log_start_offset, horizon - self.log_start_offset
        ):
            kb = seg.key_buf
            kls = seg.key_lengths
            kss = seg.key_starts
            tss = seg.timestamps
            for r in range(lo, hi):
                if tss[r] > stream_ts:
                    stream_ts = tss[r]
                klen = kls[r]
                if klen < 0:
                    continue
                ks = kss[r]
                latest[bytes(kb[ks : ks + klen])] = seg.off(r)
        grace = self.cfg.tombstone_retention_ms
        # pass 2: rewrite the segments below the horizon
        out: list[_Segment] = []
        for seg in self.segments:
            if seg.base_offset >= horizon:
                out.append(seg)
                continue
            keep: list[int] = []
            drop_bytes = 0
            kls = seg.key_lengths
            kss = seg.key_starts
            lens = seg.lengths
            for r in range(seg.count):
                klen = kls[r]
                if klen < 0:
                    keep.append(r)  # keyless record or control marker
                    continue
                ks = kss[r]
                key = bytes(seg.key_buf[ks : ks + klen])
                if latest.get(key) != seg.off(r):
                    drop_bytes += lens[r] + klen  # superseded
                    continue
                if lens[r] == 0 and stream_ts - seg.timestamps[r] > grace:
                    drop_bytes += klen  # tombstone past its grace window
                    continue
                keep.append(r)
            if len(keep) == seg.count:
                out.append(seg)
                continue
            stats["removed_records"] += seg.count - len(keep)
            stats["removed_bytes"] += drop_bytes
            stats["rewritten_segments"] += 1
            spill_path = (
                seg._spill_file[1] if seg._spill_file is not None else None
            )
            new = self._rewrite_segment(seg, keep)
            seg.drop_spill()
            if new.count == 0:
                continue  # a fully-compacted segment disappears
            if spill_path is not None:
                try:
                    new.spill(spill_path)
                except OSError:
                    pass  # stays heap-backed; correctness is unaffected
            out.append(new)
        self.segments = out
        self.compact_point = horizon
        stats["horizon"] = horizon
        self._trim_snapshots()
        return stats

    def _rewrite_segment(self, seg: _Segment, keep: list[int]) -> _Segment:
        """Copy the ``keep`` records (by relative index) into a fresh
        segment at the same base offset, with explicit logical offsets.
        The old segment — and any zero-copy views pinning its buffer —
        is left untouched; readers that grabbed views before the swap
        keep reading valid (pre-compaction) bytes."""
        new = _Segment(
            seg.base_offset, seg.created_ms, index_every=seg.index_every
        )
        if keep:
            mv = memoryview(seg.buf)
            values = [
                bytes(mv[seg.starts[r] : seg.starts[r] + seg.lengths[r]])
                for r in keep
            ]
            keys = [
                None
                if seg.key_lengths[r] < 0
                else bytes(
                    seg.key_buf[
                        seg.key_starts[r]
                        : seg.key_starts[r] + seg.key_lengths[r]
                    ]
                )
                for r in keep
            ]
            ts = [seg.timestamps[r] for r in keep]
            offs = [seg.off(r) for r in keep]
            prods = None
            if seg.pids is not None:
                prods = (
                    [seg.pids[r] for r in keep],
                    [seg.peps[r] for r in keep],
                    [seg.pseqs[r] for r in keep],
                    [seg.ctrls[r] for r in keep]
                    if seg.ctrls is not None
                    else None,
                )
            new.append_batch(values, keys, ts, prods, offsets=offs)
        new.txn_index = list(seg.txn_index)
        return new

    # -------------------------------------------------------------- retention
    def _enforce_retention(self, now_ms: int) -> None:
        cfg = self.cfg
        if cfg.cleanup == "compact":
            # compacted topics never delete by age or size — the cleaner
            # bounds growth by rewriting history to latest-per-key instead
            # (Kafka's cleanup.policy=compact)
            return
        evicted = False
        # never evict the active (last) segment
        while len(self.segments) > 1:
            head = self.segments[0]
            evict = False
            if cfg.retention_bytes is not None:
                total = sum(s.size_bytes for s in self.segments)
                if total > cfg.retention_bytes:
                    evict = True
            if not evict and cfg.retention_ms is not None:
                # age by the segment's newest record timestamp (Kafka's
                # retention.ms semantics). Record timestamps replicate
                # verbatim, so leader and followers expire the same
                # records at the same time regardless of when each broker
                # physically fetched them; created_ms is only a fallback
                # for empty segments.
                age_ref = head.timestamps[-1] if head.timestamps else head.created_ms
                if now_ms - age_ref > cfg.retention_ms:
                    evict = True
            if not evict:
                break
            self.segments.pop(0).drop_spill()
            self.log_start_offset = self.segments[0].base_offset
            evicted = True
        if evicted:
            self._expire_producers()
            # snapshots strictly below the log start describe evicted
            # history no rebuild will ever ask for
            self._drop_snapshots(lambda off: off < self.log_start_offset)
        if (
            cfg.retention_ms is not None
            and self.producers
            and now_ms > self._pid_deadline
        ):
            # retention-clock pid expiry: a long-idle producer id is
            # forgotten once its newest record timestamp ages past
            # retention_ms — even while its records still sit in the
            # never-evicted active segment. Keyed to record timestamps
            # (which replicate verbatim), not to table size or local
            # fetch time, so every replica expires the same pids at the
            # same stream time (Kafka's producer-id expiration). The
            # sweep runs only when the cached deadline (earliest possible
            # expiry) passes — never on every append. New pids appended
            # after a sweep carry newer timestamps than its minimum on
            # the leader; a follower replaying older stamps may retain a
            # pid up to one retention period longer (extra dedup state:
            # the safe direction).
            min_ts = None
            for pid in list(self.producers):
                st = self.producers[pid]
                if pid in self.txn_open:
                    # an open txn pins its pid; excluded from the
                    # deadline too (its stale last_ts would otherwise
                    # drag the deadline into the past and re-run this
                    # sweep on every append) — _close_txn re-arms the
                    # sweep when the pin comes off
                    continue
                if now_ms - st.last_ts > cfg.retention_ms:
                    del self.producers[pid]
                elif min_ts is None or st.last_ts < min_ts:
                    min_ts = st.last_ts
            self._pid_deadline = (
                min_ts if min_ts is not None else now_ms
            ) + cfg.retention_ms

    def _expire_producers(self) -> None:
        """Age producer state out with retention: drop runs whose records
        were evicted (trimming a run that straddles the log start), and
        forget pids with nothing retained (Kafka's producer-id expiry).
        Keeps the incrementally-built table identical to what a rebuild
        from the retained log would produce, so leader and followers
        stay in agreement even when one of them reconciled via
        ``truncate_to``/``reset_to`` and the other never did."""
        lso = self.log_start_offset
        for pid in list(self.producers):
            st = self.producers[pid]
            kept: list[list[int]] = []
            for r in st.runs:
                end_off = r[2] + (r[1] - r[0])
                if end_off < lso:
                    continue  # fully evicted
                if r[2] < lso:  # straddles the log start: trim the head
                    r[0] += lso - r[2]
                    r[2] = lso
                kept.append(r)
            if kept:
                st.runs = kept
            else:
                del self.producers[pid]
        # aborted ranges whose marker fell below the log start describe
        # only evicted records; open transactions clamp their start to
        # the log start (the records below it are gone either way)
        self.aborted = [a for a in self.aborted if a[2] >= lso]
        for pid, (first, epoch) in list(self.txn_open.items()):
            if first < lso:
                self.txn_open[pid] = (lso, epoch)

    def size_bytes(self) -> int:
        with self.lock:
            return sum(s.size_bytes for s in self.segments)


class StreamLog:
    """The broker: a set of topics, each a list of partitions.

    Thread-safe. Also hosts the consumer-offset store (Kafka's
    ``__consumer_offsets``) used by :mod:`repro.core.consumer`.
    """

    def __init__(self, clock: Callable[[], float] | None = None,
                 lock_class: str = "log"):
        self._topics: dict[str, list[_Partition]] = {}
        self._configs: dict[str, LogConfig] = {}
        # the controller's internal metadata log nests inside the
        # controller lock, so it carries a distinct lock class
        # ("ctl-log") ranked above it — see repro.analysis.ranks
        self._lock_class = lock_class
        self._lock = make_rlock(lock_class, name=f"{lock_class}@{id(self):x}")
        self._clock = clock or time.time
        # consumer group -> TopicPartition -> committed offset
        self._committed: dict[str, dict[TopicPartition, int]] = {}
        # attachable observability registry (repro.core.metrics
        # MetricsRegistry) — None by default, so a bare log pays one
        # attribute load per append/read; BrokerCluster attaches its
        # cluster-wide registry to every broker's log
        self.metrics = None
        # bound hot-path handles, cached per attached registry: the
        # append/read fast path must not pay a series-key format + dict
        # lookup per call (that alone blows the ≤5% overhead budget)
        self._mcache: tuple | None = None

    def _hot_metrics(self, m) -> tuple:
        """(registry, append_hist, append_ctr, read_hist, read_ctr) for
        the currently attached registry; rebuilt if it was swapped."""
        cache = self._mcache
        if cache is None or cache[0] is not m:
            cache = self._mcache = (
                m,
                m.histogram("log_append_seconds", sample=8),
                m.counter("log_append_records_total"),
                m.histogram("log_read_seconds", sample=8),
                m.counter("log_read_records_total"),
            )
        return cache

    def _now_ms(self) -> int:
        return int(self._clock() * 1000)

    # ------------------------------------------------------------------ admin
    def create_topic(self, name: str, cfg: LogConfig | None = None) -> None:
        with self._lock:
            if name in self._topics:
                raise ValueError(f"topic {name!r} already exists")
            cfg = cfg or LogConfig()
            self._configs[name] = cfg
            self._topics[name] = [
                _Partition(name, i, cfg, self._now_ms,
                           lock_class=self._lock_class + "-part")
                for i in range(cfg.num_partitions)
            ]

    def ensure_topic(self, name: str, cfg: LogConfig | None = None) -> None:
        with self._lock:
            if name not in self._topics:
                self.create_topic(name, cfg)

    def topics(self) -> list[str]:
        with self._lock:
            return sorted(self._topics)

    def num_partitions(self, topic: str) -> int:
        return len(self._partitions(topic))

    def delete_topic(self, name: str) -> None:
        with self._lock:
            self._topics.pop(name, None)
            self._configs.pop(name, None)

    def _partitions(self, topic: str) -> list[_Partition]:
        try:
            return self._topics[topic]
        except KeyError:
            raise KeyError(f"unknown topic {topic!r}") from None

    def _partition(self, topic: str, partition: int) -> _Partition:
        parts = self._partitions(topic)
        if not 0 <= partition < len(parts):
            raise IndexError(f"{topic} has no partition {partition}")
        return parts[partition]

    # ---------------------------------------------------------------- produce
    def produce(
        self,
        topic: str,
        value: bytes,
        *,
        key: bytes | None = None,
        partition: int | None = None,
    ) -> tuple[int, int]:
        """Append one record; returns (partition, offset)."""
        (p, first, _last) = self._produce_batch(topic, [value], [key], partition)
        return p, first

    def produce_batch(
        self,
        topic: str,
        values: Sequence[bytes],
        *,
        keys: Sequence[bytes | None] | None = None,
        partition: int | None = None,
    ) -> tuple[int, int, int]:
        """Append a message set to one partition.

        Returns ``(partition, first_offset, last_offset)``. Batching is the
        paper's "message set abstraction": one index/lock round per batch.
        """
        return self._produce_batch(topic, values, keys, partition)

    def _produce_batch(
        self,
        topic: str,
        values: Sequence[bytes],
        keys: Sequence[bytes | None] | None,
        partition: int | None,
    ) -> tuple[int, int, int]:
        parts = self._partitions(topic)
        if partition is None:
            partition = default_partition(keys, len(parts), self._now_ms())
        part = parts[partition]
        m = self.metrics
        if m is None or not m.enabled:
            first, last = part.append_batch(values, keys)
            return partition, first, last
        _, h_app, c_app, _, _ = self._hot_metrics(m)
        t0 = time.perf_counter()
        first, last = part.append_batch(values, keys)
        h_app.record(time.perf_counter() - t0)
        c_app.inc(len(values))
        return partition, first, last

    # ---------------------------------------------------------------- consume
    def read(
        self,
        topic: str,
        partition: int,
        offset: int,
        max_records: int = 1024,
        isolation: str | None = None,
    ) -> RecordBatch:
        m = self.metrics
        if m is None or not m.enabled:
            return self._partition(topic, partition).read(
                offset, max_records, isolation
            )
        _, _, _, h_read, c_read = self._hot_metrics(m)
        t0 = time.perf_counter()
        batch = self._partition(topic, partition).read(
            offset, max_records, isolation
        )
        h_read.record(time.perf_counter() - t0)
        c_read.inc(len(batch))
        return batch

    def read_one(self, topic: str, partition: int, offset: int) -> Record:
        """Point read of a single record, key included (the metadata-log
        replay path: a controller deserializes one committed command).
        Raises :class:`OffsetOutOfRange` when ``offset`` is past the end
        or was compacted away."""
        part = self._partition(topic, partition)
        with part.lock:
            if part._bounded_count(offset, 1) < 1:
                raise OffsetOutOfRange(
                    f"{topic}:{partition} offset {offset} is past the end"
                )
            seg = part.segments[part._segment_for(offset)]
            if seg.offsets is not None:
                rel = bisect.bisect_left(seg.offsets, offset)
                if rel >= seg.count or seg.offsets[rel] != offset:
                    raise OffsetOutOfRange(
                        f"{topic}:{partition} offset {offset} compacted away"
                    )
            else:
                rel = offset - seg.base_offset
                if rel < 0 or rel >= seg.count:
                    raise OffsetOutOfRange(
                        f"{topic}:{partition} offset {offset} compacted away"
                    )
            return seg.record(topic, partition, rel)

    def offset_for_timestamp(
        self, topic: str, partition: int, ts_ms: int
    ) -> int | None:
        """First retained offset whose record timestamp is >= ``ts_ms``
        (Kafka's ListOffsets-by-timestamp), answered from the sparse time
        index: whole segments are skipped by their ``max_ts``, then the
        per-segment index bisects to a nearby record and a short forward
        scan finishes. Like Kafka's ``.timeindex``, out-of-order
        timestamps BEFORE the indexed position are not revisited. None
        when no retained record is that new."""
        return self._partition(topic, partition).offset_for_timestamp(ts_ms)

    def read_range(
        self, topic: str, partition: int, offset: int, length: int
    ) -> RecordBatch:
        """Read the raw offset window ``[offset, offset + length)``.

        This is the paper's §V access pattern: a control message names
        ``[topic:partition:offset:length]`` and the training job reads
        that exact slice of the distributed log. The window is counted in
        raw offsets — a control marker inside it occupies its offset but
        is (like for every consumer) not delivered, so the batch may hold
        fewer than ``length`` records; stream ranges emitted by ``ingest``
        name data records only and always deliver exactly ``length``.
        """
        batch = self.read(topic, partition, offset, length)
        covered = batch.scanned if batch.scanned is not None else len(batch)
        if covered < length:
            raise OffsetOutOfRange(
                f"{topic}:{partition} range [{offset}, {offset+length}) extends past "
                f"end {self.end_offset(topic, partition)}"
            )
        return batch

    def iter_range(
        self,
        topic: str,
        partition: int,
        offset: int,
        length: int,
        chunk: int = 4096,
    ) -> Iterator[RecordBatch]:
        done = 0
        while done < length:
            take = min(chunk, length - done)
            yield self.read_range(topic, partition, offset + done, take)
            done += take

    def start_offset(self, topic: str, partition: int) -> int:
        return self._partition(topic, partition).log_start_offset

    def end_offset(self, topic: str, partition: int) -> int:
        return self._partition(topic, partition).end_offset

    # ------------------------------------------------------------ replication
    # Broker-to-broker primitives used by repro.core.cluster: a follower
    # fetches raw (value, key) pairs from the leader's log and re-appends
    # them locally; a deposed leader truncates to the new leader's end.
    def replica_fetch(
        self, topic: str, partition: int, offset: int, max_records: int = 4096
    ) -> tuple[
        list[bytes],
        list[bytes | None],
        list[int],
        tuple[list[int], list[int], list[int], list[int]] | None,
        list[int] | None,
        int,
        int | None,
    ]:
        """Fetch raw records for replication: ``(values, keys,
        timestamps, prods, offsets, next_offset, seg_base)``. ``offsets``
        is None for a dense window; ``next_offset`` always advances past
        the covered window, including fully-compacted gaps; ``seg_base``
        is the source segment's base (one response never spans segment
        files — feed it back to :meth:`replica_append` so the replica
        rolls its segments on the leader's boundaries)."""
        return self._partition(topic, partition).fetch_raw(offset, max_records)

    def replica_append(
        self,
        topic: str,
        partition: int,
        values: Sequence[bytes],
        keys: Sequence[bytes | None] | None,
        timestamps: Sequence[int] | int,
        prods: tuple | None = None,
        producer: tuple[int, int, int] | None = None,
        txn: bool = False,
        offsets: Sequence[int] | None = None,
        seg_base: int | None = None,
    ) -> tuple[int, int]:
        """Append records with explicit timestamps (scalar or per-record).

        Used by replication — a follower re-appends fetched leader records
        verbatim so consumers see identical ``Record.timestamp_ms`` before
        and after failover, and ``retention_ms`` (keyed to record
        timestamps in ``_enforce_retention``) expires the same records on
        every replica — and by the cluster's leader-side append, which
        stamps the batch once and pushes the same timestamps to the ISR.

        Producer metadata travels the same two ways: ``prods`` per-record
        (fetched via :meth:`replica_fetch`) or ``producer`` batch-level
        (the acks=all direct ISR push, one run-merge instead of a
        per-record loop). Either keeps the follower's dedup table in step
        with the leader's, so exactly-once survives failover.

        ``offsets`` re-appends the records at their leader-assigned
        logical offsets — required when the fetched range had compaction
        holes — and ``seg_base`` rolls local segments on the leader's
        boundaries (both see :meth:`replica_fetch`)."""
        m = self.metrics
        if m is None or not m.enabled:
            return self._partition(topic, partition).append_batch(
                values, keys, timestamps, prods=prods, producer=producer,
                txn=txn, offsets=offsets, seg_base=seg_base,
            )
        _, h_app, c_app, _, _ = self._hot_metrics(m)
        t0 = time.perf_counter()
        out = self._partition(topic, partition).append_batch(
            values, keys, timestamps, prods=prods, producer=producer,
            txn=txn, offsets=offsets, seg_base=seg_base,
        )
        h_app.record(time.perf_counter() - t0)
        c_app.inc(len(values))
        return out

    def producer_append(
        self,
        topic: str,
        partition: int,
        values: Sequence[bytes],
        keys: Sequence[bytes | None] | None,
        timestamps: Sequence[int] | int,
        pid: int,
        epoch: int,
        seq: int,
        txn: bool = False,
    ) -> tuple[int, int, bool]:
        """Leader-side idempotent append: returns ``(first, last,
        duplicate)``; a retried batch resolves to its original offsets
        with ``duplicate=True`` instead of re-appending. See
        :meth:`_Partition.idempotent_append` for the fencing/ordering
        rules. ``txn=True`` additionally marks the records transactional:
        they stay above the LSO — invisible to read_committed consumers —
        until a control marker resolves their transaction."""
        m = self.metrics
        if m is None or not m.enabled:
            return self._partition(topic, partition).idempotent_append(
                values, keys, timestamps, pid, epoch, seq, txn=txn
            )
        _, h_app, c_app, _, _ = self._hot_metrics(m)
        t0 = time.perf_counter()
        out = self._partition(topic, partition).idempotent_append(
            values, keys, timestamps, pid, epoch, seq, txn=txn
        )
        h_app.record(time.perf_counter() - t0)
        if not out[2]:  # a dedup hit appended nothing
            c_app.inc(len(values))
        return out

    def append_control(
        self, topic: str, partition: int, pid: int, epoch: int, *, abort: bool
    ) -> int | None:
        """Write a COMMIT/ABORT control marker resolving ``pid``'s open
        transaction on the partition; None when nothing is open (the
        idempotent re-drive path of coordinator recovery)."""
        return self._partition(topic, partition).append_control(
            pid, epoch, abort=abort
        )

    def last_stable_offset(self, topic: str, partition: int) -> int:
        """The partition's LSO — the read_committed visibility bound."""
        return self._partition(topic, partition).last_stable_offset()

    def stats(self) -> dict[str, int]:
        """Aggregate substrate stats: segment/retention state and
        producer-state (dedup) table size across every partition.
        Evaluated lazily by metrics gauge callbacks at snapshot time —
        never on the append hot path."""
        out = {
            "partitions": 0,
            "segments": 0,
            "size_bytes": 0,
            "retained_records": 0,
            "producer_state_entries": 0,
            "open_txns": 0,
            "producer_snapshots": 0,
            "index_entries": 0,
        }
        with self._lock:
            parts = [p for ps in self._topics.values() for p in ps]
        for part in parts:
            with part.lock:
                out["partitions"] += 1
                out["segments"] += len(part.segments)
                out["size_bytes"] += sum(s.size_bytes for s in part.segments)
                out["retained_records"] += (
                    part.end_offset - part.log_start_offset
                )
                out["producer_state_entries"] += len(part.producers)
                out["open_txns"] += len(part.txn_open)
                out["producer_snapshots"] += len(part.snapshots)
                out["index_entries"] += sum(
                    len(s.index_offsets) + len(s.index_times)
                    for s in part.segments
                )
        return out

    def open_txns(self, topic: str, partition: int) -> dict[int, int]:
        """pid -> first offset of its open transaction (test/observability
        hook)."""
        part = self._partition(topic, partition)
        with part.lock:
            return {pid: first for pid, (first, _) in part.txn_open.items()}

    def aborted_ranges(self, topic: str, partition: int) -> list[tuple[int, int, int]]:
        """(pid, first, marker_offset) aborted spans (test hook)."""
        part = self._partition(topic, partition)
        with part.lock:
            return list(part.aborted)

    def producer_state(
        self, topic: str, partition: int
    ) -> dict[int, tuple[int, int]]:
        """Snapshot of the partition's dedup table: pid -> (epoch,
        last_seq). Observability/test hook."""
        part = self._partition(topic, partition)
        with part.lock:
            return {
                pid: (st.epoch, st.last_seq)
                for pid, st in part.producers.items()
            }

    # ------------------------------------------------------------- compaction
    def compact(
        self, topic: str, partition: int, horizon: int | None = None
    ) -> dict:
        """Run the log cleaner on one partition (no-op unless its topic
        was created with ``cleanup="compact"``). Returns cleaner stats:
        ``{"horizon", "removed_records", "removed_bytes",
        "rewritten_segments"}``."""
        return self._partition(topic, partition).compact(horizon)

    def compact_to(self, topic: str, partition: int, horizon: int) -> dict:
        """Apply a leader's compact point on a replica (deterministic —
        see :meth:`_Partition.compact_to`)."""
        return self._partition(topic, partition).compact_to(horizon)

    def compact_point(self, topic: str, partition: int) -> int:
        """Everything below this offset is compacted (latest-per-key)."""
        return self._partition(topic, partition).compact_point

    def producer_snapshots(self, topic: str, partition: int) -> list[int]:
        """Offsets of the retained producer-state snapshots (test hook)."""
        part = self._partition(topic, partition)
        with part.lock:
            return [s[0] for s in part.snapshots]

    def txn_index(
        self, topic: str, partition: int
    ) -> list[list[tuple[int, int, int]]]:
        """Per-segment aborted-transaction index contents (test hook)."""
        part = self._partition(topic, partition)
        with part.lock:
            return [list(seg.txn_index) for seg in part.segments]

    def truncate_to(self, topic: str, partition: int, offset: int) -> int:
        """Discard records at ``offset`` and beyond; returns the real new
        end offset (below ``offset`` when the tail was compacted)."""
        return self._partition(topic, partition).truncate_to(offset)

    def reset_to(self, topic: str, partition: int, offset: int) -> int:
        """Restart the partition empty at ``offset`` (replica catch-up
        from below the leader's log start)."""
        return self._partition(topic, partition).reset_to(offset)

    def size_bytes(self, topic: str, partition: int | None = None) -> int:
        parts = self._partitions(topic)
        if partition is not None:
            return parts[partition].size_bytes()
        return sum(p.size_bytes() for p in parts)

    # -------------------------------------------------- consumer offset store
    def commit_offset(self, group: str, tp: TopicPartition, offset: int) -> None:
        with self._lock:
            self._committed.setdefault(group, {})[tp] = offset

    def committed_offset(self, group: str, tp: TopicPartition) -> int | None:
        with self._lock:
            return self._committed.get(group, {}).get(tp)


class StreamBackend(Protocol):
    """Structural type of a data substrate the upper layers accept.

    Both the single-broker :class:`StreamLog` and the replicated
    :class:`repro.core.cluster.BrokerCluster` satisfy it, so the pipeline
    (:mod:`repro.data.pipeline`), consumer groups
    (:mod:`repro.core.consumer`), control plane (:mod:`repro.core.control`),
    trainer and serving engine all run unchanged against either.
    """

    def ensure_topic(self, name: str, cfg: LogConfig | None = None) -> None: ...

    def create_topic(self, name: str, cfg: LogConfig | None = None) -> None: ...

    def topics(self) -> list[str]: ...

    def num_partitions(self, topic: str) -> int: ...

    def produce(
        self,
        topic: str,
        value: bytes,
        *,
        key: bytes | None = None,
        partition: int | None = None,
    ) -> tuple[int, int]: ...

    def produce_batch(
        self,
        topic: str,
        values: Sequence[bytes],
        *,
        keys: Sequence[bytes | None] | None = None,
        partition: int | None = None,
    ) -> tuple[int, int, int]: ...

    def read(
        self,
        topic: str,
        partition: int,
        offset: int,
        max_records: int = 1024,
        isolation: str | None = None,
    ) -> RecordBatch: ...

    def read_range(
        self, topic: str, partition: int, offset: int, length: int
    ) -> RecordBatch: ...

    def iter_range(
        self, topic: str, partition: int, offset: int, length: int, chunk: int = 4096
    ) -> Iterator[RecordBatch]: ...

    def start_offset(self, topic: str, partition: int) -> int: ...

    def end_offset(self, topic: str, partition: int) -> int: ...

    def commit_offset(self, group: str, tp: TopicPartition, offset: int) -> None: ...

    def committed_offset(self, group: str, tp: TopicPartition) -> int | None: ...
