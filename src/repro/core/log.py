"""Distributed log — the Kafka-ML data substrate, JAX-host-native.

Implements the semantics Kafka-ML relies on (paper §II, §V):

* topics split into **partitions**; each partition is an append-only log of
  records addressed by a monotonically increasing **offset**;
* records are retained after consumption (the *distributed log*), so
  consumers can re-read ranges — this is what lets Kafka-ML replay a
  training stream to a new deployment with a tens-of-bytes control message
  instead of re-sending the data;
* **delete retention policy** with ``retention_bytes`` / ``retention_ms``
  (paper §V lists exactly these two knobs; compact policy intentionally
  not offered, as the paper argues delete is the right policy for ML
  streams);
* message-set (batched) appends amortize per-record overhead — the paper's
  "message set abstraction";
* zero-copy reads: records are returned as memoryviews into segment
  buffers ("zero-copy optimizations" in paper §II);
* **idempotent producers** (exactly-once across client retries): each
  partition keeps a producer-state table (pid → epoch, last sequence,
  recent batch runs) derived from (pid, epoch, seq) stamps embedded in
  the records themselves, so ``producer_append`` resolves a retried
  batch to its *original* offsets instead of re-appending, the table
  replicates with the records, and it is rebuilt from the retained log
  after truncation (see DESIGN.md §7);
* **transactions** (DESIGN.md §8): transactional records carry a txn
  flag next to their producer stamp, and COMMIT/ABORT **control
  records** (markers) written by the transaction coordinator resolve
  them. Each partition tracks its open transactions (pid → first
  offset) and its aborted ranges — both, like producer state, derived
  purely from the records in the log, so replicas and post-truncation
  rebuilds agree. ``last_stable_offset`` (LSO) is the first offset of
  the earliest still-open transaction; ``read(...,
  isolation="read_committed")`` caps at the LSO and filters out
  markers and aborted records.

The log is an in-process, host-memory structure (segments are bytearrays)
with optional disk spill. On a TPU pod the broker is colocated with the
host, so a network hop becomes a RAM hop; every *semantic* (offsets,
retention, replay, consumer groups) is preserved — see DESIGN.md §2.
"""

from __future__ import annotations

import bisect
import itertools
import os
import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Callable, Iterator, Protocol, Sequence

import numpy as np

__all__ = [
    "METADATA_TOPIC",
    "LogConfig",
    "OffsetOutOfRange",
    "OutOfOrderSequence",
    "ProducerFenced",
    "Record",
    "RecordBatch",
    "StreamBackend",
    "StreamLog",
    "TopicPartition",
]

# The cluster-metadata topic (KRaft's ``@metadata``): each controller
# node's replicated metadata log is an ordinary StreamLog topic of this
# name — offsets are Raft log indexes and ``truncate_to`` is Raft's
# conflict-suffix truncation. See repro.core.controller.
METADATA_TOPIC = "__cluster_metadata"


class OffsetOutOfRange(LookupError):
    """Requested offset is below the log start (evicted) or past the end."""


class ProducerFenced(RuntimeError):
    """An idempotent append carried a producer epoch older than the one the
    partition (or cluster) has seen — a *zombie*: a prior incarnation of a
    producer whose id was re-initialized with a bumped epoch. Fatal to the
    producer instance (Kafka's PRODUCER_FENCED); deliberately NOT a
    ``ClusterError`` subclass, so client retry loops never re-send a fenced
    batch."""


class OutOfOrderSequence(RuntimeError):
    """An idempotent append's sequence number is neither the next expected
    one, a retry resolvable inside the dedup window, nor a fresh epoch —
    either a gap (records lost between producer and broker) or a duplicate
    too old for the bounded window (Kafka's OUT_OF_ORDER_SEQUENCE_NUMBER /
    DUPLICATE_SEQUENCE_NUMBER). Fatal: acking it could hide loss or
    re-append data."""


# Per-producer dedup window: how many distinct (non-mergeable) batch runs
# each partition remembers per producer id. A synchronous producer has one
# batch in flight, so its retry always hits the newest run; 8 leaves slack
# for pipelined producers (Kafka keeps 5 batch metadata entries).
_MAX_PRODUCER_RUNS = 8

# Per-record control/transaction flag values (the ``ctrls`` arrays):
# 0 = plain record, 1 = transactional data record, 2 = COMMIT marker,
# 3 = ABORT marker. Markers are control records: they occupy offsets and
# replicate like data, but consumers never see them.
CTRL_NONE = 0
CTRL_TXN_DATA = 1
CTRL_COMMIT = 2
CTRL_ABORT = 3

# marker payloads (self-describing; never delivered to consumers)
_COMMIT_MARKER = b"\x00txn:commit"
_ABORT_MARKER = b"\x00txn:abort"


class _ProducerState:
    """Dedup state for one producer id on one partition.

    ``runs`` is a bounded list of ``[first_seq, last_seq, first_offset]``
    spans that are contiguous in *both* sequence and offset, so a retried
    batch fully inside a run maps back to its original offsets by
    arithmetic (``first_offset + (seq - first_seq)``). Because runs are
    derived purely from the records in the log (in log order), a leader
    and its followers — and a truncated log after a rebuild — always agree
    on the same table without shipping snapshots.
    """

    __slots__ = ("epoch", "last_seq", "runs", "last_ts")

    def __init__(self, epoch: int):
        self.epoch = epoch
        self.last_seq = -1
        self.runs: list[list[int]] = []
        # newest record timestamp this pid appended — the retention-clock
        # expiry key (record timestamps replicate verbatim, so every
        # replica ages the same pid out at the same stream time)
        self.last_ts = 0

    def note(
        self, first_seq: int, last_seq: int, first_offset: int, ts: int = 0
    ) -> None:
        """Record an appended span (contiguous in seq and offset)."""
        if ts > self.last_ts:
            self.last_ts = ts
        if self.runs:
            r = self.runs[-1]
            if (
                first_seq == r[1] + 1
                and first_offset == r[2] + (r[1] - r[0]) + 1
            ):
                r[1] = last_seq  # extends the newest run
                self.last_seq = max(self.last_seq, last_seq)
                return
        self.runs.append([first_seq, last_seq, first_offset])
        del self.runs[:-_MAX_PRODUCER_RUNS]
        self.last_seq = max(self.last_seq, last_seq)

    def find(self, seq: int, n: int) -> tuple[int, int] | None:
        """Original (first, last) offsets of a retried batch ``[seq,
        seq+n)``, or None if it is not fully inside a cached run."""
        for r in reversed(self.runs):
            if r[0] <= seq and seq + n - 1 <= r[1]:
                first = r[2] + (seq - r[0])
                return first, first + n - 1
        return None


def default_partition(
    keys: Sequence[bytes | None] | None, nparts: int, now_ms: int
) -> int:
    """Default partitioner shared by every backend: key-hash when the batch
    is keyed, else a time-slot (sticky round-robin-ish). Keeping one
    implementation means a key maps to the same partition on a bare
    StreamLog and on a BrokerCluster.

    The key hash is CRC32, not Python's ``hash()``: ``hash(bytes)`` is
    salted per process (PYTHONHASHSEED), so the same key would land on
    different partitions across producer processes and restarts. A stable
    hash is what makes key→partition routing a durable contract (Kafka
    uses murmur2 for the same reason).
    """
    if keys is not None and keys and keys[0] is not None:
        return zlib.crc32(bytes(keys[0])) % nparts
    return now_ms % nparts


@dataclass(frozen=True)
class TopicPartition:
    """Identifies one partition of one topic (Kafka's TopicPartition)."""

    topic: str
    partition: int

    def __str__(self) -> str:  # [topic:partition] per the paper's format
        return f"{self.topic}:{self.partition}"


@dataclass(frozen=True)
class Record:
    """One record as seen by a consumer."""

    topic: str
    partition: int
    offset: int
    value: memoryview  # zero-copy view into the segment buffer
    key: bytes | None
    timestamp_ms: int

    def value_bytes(self) -> bytes:
        return bytes(self.value)


@dataclass
class LogConfig:
    """Per-topic configuration (mirrors Kafka topic configs)."""

    num_partitions: int = 1
    # delete-retention knobs (paper §V): None ⇒ not applicable
    retention_bytes: int | None = None
    retention_ms: int | None = None
    segment_bytes: int = 8 * 1024 * 1024  # roll segments at this size
    # replication: honored by repro.core.cluster.BrokerCluster; a bare
    # single-host StreamLog keeps these as bookkeeping only. None means
    # "backend default" (1 on a bare log; the cluster's configured defaults
    # on a BrokerCluster) — so a config written for partitioning/retention
    # never silently opts a cluster topic out of replication.
    replication_factor: int | None = None
    min_insync_replicas: int | None = None  # acks=all needs this many in ISR
    # disk spill: sealed (rolled) segments move their payload to an
    # mmap-backed file under spill_dir; reads stay zero-copy (memoryview
    # over the map). Host RAM then holds only the active segment + indexes.
    spill_dir: str | None = None


class _Segment:
    """A contiguous chunk of the partition log.

    Layout: one shared ``bytearray`` holding concatenated record payloads;
    numpy index arrays map relative record index -> (start, length, key
    range, timestamp). Batched appends write once into the buffer.
    """

    __slots__ = (
        "base_offset",
        "buf",
        "buf_len",
        "key_buf",
        "starts",
        "lengths",
        "key_starts",
        "key_lengths",
        "timestamps",
        "pids",
        "peps",
        "pseqs",
        "ctrls",
        "markers",
        "count",
        "created_ms",
        "_spill_file",
        "logical_bytes",
    )

    def __init__(self, base_offset: int, created_ms: int):
        self.base_offset = base_offset
        # the payload buffer over-allocates (doubling growth) and tracks the
        # written prefix in buf_len: appends are a single in-place slice
        # assignment instead of a resize, so a hot 8 MiB segment doesn't
        # re-memcpy itself every few batches (bytearray's native growth
        # factor is ~1.125x) and appends can't hit BufferError from a
        # consumer's outstanding zero-copy view (equal-length slice writes
        # never resize an exported buffer)
        self.buf = bytearray()
        self.buf_len = 0
        self.key_buf = bytearray()
        # python lists while hot; frozen to numpy on roll
        self.starts: list[int] = []
        self.lengths: list[int] = []
        self.key_starts: list[int] = []
        self.key_lengths: list[int] = []
        self.timestamps: list[int] = []
        # per-record producer metadata (pid < 0 ⇒ non-idempotent record):
        # batches carry their (pid, epoch, seq) into the log itself, so a
        # replica — or a rebuild after truncation — derives exactly the
        # same producer-state table the leader built incrementally.
        # Lazily allocated (None until the segment's first stamped
        # record, backfilled with sentinels then), so purely
        # non-idempotent partitions pay nothing per record.
        self.pids: list[int] | None = None
        self.peps: list[int] | None = None
        self.pseqs: list[int] | None = None
        # per-record control/transaction flags (CTRL_*), lazily allocated
        # like the producer metadata: None until the segment holds its
        # first transactional or marker record. ``markers`` counts the
        # control markers among them, so reads of marker-free spans keep
        # the contiguous fast path even on fully-transactional topics
        # (whose every record carries a ctrl flag).
        self.ctrls: list[int] | None = None
        self.markers = 0
        self.count = 0
        self.created_ms = created_ms
        self._spill_file = None
        # retained payload bytes when the physical buffers can't shrink
        # (truncation inside a sealed mmap-backed segment); None = physical
        self.logical_bytes: int | None = None

    @property
    def size_bytes(self) -> int:
        if self.logical_bytes is not None:
            return self.logical_bytes
        return self.buf_len + len(self.key_buf)

    @property
    def last_offset(self) -> int:
        return self.base_offset + self.count - 1

    def append_batch(
        self,
        values: Sequence[bytes | bytearray | memoryview],
        keys: Sequence[bytes | None] | None,
        timestamp_ms: int | Sequence[int],
        prods: tuple[Sequence[int], Sequence[int], Sequence[int]] | None = None,
    ) -> None:
        """Append one message set in bulk: one ``join`` into the shared
        buffer plus list extends, instead of a per-record Python loop —
        the hot path of every produce and every replica push.

        ``prods`` is per-record producer metadata ``(pids, epochs, seqs)``
        (parallel sequences); None extends the non-idempotent sentinel."""
        n = len(values)
        if n == 0:
            return
        pos = self.buf_len
        lens = list(map(len, values))
        starts = list(itertools.accumulate(lens, initial=pos))
        end = starts.pop()  # accumulate also yields the end position
        if end > len(self.buf):
            # preallocate with doubling growth (O(log) total re-copies)
            grow = bytes(max(end - len(self.buf), len(self.buf)))
            try:
                self.buf += grow
            except BufferError:
                # a consumer's zero-copy view pins the current buffer:
                # rebuild instead of resizing (old views stay valid on the
                # old buffer; appends continue on the new one)
                self.buf = self.buf[:] + grow
        self.buf[pos:end] = b"".join(values)
        self.buf_len = end
        self.starts.extend(starts)
        self.lengths.extend(lens)
        kpos = len(self.key_buf)
        if keys is None:
            self.key_starts.extend([kpos] * n)
            self.key_lengths.extend([-1] * n)
        else:
            for k in keys:
                if k is None:
                    self.key_starts.append(kpos)
                    self.key_lengths.append(-1)
                else:
                    self.key_starts.append(kpos)
                    self.key_lengths.append(len(k))
                    self.key_buf += k
                    kpos += len(k)
        if isinstance(timestamp_ms, int):
            self.timestamps.extend([timestamp_ms] * n)
        else:
            self.timestamps.extend(timestamp_ms)
        ctrls = prods[3] if prods is not None and len(prods) > 3 else None
        if prods is not None:
            if self.pids is None:
                # first stamped record: backfill the unstamped prefix
                self.pids = [-1] * self.count
                self.peps = [-1] * self.count
                self.pseqs = [-1] * self.count
            self.pids.extend(prods[0])
            self.peps.extend(prods[1])
            self.pseqs.extend(prods[2])
        elif self.pids is not None:
            self.pids.extend(itertools.repeat(-1, n))
            self.peps.extend(itertools.repeat(-1, n))
            self.pseqs.extend(itertools.repeat(-1, n))
        if ctrls is not None and (self.ctrls is not None or any(ctrls)):
            if self.ctrls is None:
                self.ctrls = [CTRL_NONE] * self.count
            self.ctrls.extend(ctrls)
            self.markers += sum(1 for x in ctrls if x >= CTRL_COMMIT)
        elif self.ctrls is not None:
            self.ctrls.extend(itertools.repeat(CTRL_NONE, n))
        self.count += n

    def record(self, topic: str, partition: int, rel: int) -> Record:
        start = self.starts[rel]
        length = self.lengths[rel]
        klen = self.key_lengths[rel]
        key = (
            None
            if klen < 0
            else bytes(self.key_buf[self.key_starts[rel] : self.key_starts[rel] + klen])
        )
        return Record(
            topic=topic,
            partition=partition,
            offset=self.base_offset + rel,
            value=memoryview(self.buf)[start : start + length],
            key=key,
            timestamp_ms=self.timestamps[rel],
        )

    def spill(self, path: str) -> None:
        """Seal this segment's payload to an mmap-backed file (zero-copy
        reads continue through the map); frees the heap buffer."""
        import mmap

        with open(path, "wb") as f:
            f.write(bytes(memoryview(self.buf)[: self.buf_len]))
            f.flush()
        if self.buf_len == 0:
            return
        fh = open(path, "rb")
        mm = mmap.mmap(fh.fileno(), 0, access=mmap.ACCESS_READ)
        self.buf = mm  # memoryview(mmap) slices stay zero-copy
        self._spill_file = (fh, path)

    def drop_spill(self) -> None:
        sp = getattr(self, "_spill_file", None)
        if sp is not None:
            fh, path = sp
            try:
                self.buf.close() if hasattr(self.buf, "close") else None
            except BufferError:
                pass  # outstanding zero-copy views keep the map alive
            try:
                fh.close()
                os.unlink(path)
            except OSError:
                pass


@dataclass
class RecordBatch:
    """A batch of records read from one partition — supports vectorized decode.

    ``values`` are zero-copy memoryviews; ``to_matrix`` stacks fixed-size
    payloads into a single (n, record_bytes) uint8 array in one pass, the
    fast path used by the training data pipeline.
    """

    topic: str
    partition: int
    first_offset: int
    values: list[memoryview]
    timestamps: list[int]
    # read_committed reads skip control markers and aborted records, so
    # the delivered records may be non-contiguous: ``offsets`` then holds
    # each record's true offset and ``scanned`` how many raw offsets the
    # read consumed (next_offset = first_offset + scanned, so a poll
    # advances past a marker-only span instead of re-reading it forever).
    # Both stay None on the contiguous (raw) read path.
    offsets: list[int] | None = None
    scanned: int | None = None
    # zero-copy framing (DESIGN.md §10): records of one segment are always
    # tightly packed, so the contiguous read path also hands out one
    # ``(payload_view, record_count)`` memoryview per segment span covering
    # the delivered records back to back. Fixed-layout decoders
    # (repro.data.formats) turn a span directly into per-field strided
    # ndarray views — no per-record Python, no copy. None on filtered
    # (marker/aborted-skipping) reads, where delivery is non-contiguous.
    spans: list[tuple[memoryview, int]] | None = None

    def __len__(self) -> int:
        return len(self.values)

    @property
    def next_offset(self) -> int:
        if self.scanned is not None:
            return self.first_offset + self.scanned
        return self.first_offset + len(self.values)

    def framed(self, record_bytes: int) -> list[tuple[memoryview, int]] | None:
        """The batch's contiguous spans, validated for fixed-layout decode
        at ``record_bytes`` per record: every delivered record accounted
        for, every span exactly ``count * record_bytes`` long. None when
        the batch came off a filtered read (no spans) or the records are
        not the expected fixed size — callers then fall back to the
        copying :meth:`to_matrix` path."""
        if self.spans is None or record_bytes <= 0:
            return None
        if sum(n for _, n in self.spans) != len(self.values):
            return None
        for mv, n in self.spans:
            if mv.nbytes != n * record_bytes:
                return None
        return self.spans

    def to_matrix(self) -> np.ndarray:
        if not self.values:
            return np.zeros((0, 0), dtype=np.uint8)
        n = len(self.values[0])
        if any(len(v) != n for v in self.values):
            raise ValueError("to_matrix requires fixed-size records")
        spans = self.framed(n)
        if spans is not None:
            # contiguous fixed-size records: bulk row-block copies (one
            # per segment span) instead of a per-record loop
            out = np.empty((len(self.values), n), dtype=np.uint8)
            row = 0
            for mv, cnt in spans:
                out[row : row + cnt] = np.frombuffer(mv, np.uint8).reshape(cnt, n)
                row += cnt
            return out
        out = np.empty((len(self.values), n), dtype=np.uint8)
        for i, v in enumerate(self.values):
            out[i] = np.frombuffer(v, dtype=np.uint8)
        return out


class _Partition:
    def __init__(self, topic: str, index: int, cfg: LogConfig, clock: Callable[[], int]):
        self.topic = topic
        self.index = index
        self.cfg = cfg
        self.clock = clock
        self.segments: list[_Segment] = [_Segment(0, clock())]
        self.log_start_offset = 0  # first retained offset
        # pid -> dedup state; derived purely from the records in the log
        # (their embedded (pid, epoch, seq) metadata), kept incrementally
        # on every append and rebuilt from the retained log after
        # truncation — so leader, followers and a reconciled rejoiner all
        # hold the same table. The window is additionally bounded by
        # retention: a pid whose records were all evicted starts fresh
        # (Kafka's producer-id expiry).
        self.producers: dict[int, _ProducerState] = {}
        # transaction state, derived purely from the records (txn flags +
        # control markers), exactly like the producer table above:
        #   txn_open: pid -> (first offset of its open txn, producer epoch)
        #   aborted:  [(pid, first_offset, marker_offset), ...] — records
        #             of `pid` in [first, marker) belong to an aborted
        #             transaction and are invisible at read_committed
        self.txn_open: dict[int, tuple[int, int]] = {}
        self.aborted: list[tuple[int, int, int]] = []
        # earliest time the retention-clock pid expiry could next fire
        # (min last_ts + retention_ms, recomputed by each sweep): keeps
        # the expiry scan off the per-append hot path
        self._pid_deadline = 0
        self.lock = threading.RLock()

    # ------------------------------------------------------------------ write
    def append_batch(
        self,
        values: Sequence[bytes],
        keys: Sequence[bytes | None] | None,
        timestamps: Sequence[int] | None = None,
        prods: tuple | None = None,
        producer: tuple[int, int, int] | None = None,
        txn: bool = False,
    ) -> tuple[int, int]:
        """Append a message set; returns (first_offset, last_offset).

        ``timestamps`` is passed by replication only: a follower re-appends
        leader records with their original timestamps so replicas agree on
        time-based retention and on what consumers observe after failover.

        Producer metadata rides the same way: ``producer=(pid, epoch,
        base_seq)`` stamps one batch (leader append / direct ISR push —
        sequences run ``base_seq..base_seq+n-1``), while ``prods`` carries
        per-record metadata fetched from another replica's log. Either
        path updates this partition's dedup table as a side effect; the
        *checks* (fencing, dedup, gap detection) live in
        :meth:`idempotent_append` — replication never re-validates, leader
        order is law.
        """
        with self.lock:
            now = self.clock()
            n = len(values)
            if producer is not None:
                pid, pep, seq = producer
                # lazy C-level iterables: the segment extends consume them
                # without materializing intermediate lists (hot path);
                # the ctrl column is only materialized for transactional
                # batches, so plain idempotent produce stays flag-free
                prods = (
                    itertools.repeat(pid, n),
                    itertools.repeat(pep, n),
                    range(seq, seq + n),
                    [CTRL_TXN_DATA] * n if txn else None,
                )
            seg = self.segments[-1]
            if seg.size_bytes >= self.cfg.segment_bytes and seg.count > 0:
                if self.cfg.spill_dir is not None:  # seal -> mmap-backed file
                    os.makedirs(self.cfg.spill_dir, exist_ok=True)
                    seg.spill(os.path.join(
                        self.cfg.spill_dir,
                        f"{self.topic}-{self.index}-{seg.base_offset}.seg",
                    ))
                seg = _Segment(seg.base_offset + seg.count, now)
                self.segments.append(seg)
            first = seg.base_offset + seg.count
            seg.append_batch(
                values, keys, now if timestamps is None else timestamps, prods
            )
            if producer is not None:
                # one contiguous batch: a single run merge, off the
                # per-record path (the acks=all hot path pushes batches)
                ts = timestamps if timestamps is None or isinstance(
                    timestamps, int
                ) else (timestamps[-1] if len(timestamps) else None)
                self._note_producer_run(
                    pid, pep, seq, seq + n - 1, first,
                    now if ts is None else ts,
                )
                if txn:
                    self._open_txn(pid, pep, first)
            elif prods is not None:
                self._note_producer_records(
                    prods, first, now if timestamps is None else timestamps
                )
            self._enforce_retention(now)
            return first, seg.last_offset

    # ------------------------------------------------------ producer state
    def _producer_state(self, pid: int, epoch: int) -> _ProducerState | None:
        """State for ``pid`` at ``epoch``; a newer epoch resets the dedup
        window (an epoch bump restarts sequence numbering), an older one
        returns None (the record predates the current incarnation)."""
        st = self.producers.get(pid)
        if st is None or epoch > st.epoch:
            st = _ProducerState(epoch)
            self.producers[pid] = st
        elif epoch < st.epoch:
            return None
        return st

    def _note_producer_run(
        self,
        pid: int,
        epoch: int,
        first_seq: int,
        last_seq: int,
        first_off: int,
        ts: int = 0,
    ) -> None:
        st = self._producer_state(pid, epoch)
        if st is not None:
            st.note(first_seq, last_seq, first_off, ts)

    def _note_producer_records(
        self,
        prods: tuple,
        first_off: int,
        timestamps: Sequence[int] | int = 0,
    ) -> None:
        """Replication path: fold per-record metadata into the table.
        Consecutive records merge into the same runs the source built, so
        replica tables converge on the leader's. Control flags replay the
        transaction state machine the same way: a txn-flagged record
        opens its pid's transaction, a marker closes (or aborts) it."""
        pids, peps, pseqs = prods[0], prods[1], prods[2]
        ctrls = prods[3] if len(prods) > 3 else None
        scalar_ts = timestamps if isinstance(timestamps, int) else None
        for i, pid in enumerate(pids):
            if pid < 0:
                continue
            ctrl = ctrls[i] if ctrls is not None else CTRL_NONE
            if ctrl >= CTRL_COMMIT:
                self._close_txn(
                    pid, peps[i], first_off + i, abort=ctrl == CTRL_ABORT
                )
                continue
            ts = scalar_ts if scalar_ts is not None else timestamps[i]
            self._note_producer_run(
                pid, peps[i], pseqs[i], pseqs[i], first_off + i, ts
            )
            if ctrl == CTRL_TXN_DATA:
                self._open_txn(pid, peps[i], first_off + i)

    def _rebuild_producer_state(self) -> None:
        """Re-derive the dedup table — and the transaction state — from
        the retained log (after ``truncate_to``): state for truncated
        records must disappear — their batches are gone, so a retry must
        re-append, not dedup against offsets that no longer hold them,
        and a truncated marker must re-open the transaction it closed."""
        self.producers = {}
        self.txn_open = {}
        self.aborted = []
        self._pid_deadline = 0  # rebuilt state may hold older timestamps
        for seg in self.segments:
            pids = seg.pids
            if pids is None:
                continue  # segment never saw a stamped record
            base = seg.base_offset
            ctrls = seg.ctrls
            for r in range(seg.count):
                if pids[r] < 0:
                    continue
                ctrl = ctrls[r] if ctrls is not None else CTRL_NONE
                if ctrl >= CTRL_COMMIT:
                    self._close_txn(
                        pids[r], seg.peps[r], base + r,
                        abort=ctrl == CTRL_ABORT,
                    )
                    continue
                self._note_producer_run(
                    pids[r], seg.peps[r], seg.pseqs[r], seg.pseqs[r],
                    base + r, seg.timestamps[r],
                )
                if ctrl == CTRL_TXN_DATA:
                    self._open_txn(pids[r], seg.peps[r], base + r)

    # ------------------------------------------------------ transactions
    def _open_txn(self, pid: int, epoch: int, offset: int) -> None:
        """First transactional record of a (pid, epoch) transaction pins
        the partition's LSO at its offset until a marker resolves it."""
        cur = self.txn_open.get(pid)
        if cur is None:
            self.txn_open[pid] = (offset, epoch)
        elif epoch > cur[1]:
            # a newer incarnation appended before the old txn's marker
            # arrived (abnormal interleaving): keep the earliest offset —
            # the LSO must not advance past unresolved records
            self.txn_open[pid] = (cur[0], epoch)

    def _close_txn(
        self, pid: int, epoch: int, marker_off: int, *, abort: bool
    ) -> None:
        cur = self.txn_open.get(pid)
        if cur is None or epoch < cur[1]:
            return  # stale marker: never resolves a newer incarnation
        del self.txn_open[pid]
        # the pid is no longer pinned: re-arm the retention-clock expiry
        # sweep so a long-pinned idle pid is reconsidered promptly
        self._pid_deadline = 0
        if abort:
            self.aborted.append((pid, cur[0], marker_off))

    def append_control(
        self, pid: int, epoch: int, *, abort: bool
    ) -> int | None:
        """Write a COMMIT/ABORT marker resolving ``pid``'s open
        transaction; returns the marker's offset, or None when the pid
        has no open transaction at ``epoch`` or newer here — which makes
        coordinator-recovery re-drives idempotent (the second marker
        write for an already-resolved partition is a no-op, not a
        duplicate marker)."""
        with self.lock:
            cur = self.txn_open.get(pid)
            if cur is None or cur[1] > epoch:
                return None
            value = _ABORT_MARKER if abort else _COMMIT_MARKER
            ctrl = CTRL_ABORT if abort else CTRL_COMMIT
            first, _last = self.append_batch(
                [value], None, prods=([pid], [epoch], [-1], [ctrl])
            )
            return first

    def last_stable_offset(self) -> int:
        """First offset of the earliest open transaction (Kafka's LSO):
        records at or above it are not yet stable — their transaction may
        still abort — so read_committed consumers stop here."""
        with self.lock:
            if not self.txn_open:
                return self.end_offset
            return min(first for first, _ in self.txn_open.values())

    def idempotent_append(
        self,
        values: Sequence[bytes],
        keys: Sequence[bytes | None] | None,
        timestamps: Sequence[int] | int | None,
        pid: int,
        epoch: int,
        seq: int,
        txn: bool = False,
    ) -> tuple[int, int, bool]:
        """Leader-side idempotent append: dedup + fencing + gap detection.

        Returns ``(first, last, duplicate)``. A retried batch whose
        sequences are already in the log returns the **original** offsets
        with ``duplicate=True`` instead of re-appending — the exactly-once
        contract across client retries. Raises :class:`ProducerFenced` for
        a stale epoch and :class:`OutOfOrderSequence` for a gap or a
        duplicate older than the dedup window.
        """
        with self.lock:
            n = len(values)
            st = self.producers.get(pid)
            if st is not None:
                if epoch < st.epoch:
                    raise ProducerFenced(
                        f"{self.topic}:{self.index} producer {pid} epoch "
                        f"{epoch} fenced by newer epoch {st.epoch}"
                    )
                if epoch == st.epoch and st.last_seq >= 0:
                    hit = st.find(seq, n)
                    if hit is not None:
                        return hit[0], hit[1], True
                    if seq <= st.last_seq:
                        raise OutOfOrderSequence(
                            f"{self.topic}:{self.index} producer {pid} "
                            f"sequence {seq} already appended but outside "
                            f"the dedup window (last_seq {st.last_seq})"
                        )
                    if seq != st.last_seq + 1:
                        raise OutOfOrderSequence(
                            f"{self.topic}:{self.index} producer {pid} "
                            f"sequence gap: expected {st.last_seq + 1}, "
                            f"got {seq}"
                        )
            first, last = self.append_batch(
                values, keys, timestamps, producer=(pid, epoch, seq), txn=txn
            )
            return first, last, False

    # ------------------------------------------------------------------- read
    @property
    def end_offset(self) -> int:
        # taken under the partition lock so a concurrent append's segment
        # roll can't be observed half-applied (the lock is reentrant, so
        # read paths that already hold it are unaffected)
        with self.lock:
            seg = self.segments[-1]
            return seg.base_offset + seg.count

    def _bounded_count(self, offset: int, max_records: int) -> int:
        """Validate ``offset`` against [log start, end]; return how many
        records a read starting there may return."""
        if offset < self.log_start_offset:
            raise OffsetOutOfRange(
                f"{self.topic}:{self.index} offset {offset} < log start "
                f"{self.log_start_offset} (evicted by retention)"
            )
        end = self.end_offset
        if offset > end:
            raise OffsetOutOfRange(
                f"{self.topic}:{self.index} offset {offset} > end {end}"
            )
        return min(max_records, end - offset)

    def _iter_spans(self, offset: int, n: int):
        """Yield ``(segment, rel_start, rel_stop)`` spans covering records
        ``[offset, offset + n)`` — the one segment walk shared by consumer
        reads and replication fetches."""
        si = self._segment_for(offset)
        off = offset
        remaining = n
        while remaining > 0:
            seg = self.segments[si]
            rel = off - seg.base_offset
            take = min(remaining, seg.count - rel)
            if take > 0:
                yield seg, rel, rel + take
            remaining -= take
            off += take
            si += 1

    def read(
        self, offset: int, max_records: int, isolation: str | None = None
    ) -> RecordBatch:
        if isolation == "read_committed":
            return self._read_committed(offset, max_records)
        with self.lock:
            n = self._bounded_count(offset, max_records)
            spans = list(self._iter_spans(offset, n))
            if any(seg.markers for seg, _, _ in spans):
                # a control marker may sit in range — consumers never see
                # control records at ANY isolation level (a raw reader
                # handed marker bytes as a data record would crash on
                # them); read_uncommitted still delivers not-yet-resolved
                # and aborted transactional data. Marker-free spans (the
                # overwhelming majority even on transactional topics)
                # stay on the contiguous fast path below.
                return self._read_filtered(
                    offset, n, spans, skip_aborted=False
                )
            values: list[memoryview] = []
            timestamps: list[int] = []
            payload_spans: list[tuple[memoryview, int]] = []
            for seg, lo, hi in spans:
                mv = memoryview(seg.buf)
                for r in range(lo, hi):
                    start = seg.starts[r]
                    values.append(mv[start : start + seg.lengths[r]])
                    timestamps.append(seg.timestamps[r])
                # records of one segment are tightly packed (starts are
                # cumulative lengths), so the whole [lo, hi) span is ONE
                # contiguous byte range — exported as a single view for
                # zero-copy fixed-layout decode (RecordBatch.framed)
                end = seg.starts[hi - 1] + seg.lengths[hi - 1]
                payload_spans.append((mv[seg.starts[lo] : end], hi - lo))
            return RecordBatch(
                topic=self.topic,
                partition=self.index,
                first_offset=offset,
                values=values,
                timestamps=timestamps,
                spans=payload_spans,
            )

    def _read_committed(self, offset: int, max_records: int) -> RecordBatch:
        """Read capped at the LSO, with control markers and aborted
        records filtered out."""
        with self.lock:
            n = self._bounded_count(offset, max_records)
            n = min(n, max(self.last_stable_offset() - offset, 0))
            return self._read_filtered(
                offset, n, list(self._iter_spans(offset, n)),
                skip_aborted=True,
            )

    def _read_filtered(
        self, offset: int, n: int, spans: list, skip_aborted: bool
    ) -> RecordBatch:
        """Read with control markers filtered out — plus, at
        read_committed (``skip_aborted``), aborted transactions' records.
        The returned batch carries explicit per-record ``offsets`` and
        the raw ``scanned`` count, so the consumer's next position
        advances past filtered spans. Caller holds the partition lock."""
        values: list[memoryview] = []
        timestamps: list[int] = []
        offsets: list[int] = []
        abort_ranges: dict[int, list[tuple[int, int]]] = {}
        if skip_aborted:
            hi = offset + n
            for pid, first, marker in self.aborted:
                # only ranges overlapping the read window matter; the
                # prefilter keeps the per-record check short on long
                # partitions with many historical aborts. (A per-segment
                # aborted-txn index — Kafka's .txnindex — is the
                # follow-up for truly huge retained partitions.)
                if first < hi and marker > offset:
                    abort_ranges.setdefault(pid, []).append((first, marker))
        for seg, lo, hi in spans:
            mv = memoryview(seg.buf)
            ctrls = seg.ctrls
            for r in range(lo, hi):
                ctrl = ctrls[r] if ctrls is not None else CTRL_NONE
                if ctrl >= CTRL_COMMIT:
                    continue  # control marker: never delivered
                if skip_aborted and ctrl == CTRL_TXN_DATA:
                    off = seg.base_offset + r
                    ab = abort_ranges.get(seg.pids[r])
                    if ab is not None and any(a <= off < b for a, b in ab):
                        continue  # aborted transaction's record
                start = seg.starts[r]
                values.append(mv[start : start + seg.lengths[r]])
                timestamps.append(seg.timestamps[r])
                offsets.append(seg.base_offset + r)
        return RecordBatch(
            topic=self.topic,
            partition=self.index,
            first_offset=offset,
            values=values,
            timestamps=timestamps,
            offsets=offsets,
            scanned=n,
        )

    def _segment_for(self, offset: int) -> int:
        bases = [s.base_offset for s in self.segments]
        i = bisect.bisect_right(bases, offset) - 1
        return max(i, 0)

    def fetch_raw(
        self, offset: int, max_records: int
    ) -> tuple[
        list[bytes],
        list[bytes | None],
        list[int],
        tuple[list[int], list[int], list[int], list[int]] | None,
    ]:
        """Replication fetch: materialized (values, keys, timestamps,
        producer metadata) so a follower can re-append them verbatim to
        its copy of the partition — including the (pid, epoch, seq)
        stamps its dedup table is derived from, and the control flags its
        transaction state is derived from."""
        with self.lock:
            n = self._bounded_count(offset, max_records)
            values: list[bytes] = []
            keys: list[bytes | None] = []
            timestamps: list[int] = []
            pids: list[int] = []
            peps: list[int] = []
            pseqs: list[int] = []
            ctrls: list[int] = []
            spans = list(self._iter_spans(offset, n))
            # None unless some record in range is stamped, so followers of
            # purely non-idempotent partitions append lazily too
            stamped = any(seg.pids is not None for seg, _, _ in spans)
            for seg, lo, hi in spans:
                for r in range(lo, hi):
                    start = seg.starts[r]
                    values.append(bytes(seg.buf[start : start + seg.lengths[r]]))
                    klen = seg.key_lengths[r]
                    ks = seg.key_starts[r]
                    keys.append(
                        None if klen < 0 else bytes(seg.key_buf[ks : ks + klen])
                    )
                    timestamps.append(seg.timestamps[r])
                if not stamped:
                    continue
                if seg.pids is None:
                    pids.extend(itertools.repeat(-1, hi - lo))
                    peps.extend(itertools.repeat(-1, hi - lo))
                    pseqs.extend(itertools.repeat(-1, hi - lo))
                else:
                    pids.extend(seg.pids[lo:hi])
                    peps.extend(seg.peps[lo:hi])
                    pseqs.extend(seg.pseqs[lo:hi])
                if seg.ctrls is None:
                    ctrls.extend(itertools.repeat(CTRL_NONE, hi - lo))
                else:
                    ctrls.extend(seg.ctrls[lo:hi])
            return (
                values, keys, timestamps,
                (pids, peps, pseqs, ctrls) if stamped else None,
            )

    def reset_to(self, offset: int) -> int:
        """Discard the entire partition contents and restart the log at
        ``offset`` (a follower that fell behind the leader's retention point
        re-fetches from the leader's log start)."""
        with self.lock:
            for s in self.segments:
                s.drop_spill()
            self.segments = [_Segment(offset, self.clock())]
            self.log_start_offset = offset
            # the log is empty: dedup and transaction state rebuild as
            # records re-fetch (replica_append carries their metadata)
            self.producers = {}
            self.txn_open = {}
            self.aborted = []
            self._pid_deadline = 0
            return offset

    def truncate_to(self, offset: int) -> int:
        """Discard every record at ``offset`` and beyond (post-failover log
        reconciliation: a deposed leader truncates to the new leader's end
        before re-fetching). Returns the new end offset."""
        with self.lock:
            if offset >= self.end_offset:
                return self.end_offset
            if offset < self.log_start_offset:
                # nothing retained below the truncation point — reset the
                # partition; the follower re-fetches from `offset` upward
                return self.reset_to(offset)
            while self.segments and self.segments[-1].base_offset >= offset:
                self.segments.pop().drop_spill()
            if not self.segments:
                self.segments = [_Segment(offset, self.clock())]
                self._rebuild_producer_state()
                return offset
            seg = self.segments[-1]
            rel = offset - seg.base_offset
            if rel < seg.count:
                if isinstance(seg.buf, bytearray):
                    # drop the truncated records' payload too, or it stays
                    # resident and skews size_bytes/retention accounting.
                    # Rebuild rather than resize in place: outstanding
                    # zero-copy reads may hold memoryview exports of the
                    # old buffer, and resizing an exported bytearray raises
                    # BufferError. The old buffer lives until those views
                    # are dropped; new appends go to the rebuilt one.
                    seg.buf = seg.buf[: seg.starts[rel]]
                    seg.buf_len = seg.starts[rel]
                    seg.key_buf = seg.key_buf[: seg.key_starts[rel]]
                else:
                    # sealed mmap segment: can't shrink the map — record the
                    # retained payload so size_bytes/retention stay honest
                    seg.logical_bytes = seg.starts[rel] + seg.key_starts[rel]
                del seg.starts[rel:]
                del seg.lengths[rel:]
                del seg.key_starts[rel:]
                del seg.key_lengths[rel:]
                del seg.timestamps[rel:]
                if seg.pids is not None:
                    del seg.pids[rel:]
                    del seg.peps[rel:]
                    del seg.pseqs[rel:]
                if seg.ctrls is not None:
                    seg.markers -= sum(
                        1 for x in seg.ctrls[rel:] if x >= CTRL_COMMIT
                    )
                    del seg.ctrls[rel:]
                seg.count = rel
            if seg._spill_file is not None:
                # sealed/spilled segments are read-only maps — appendable
                # writes need a fresh heap-backed active segment
                self.segments.append(_Segment(offset, self.clock()))
            # dedup state for the truncated suffix must not survive it: a
            # deposed leader that rejoins (leader-epoch reconciliation)
            # re-derives the table from what the log still holds, so its
            # table converges with the new leader's as it re-fetches
            self._rebuild_producer_state()
            return offset

    # -------------------------------------------------------------- retention
    def _enforce_retention(self, now_ms: int) -> None:
        cfg = self.cfg
        evicted = False
        # never evict the active (last) segment
        while len(self.segments) > 1:
            head = self.segments[0]
            evict = False
            if cfg.retention_bytes is not None:
                total = sum(s.size_bytes for s in self.segments)
                if total > cfg.retention_bytes:
                    evict = True
            if not evict and cfg.retention_ms is not None:
                # age by the segment's newest record timestamp (Kafka's
                # retention.ms semantics). Record timestamps replicate
                # verbatim, so leader and followers expire the same
                # records at the same time regardless of when each broker
                # physically fetched them; created_ms is only a fallback
                # for empty segments.
                age_ref = head.timestamps[-1] if head.timestamps else head.created_ms
                if now_ms - age_ref > cfg.retention_ms:
                    evict = True
            if not evict:
                break
            self.segments.pop(0).drop_spill()
            self.log_start_offset = self.segments[0].base_offset
            evicted = True
        if evicted:
            self._expire_producers()
        if (
            cfg.retention_ms is not None
            and self.producers
            and now_ms > self._pid_deadline
        ):
            # retention-clock pid expiry: a long-idle producer id is
            # forgotten once its newest record timestamp ages past
            # retention_ms — even while its records still sit in the
            # never-evicted active segment. Keyed to record timestamps
            # (which replicate verbatim), not to table size or local
            # fetch time, so every replica expires the same pids at the
            # same stream time (Kafka's producer-id expiration). The
            # sweep runs only when the cached deadline (earliest possible
            # expiry) passes — never on every append. New pids appended
            # after a sweep carry newer timestamps than its minimum on
            # the leader; a follower replaying older stamps may retain a
            # pid up to one retention period longer (extra dedup state:
            # the safe direction).
            min_ts = None
            for pid in list(self.producers):
                st = self.producers[pid]
                if pid in self.txn_open:
                    # an open txn pins its pid; excluded from the
                    # deadline too (its stale last_ts would otherwise
                    # drag the deadline into the past and re-run this
                    # sweep on every append) — _close_txn re-arms the
                    # sweep when the pin comes off
                    continue
                if now_ms - st.last_ts > cfg.retention_ms:
                    del self.producers[pid]
                elif min_ts is None or st.last_ts < min_ts:
                    min_ts = st.last_ts
            self._pid_deadline = (
                min_ts if min_ts is not None else now_ms
            ) + cfg.retention_ms

    def _expire_producers(self) -> None:
        """Age producer state out with retention: drop runs whose records
        were evicted (trimming a run that straddles the log start), and
        forget pids with nothing retained (Kafka's producer-id expiry).
        Keeps the incrementally-built table identical to what a rebuild
        from the retained log would produce, so leader and followers
        stay in agreement even when one of them reconciled via
        ``truncate_to``/``reset_to`` and the other never did."""
        lso = self.log_start_offset
        for pid in list(self.producers):
            st = self.producers[pid]
            kept: list[list[int]] = []
            for r in st.runs:
                end_off = r[2] + (r[1] - r[0])
                if end_off < lso:
                    continue  # fully evicted
                if r[2] < lso:  # straddles the log start: trim the head
                    r[0] += lso - r[2]
                    r[2] = lso
                kept.append(r)
            if kept:
                st.runs = kept
            else:
                del self.producers[pid]
        # aborted ranges whose marker fell below the log start describe
        # only evicted records; open transactions clamp their start to
        # the log start (the records below it are gone either way)
        self.aborted = [a for a in self.aborted if a[2] >= lso]
        for pid, (first, epoch) in list(self.txn_open.items()):
            if first < lso:
                self.txn_open[pid] = (lso, epoch)

    def size_bytes(self) -> int:
        with self.lock:
            return sum(s.size_bytes for s in self.segments)


class StreamLog:
    """The broker: a set of topics, each a list of partitions.

    Thread-safe. Also hosts the consumer-offset store (Kafka's
    ``__consumer_offsets``) used by :mod:`repro.core.consumer`.
    """

    def __init__(self, clock: Callable[[], float] | None = None):
        self._topics: dict[str, list[_Partition]] = {}
        self._configs: dict[str, LogConfig] = {}
        self._lock = threading.RLock()
        self._clock = clock or time.time
        # consumer group -> TopicPartition -> committed offset
        self._committed: dict[str, dict[TopicPartition, int]] = {}
        # attachable observability registry (repro.core.metrics
        # MetricsRegistry) — None by default, so a bare log pays one
        # attribute load per append/read; BrokerCluster attaches its
        # cluster-wide registry to every broker's log
        self.metrics = None
        # bound hot-path handles, cached per attached registry: the
        # append/read fast path must not pay a series-key format + dict
        # lookup per call (that alone blows the ≤5% overhead budget)
        self._mcache: tuple | None = None

    def _hot_metrics(self, m) -> tuple:
        """(registry, append_hist, append_ctr, read_hist, read_ctr) for
        the currently attached registry; rebuilt if it was swapped."""
        cache = self._mcache
        if cache is None or cache[0] is not m:
            cache = self._mcache = (
                m,
                m.histogram("log_append_seconds", sample=8),
                m.counter("log_append_records_total"),
                m.histogram("log_read_seconds", sample=8),
                m.counter("log_read_records_total"),
            )
        return cache

    def _now_ms(self) -> int:
        return int(self._clock() * 1000)

    # ------------------------------------------------------------------ admin
    def create_topic(self, name: str, cfg: LogConfig | None = None) -> None:
        with self._lock:
            if name in self._topics:
                raise ValueError(f"topic {name!r} already exists")
            cfg = cfg or LogConfig()
            self._configs[name] = cfg
            self._topics[name] = [
                _Partition(name, i, cfg, self._now_ms)
                for i in range(cfg.num_partitions)
            ]

    def ensure_topic(self, name: str, cfg: LogConfig | None = None) -> None:
        with self._lock:
            if name not in self._topics:
                self.create_topic(name, cfg)

    def topics(self) -> list[str]:
        with self._lock:
            return sorted(self._topics)

    def num_partitions(self, topic: str) -> int:
        return len(self._partitions(topic))

    def delete_topic(self, name: str) -> None:
        with self._lock:
            self._topics.pop(name, None)
            self._configs.pop(name, None)

    def _partitions(self, topic: str) -> list[_Partition]:
        try:
            return self._topics[topic]
        except KeyError:
            raise KeyError(f"unknown topic {topic!r}") from None

    def _partition(self, topic: str, partition: int) -> _Partition:
        parts = self._partitions(topic)
        if not 0 <= partition < len(parts):
            raise IndexError(f"{topic} has no partition {partition}")
        return parts[partition]

    # ---------------------------------------------------------------- produce
    def produce(
        self,
        topic: str,
        value: bytes,
        *,
        key: bytes | None = None,
        partition: int | None = None,
    ) -> tuple[int, int]:
        """Append one record; returns (partition, offset)."""
        (p, first, _last) = self._produce_batch(topic, [value], [key], partition)
        return p, first

    def produce_batch(
        self,
        topic: str,
        values: Sequence[bytes],
        *,
        keys: Sequence[bytes | None] | None = None,
        partition: int | None = None,
    ) -> tuple[int, int, int]:
        """Append a message set to one partition.

        Returns ``(partition, first_offset, last_offset)``. Batching is the
        paper's "message set abstraction": one index/lock round per batch.
        """
        return self._produce_batch(topic, values, keys, partition)

    def _produce_batch(
        self,
        topic: str,
        values: Sequence[bytes],
        keys: Sequence[bytes | None] | None,
        partition: int | None,
    ) -> tuple[int, int, int]:
        parts = self._partitions(topic)
        if partition is None:
            partition = default_partition(keys, len(parts), self._now_ms())
        part = parts[partition]
        m = self.metrics
        if m is None or not m.enabled:
            first, last = part.append_batch(values, keys)
            return partition, first, last
        _, h_app, c_app, _, _ = self._hot_metrics(m)
        t0 = time.perf_counter()
        first, last = part.append_batch(values, keys)
        h_app.record(time.perf_counter() - t0)
        c_app.inc(len(values))
        return partition, first, last

    # ---------------------------------------------------------------- consume
    def read(
        self,
        topic: str,
        partition: int,
        offset: int,
        max_records: int = 1024,
        isolation: str | None = None,
    ) -> RecordBatch:
        m = self.metrics
        if m is None or not m.enabled:
            return self._partition(topic, partition).read(
                offset, max_records, isolation
            )
        _, _, _, h_read, c_read = self._hot_metrics(m)
        t0 = time.perf_counter()
        batch = self._partition(topic, partition).read(
            offset, max_records, isolation
        )
        h_read.record(time.perf_counter() - t0)
        c_read.inc(len(batch))
        return batch

    def read_one(self, topic: str, partition: int, offset: int) -> Record:
        """Point read of a single record, key included (the metadata-log
        replay path: a controller deserializes one committed command)."""
        part = self._partition(topic, partition)
        with part.lock:
            if part._bounded_count(offset, 1) < 1:
                raise OffsetOutOfRange(
                    f"{topic}:{partition} offset {offset} is past the end"
                )
            seg = part.segments[part._segment_for(offset)]
            return seg.record(topic, partition, offset - seg.base_offset)

    def read_range(
        self, topic: str, partition: int, offset: int, length: int
    ) -> RecordBatch:
        """Read the raw offset window ``[offset, offset + length)``.

        This is the paper's §V access pattern: a control message names
        ``[topic:partition:offset:length]`` and the training job reads
        that exact slice of the distributed log. The window is counted in
        raw offsets — a control marker inside it occupies its offset but
        is (like for every consumer) not delivered, so the batch may hold
        fewer than ``length`` records; stream ranges emitted by ``ingest``
        name data records only and always deliver exactly ``length``.
        """
        batch = self.read(topic, partition, offset, length)
        covered = batch.scanned if batch.scanned is not None else len(batch)
        if covered < length:
            raise OffsetOutOfRange(
                f"{topic}:{partition} range [{offset}, {offset+length}) extends past "
                f"end {self.end_offset(topic, partition)}"
            )
        return batch

    def iter_range(
        self,
        topic: str,
        partition: int,
        offset: int,
        length: int,
        chunk: int = 4096,
    ) -> Iterator[RecordBatch]:
        done = 0
        while done < length:
            take = min(chunk, length - done)
            yield self.read_range(topic, partition, offset + done, take)
            done += take

    def start_offset(self, topic: str, partition: int) -> int:
        return self._partition(topic, partition).log_start_offset

    def end_offset(self, topic: str, partition: int) -> int:
        return self._partition(topic, partition).end_offset

    # ------------------------------------------------------------ replication
    # Broker-to-broker primitives used by repro.core.cluster: a follower
    # fetches raw (value, key) pairs from the leader's log and re-appends
    # them locally; a deposed leader truncates to the new leader's end.
    def replica_fetch(
        self, topic: str, partition: int, offset: int, max_records: int = 4096
    ) -> tuple[
        list[bytes],
        list[bytes | None],
        list[int],
        tuple[list[int], list[int], list[int], list[int]] | None,
    ]:
        return self._partition(topic, partition).fetch_raw(offset, max_records)

    def replica_append(
        self,
        topic: str,
        partition: int,
        values: Sequence[bytes],
        keys: Sequence[bytes | None] | None,
        timestamps: Sequence[int] | int,
        prods: tuple | None = None,
        producer: tuple[int, int, int] | None = None,
        txn: bool = False,
    ) -> tuple[int, int]:
        """Append records with explicit timestamps (scalar or per-record).

        Used by replication — a follower re-appends fetched leader records
        verbatim so consumers see identical ``Record.timestamp_ms`` before
        and after failover, and ``retention_ms`` (keyed to record
        timestamps in ``_enforce_retention``) expires the same records on
        every replica — and by the cluster's leader-side append, which
        stamps the batch once and pushes the same timestamps to the ISR.

        Producer metadata travels the same two ways: ``prods`` per-record
        (fetched via :meth:`replica_fetch`) or ``producer`` batch-level
        (the acks=all direct ISR push, one run-merge instead of a
        per-record loop). Either keeps the follower's dedup table in step
        with the leader's, so exactly-once survives failover."""
        m = self.metrics
        if m is None or not m.enabled:
            return self._partition(topic, partition).append_batch(
                values, keys, timestamps, prods=prods, producer=producer,
                txn=txn,
            )
        _, h_app, c_app, _, _ = self._hot_metrics(m)
        t0 = time.perf_counter()
        out = self._partition(topic, partition).append_batch(
            values, keys, timestamps, prods=prods, producer=producer, txn=txn
        )
        h_app.record(time.perf_counter() - t0)
        c_app.inc(len(values))
        return out

    def producer_append(
        self,
        topic: str,
        partition: int,
        values: Sequence[bytes],
        keys: Sequence[bytes | None] | None,
        timestamps: Sequence[int] | int,
        pid: int,
        epoch: int,
        seq: int,
        txn: bool = False,
    ) -> tuple[int, int, bool]:
        """Leader-side idempotent append: returns ``(first, last,
        duplicate)``; a retried batch resolves to its original offsets
        with ``duplicate=True`` instead of re-appending. See
        :meth:`_Partition.idempotent_append` for the fencing/ordering
        rules. ``txn=True`` additionally marks the records transactional:
        they stay above the LSO — invisible to read_committed consumers —
        until a control marker resolves their transaction."""
        m = self.metrics
        if m is None or not m.enabled:
            return self._partition(topic, partition).idempotent_append(
                values, keys, timestamps, pid, epoch, seq, txn=txn
            )
        _, h_app, c_app, _, _ = self._hot_metrics(m)
        t0 = time.perf_counter()
        out = self._partition(topic, partition).idempotent_append(
            values, keys, timestamps, pid, epoch, seq, txn=txn
        )
        h_app.record(time.perf_counter() - t0)
        if not out[2]:  # a dedup hit appended nothing
            c_app.inc(len(values))
        return out

    def append_control(
        self, topic: str, partition: int, pid: int, epoch: int, *, abort: bool
    ) -> int | None:
        """Write a COMMIT/ABORT control marker resolving ``pid``'s open
        transaction on the partition; None when nothing is open (the
        idempotent re-drive path of coordinator recovery)."""
        return self._partition(topic, partition).append_control(
            pid, epoch, abort=abort
        )

    def last_stable_offset(self, topic: str, partition: int) -> int:
        """The partition's LSO — the read_committed visibility bound."""
        return self._partition(topic, partition).last_stable_offset()

    def stats(self) -> dict[str, int]:
        """Aggregate substrate stats: segment/retention state and
        producer-state (dedup) table size across every partition.
        Evaluated lazily by metrics gauge callbacks at snapshot time —
        never on the append hot path."""
        out = {
            "partitions": 0,
            "segments": 0,
            "size_bytes": 0,
            "retained_records": 0,
            "producer_state_entries": 0,
            "open_txns": 0,
        }
        with self._lock:
            parts = [p for ps in self._topics.values() for p in ps]
        for part in parts:
            with part.lock:
                out["partitions"] += 1
                out["segments"] += len(part.segments)
                out["size_bytes"] += sum(s.size_bytes for s in part.segments)
                out["retained_records"] += (
                    part.end_offset - part.log_start_offset
                )
                out["producer_state_entries"] += len(part.producers)
                out["open_txns"] += len(part.txn_open)
        return out

    def open_txns(self, topic: str, partition: int) -> dict[int, int]:
        """pid -> first offset of its open transaction (test/observability
        hook)."""
        part = self._partition(topic, partition)
        with part.lock:
            return {pid: first for pid, (first, _) in part.txn_open.items()}

    def aborted_ranges(self, topic: str, partition: int) -> list[tuple[int, int, int]]:
        """(pid, first, marker_offset) aborted spans (test hook)."""
        part = self._partition(topic, partition)
        with part.lock:
            return list(part.aborted)

    def producer_state(
        self, topic: str, partition: int
    ) -> dict[int, tuple[int, int]]:
        """Snapshot of the partition's dedup table: pid -> (epoch,
        last_seq). Observability/test hook."""
        part = self._partition(topic, partition)
        with part.lock:
            return {
                pid: (st.epoch, st.last_seq)
                for pid, st in part.producers.items()
            }

    def truncate_to(self, topic: str, partition: int, offset: int) -> int:
        return self._partition(topic, partition).truncate_to(offset)

    def reset_to(self, topic: str, partition: int, offset: int) -> int:
        return self._partition(topic, partition).reset_to(offset)

    def size_bytes(self, topic: str, partition: int | None = None) -> int:
        parts = self._partitions(topic)
        if partition is not None:
            return parts[partition].size_bytes()
        return sum(p.size_bytes() for p in parts)

    # -------------------------------------------------- consumer offset store
    def commit_offset(self, group: str, tp: TopicPartition, offset: int) -> None:
        with self._lock:
            self._committed.setdefault(group, {})[tp] = offset

    def committed_offset(self, group: str, tp: TopicPartition) -> int | None:
        with self._lock:
            return self._committed.get(group, {}).get(tp)


class StreamBackend(Protocol):
    """Structural type of a data substrate the upper layers accept.

    Both the single-broker :class:`StreamLog` and the replicated
    :class:`repro.core.cluster.BrokerCluster` satisfy it, so the pipeline
    (:mod:`repro.data.pipeline`), consumer groups
    (:mod:`repro.core.consumer`), control plane (:mod:`repro.core.control`),
    trainer and serving engine all run unchanged against either.
    """

    def ensure_topic(self, name: str, cfg: LogConfig | None = None) -> None: ...

    def create_topic(self, name: str, cfg: LogConfig | None = None) -> None: ...

    def topics(self) -> list[str]: ...

    def num_partitions(self, topic: str) -> int: ...

    def produce(
        self,
        topic: str,
        value: bytes,
        *,
        key: bytes | None = None,
        partition: int | None = None,
    ) -> tuple[int, int]: ...

    def produce_batch(
        self,
        topic: str,
        values: Sequence[bytes],
        *,
        keys: Sequence[bytes | None] | None = None,
        partition: int | None = None,
    ) -> tuple[int, int, int]: ...

    def read(
        self,
        topic: str,
        partition: int,
        offset: int,
        max_records: int = 1024,
        isolation: str | None = None,
    ) -> RecordBatch: ...

    def read_range(
        self, topic: str, partition: int, offset: int, length: int
    ) -> RecordBatch: ...

    def iter_range(
        self, topic: str, partition: int, offset: int, length: int, chunk: int = 4096
    ) -> Iterator[RecordBatch]: ...

    def start_offset(self, topic: str, partition: int) -> int: ...

    def end_offset(self, topic: str, partition: int) -> int: ...

    def commit_offset(self, group: str, tp: TopicPartition, offset: int) -> None: ...

    def committed_offset(self, group: str, tp: TopicPartition) -> int | None: ...
