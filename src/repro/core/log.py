"""Distributed log — the Kafka-ML data substrate, JAX-host-native.

Implements the semantics Kafka-ML relies on (paper §II, §V):

* topics split into **partitions**; each partition is an append-only log of
  records addressed by a monotonically increasing **offset**;
* records are retained after consumption (the *distributed log*), so
  consumers can re-read ranges — this is what lets Kafka-ML replay a
  training stream to a new deployment with a tens-of-bytes control message
  instead of re-sending the data;
* **delete retention policy** with ``retention_bytes`` / ``retention_ms``
  (paper §V lists exactly these two knobs; compact policy intentionally
  not offered, as the paper argues delete is the right policy for ML
  streams);
* message-set (batched) appends amortize per-record overhead — the paper's
  "message set abstraction";
* zero-copy reads: records are returned as memoryviews into segment
  buffers ("zero-copy optimizations" in paper §II).

The log is an in-process, host-memory structure (segments are bytearrays)
with optional disk spill. On a TPU pod the broker is colocated with the
host, so a network hop becomes a RAM hop; every *semantic* (offsets,
retention, replay, consumer groups) is preserved — see DESIGN.md §2.
"""

from __future__ import annotations

import bisect
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Iterator, Sequence

import numpy as np

__all__ = [
    "LogConfig",
    "OffsetOutOfRange",
    "Record",
    "RecordBatch",
    "StreamLog",
    "TopicPartition",
]


class OffsetOutOfRange(LookupError):
    """Requested offset is below the log start (evicted) or past the end."""


@dataclass(frozen=True)
class TopicPartition:
    """Identifies one partition of one topic (Kafka's TopicPartition)."""

    topic: str
    partition: int

    def __str__(self) -> str:  # [topic:partition] per the paper's format
        return f"{self.topic}:{self.partition}"


@dataclass(frozen=True)
class Record:
    """One record as seen by a consumer."""

    topic: str
    partition: int
    offset: int
    value: memoryview  # zero-copy view into the segment buffer
    key: bytes | None
    timestamp_ms: int

    def value_bytes(self) -> bytes:
        return bytes(self.value)


@dataclass
class LogConfig:
    """Per-topic configuration (mirrors Kafka topic configs)."""

    num_partitions: int = 1
    # delete-retention knobs (paper §V): None ⇒ not applicable
    retention_bytes: int | None = None
    retention_ms: int | None = None
    segment_bytes: int = 8 * 1024 * 1024  # roll segments at this size
    replication_factor: int = 1  # bookkeeping only (single-host broker)
    # disk spill: sealed (rolled) segments move their payload to an
    # mmap-backed file under spill_dir; reads stay zero-copy (memoryview
    # over the map). Host RAM then holds only the active segment + indexes.
    spill_dir: str | None = None


class _Segment:
    """A contiguous chunk of the partition log.

    Layout: one shared ``bytearray`` holding concatenated record payloads;
    numpy index arrays map relative record index -> (start, length, key
    range, timestamp). Batched appends write once into the buffer.
    """

    __slots__ = (
        "base_offset",
        "buf",
        "key_buf",
        "starts",
        "lengths",
        "key_starts",
        "key_lengths",
        "timestamps",
        "count",
        "created_ms",
        "_spill_file",
    )

    def __init__(self, base_offset: int, created_ms: int):
        self.base_offset = base_offset
        self.buf = bytearray()
        self.key_buf = bytearray()
        # python lists while hot; frozen to numpy on roll
        self.starts: list[int] = []
        self.lengths: list[int] = []
        self.key_starts: list[int] = []
        self.key_lengths: list[int] = []
        self.timestamps: list[int] = []
        self.count = 0
        self.created_ms = created_ms
        self._spill_file = None

    @property
    def size_bytes(self) -> int:
        return len(self.buf) + len(self.key_buf)

    @property
    def last_offset(self) -> int:
        return self.base_offset + self.count - 1

    def append_batch(
        self,
        values: Sequence[bytes | bytearray | memoryview],
        keys: Sequence[bytes | None] | None,
        timestamp_ms: int,
    ) -> None:
        pos = len(self.buf)
        kpos = len(self.key_buf)
        for i, v in enumerate(values):
            self.starts.append(pos)
            n = len(v)
            self.lengths.append(n)
            self.buf += v
            pos += n
            k = keys[i] if keys is not None else None
            if k is None:
                self.key_starts.append(kpos)
                self.key_lengths.append(-1)
            else:
                self.key_starts.append(kpos)
                self.key_lengths.append(len(k))
                self.key_buf += k
                kpos += len(k)
            self.timestamps.append(timestamp_ms)
        self.count += len(values)

    def record(self, topic: str, partition: int, rel: int) -> Record:
        start = self.starts[rel]
        length = self.lengths[rel]
        klen = self.key_lengths[rel]
        key = (
            None
            if klen < 0
            else bytes(self.key_buf[self.key_starts[rel] : self.key_starts[rel] + klen])
        )
        return Record(
            topic=topic,
            partition=partition,
            offset=self.base_offset + rel,
            value=memoryview(self.buf)[start : start + length],
            key=key,
            timestamp_ms=self.timestamps[rel],
        )

    def spill(self, path: str) -> None:
        """Seal this segment's payload to an mmap-backed file (zero-copy
        reads continue through the map); frees the heap buffer."""
        import mmap

        with open(path, "wb") as f:
            f.write(bytes(self.buf))
            f.flush()
        if len(self.buf) == 0:
            return
        fh = open(path, "rb")
        mm = mmap.mmap(fh.fileno(), 0, access=mmap.ACCESS_READ)
        self.buf = mm  # memoryview(mmap) slices stay zero-copy
        self._spill_file = (fh, path)

    def drop_spill(self) -> None:
        sp = getattr(self, "_spill_file", None)
        if sp is not None:
            fh, path = sp
            try:
                self.buf.close() if hasattr(self.buf, "close") else None
                fh.close()
                os.unlink(path)
            except OSError:
                pass


@dataclass
class RecordBatch:
    """A batch of records read from one partition — supports vectorized decode.

    ``values`` are zero-copy memoryviews; ``to_matrix`` stacks fixed-size
    payloads into a single (n, record_bytes) uint8 array in one pass, the
    fast path used by the training data pipeline.
    """

    topic: str
    partition: int
    first_offset: int
    values: list[memoryview]
    timestamps: list[int]

    def __len__(self) -> int:
        return len(self.values)

    @property
    def next_offset(self) -> int:
        return self.first_offset + len(self.values)

    def to_matrix(self) -> np.ndarray:
        if not self.values:
            return np.zeros((0, 0), dtype=np.uint8)
        n = len(self.values[0])
        if any(len(v) != n for v in self.values):
            raise ValueError("to_matrix requires fixed-size records")
        out = np.empty((len(self.values), n), dtype=np.uint8)
        for i, v in enumerate(self.values):
            out[i] = np.frombuffer(v, dtype=np.uint8)
        return out


class _Partition:
    def __init__(self, topic: str, index: int, cfg: LogConfig, clock: Callable[[], int]):
        self.topic = topic
        self.index = index
        self.cfg = cfg
        self.clock = clock
        self.segments: list[_Segment] = [_Segment(0, clock())]
        self.log_start_offset = 0  # first retained offset
        self.lock = threading.RLock()

    # ------------------------------------------------------------------ write
    def append_batch(
        self, values: Sequence[bytes], keys: Sequence[bytes | None] | None
    ) -> tuple[int, int]:
        """Append a message set; returns (first_offset, last_offset)."""
        with self.lock:
            now = self.clock()
            seg = self.segments[-1]
            if seg.size_bytes >= self.cfg.segment_bytes and seg.count > 0:
                if self.cfg.spill_dir is not None:  # seal -> mmap-backed file
                    os.makedirs(self.cfg.spill_dir, exist_ok=True)
                    seg.spill(os.path.join(
                        self.cfg.spill_dir,
                        f"{self.topic}-{self.index}-{seg.base_offset}.seg",
                    ))
                seg = _Segment(seg.base_offset + seg.count, now)
                self.segments.append(seg)
            first = seg.base_offset + seg.count
            seg.append_batch(values, keys, now)
            self._enforce_retention(now)
            return first, seg.last_offset

    # ------------------------------------------------------------------- read
    @property
    def end_offset(self) -> int:
        seg = self.segments[-1]
        return seg.base_offset + seg.count

    def read(self, offset: int, max_records: int) -> RecordBatch:
        with self.lock:
            if offset < self.log_start_offset:
                raise OffsetOutOfRange(
                    f"{self.topic}:{self.index} offset {offset} < log start "
                    f"{self.log_start_offset} (evicted by retention)"
                )
            end = self.end_offset
            if offset > end:
                raise OffsetOutOfRange(
                    f"{self.topic}:{self.index} offset {offset} > end {end}"
                )
            n = min(max_records, end - offset)
            values: list[memoryview] = []
            timestamps: list[int] = []
            if n > 0:
                si = self._segment_for(offset)
                remaining = n
                off = offset
                while remaining > 0:
                    seg = self.segments[si]
                    rel = off - seg.base_offset
                    take = min(remaining, seg.count - rel)
                    mv = memoryview(seg.buf)
                    for r in range(rel, rel + take):
                        start = seg.starts[r]
                        values.append(mv[start : start + seg.lengths[r]])
                        timestamps.append(seg.timestamps[r])
                    remaining -= take
                    off += take
                    si += 1
            return RecordBatch(
                topic=self.topic,
                partition=self.index,
                first_offset=offset,
                values=values,
                timestamps=timestamps,
            )

    def _segment_for(self, offset: int) -> int:
        bases = [s.base_offset for s in self.segments]
        i = bisect.bisect_right(bases, offset) - 1
        return max(i, 0)

    # -------------------------------------------------------------- retention
    def _enforce_retention(self, now_ms: int) -> None:
        cfg = self.cfg
        # never evict the active (last) segment
        while len(self.segments) > 1:
            head = self.segments[0]
            evict = False
            if cfg.retention_bytes is not None:
                total = sum(s.size_bytes for s in self.segments)
                if total > cfg.retention_bytes:
                    evict = True
            if not evict and cfg.retention_ms is not None:
                if now_ms - head.created_ms > cfg.retention_ms:
                    evict = True
            if not evict:
                break
            self.segments.pop(0).drop_spill()
            self.log_start_offset = self.segments[0].base_offset

    def size_bytes(self) -> int:
        with self.lock:
            return sum(s.size_bytes for s in self.segments)


class StreamLog:
    """The broker: a set of topics, each a list of partitions.

    Thread-safe. Also hosts the consumer-offset store (Kafka's
    ``__consumer_offsets``) used by :mod:`repro.core.consumer`.
    """

    def __init__(self, clock: Callable[[], float] | None = None):
        self._topics: dict[str, list[_Partition]] = {}
        self._configs: dict[str, LogConfig] = {}
        self._lock = threading.RLock()
        self._clock = clock or time.time
        # consumer group -> TopicPartition -> committed offset
        self._committed: dict[str, dict[TopicPartition, int]] = {}

    def _now_ms(self) -> int:
        return int(self._clock() * 1000)

    # ------------------------------------------------------------------ admin
    def create_topic(self, name: str, cfg: LogConfig | None = None) -> None:
        with self._lock:
            if name in self._topics:
                raise ValueError(f"topic {name!r} already exists")
            cfg = cfg or LogConfig()
            self._configs[name] = cfg
            self._topics[name] = [
                _Partition(name, i, cfg, self._now_ms)
                for i in range(cfg.num_partitions)
            ]

    def ensure_topic(self, name: str, cfg: LogConfig | None = None) -> None:
        with self._lock:
            if name not in self._topics:
                self.create_topic(name, cfg)

    def topics(self) -> list[str]:
        with self._lock:
            return sorted(self._topics)

    def num_partitions(self, topic: str) -> int:
        return len(self._partitions(topic))

    def delete_topic(self, name: str) -> None:
        with self._lock:
            self._topics.pop(name, None)
            self._configs.pop(name, None)

    def _partitions(self, topic: str) -> list[_Partition]:
        try:
            return self._topics[topic]
        except KeyError:
            raise KeyError(f"unknown topic {topic!r}") from None

    def _partition(self, topic: str, partition: int) -> _Partition:
        parts = self._partitions(topic)
        if not 0 <= partition < len(parts):
            raise IndexError(f"{topic} has no partition {partition}")
        return parts[partition]

    # ---------------------------------------------------------------- produce
    def produce(
        self,
        topic: str,
        value: bytes,
        *,
        key: bytes | None = None,
        partition: int | None = None,
    ) -> tuple[int, int]:
        """Append one record; returns (partition, offset)."""
        (p, first, _last) = self._produce_batch(topic, [value], [key], partition)
        return p, first

    def produce_batch(
        self,
        topic: str,
        values: Sequence[bytes],
        *,
        keys: Sequence[bytes | None] | None = None,
        partition: int | None = None,
    ) -> tuple[int, int, int]:
        """Append a message set to one partition.

        Returns ``(partition, first_offset, last_offset)``. Batching is the
        paper's "message set abstraction": one index/lock round per batch.
        """
        return self._produce_batch(topic, values, keys, partition)

    def _produce_batch(
        self,
        topic: str,
        values: Sequence[bytes],
        keys: Sequence[bytes | None] | None,
        partition: int | None,
    ) -> tuple[int, int, int]:
        parts = self._partitions(topic)
        if partition is None:
            if keys is not None and keys and keys[0] is not None:
                partition = hash(bytes(keys[0])) % len(parts)
            else:
                partition = self._now_ms() % len(parts)  # sticky round-robin-ish
        part = parts[partition]
        first, last = part.append_batch(values, keys)
        return partition, first, last

    # ---------------------------------------------------------------- consume
    def read(
        self, topic: str, partition: int, offset: int, max_records: int = 1024
    ) -> RecordBatch:
        return self._partition(topic, partition).read(offset, max_records)

    def read_range(
        self, topic: str, partition: int, offset: int, length: int
    ) -> RecordBatch:
        """Read exactly ``length`` records starting at ``offset``.

        This is the paper's §V access pattern: a control message names
        ``[topic:partition:offset:length]`` and the training job reads that
        exact slice of the distributed log.
        """
        batch = self.read(topic, partition, offset, length)
        if len(batch) < length:
            raise OffsetOutOfRange(
                f"{topic}:{partition} range [{offset}, {offset+length}) extends past "
                f"end {self.end_offset(topic, partition)}"
            )
        return batch

    def iter_range(
        self,
        topic: str,
        partition: int,
        offset: int,
        length: int,
        chunk: int = 4096,
    ) -> Iterator[RecordBatch]:
        done = 0
        while done < length:
            take = min(chunk, length - done)
            yield self.read_range(topic, partition, offset + done, take)
            done += take

    def start_offset(self, topic: str, partition: int) -> int:
        return self._partition(topic, partition).log_start_offset

    def end_offset(self, topic: str, partition: int) -> int:
        return self._partition(topic, partition).end_offset

    def size_bytes(self, topic: str, partition: int | None = None) -> int:
        parts = self._partitions(topic)
        if partition is not None:
            return parts[partition].size_bytes()
        return sum(p.size_bytes() for p in parts)

    # -------------------------------------------------- consumer offset store
    def commit_offset(self, group: str, tp: TopicPartition, offset: int) -> None:
        with self._lock:
            self._committed.setdefault(group, {})[tp] = offset

    def committed_offset(self, group: str, tp: TopicPartition) -> int | None:
        with self._lock:
            return self._committed.get(group, {}).get(tp)
