"""Replicated broker cluster — multi-broker StreamLog with ISR replication.

The paper's fault-tolerance and high-availability claims (abstract, §II,
§V) rest on Kafka's *replicated* distributed log: every partition lives on
``replication_factor`` brokers, one of which is the **leader** (serves all
produce/fetch traffic) while the rest are **followers** that replicate the
leader's log by fetching from it. This module supplies that substrate for
the JAX-side reproduction:

* :class:`BrokerCluster` — N in-process brokers, each backed by its own
  :class:`~repro.core.log.StreamLog`. Topics are created with per-partition
  **replica sets** (round-robin placement), a deterministic **preferred
  leader**, an **in-sync-replica (ISR)** set, and a **high watermark** (HW):
  the largest offset known to be on every ISR member. Consumers only ever
  see records below the HW, so an acknowledged-and-visible record can never
  be un-read by a failover.
* **Producer acks** (paper §II's durability/latency trade-off):
  ``acks=0`` fire-and-forget, ``acks=1`` leader-only append, ``acks='all'``
  append + synchronous ISR replication + HW advance before the call
  returns. An ``acks='all'`` record survives the loss of any single broker
  *provided the ISR held >= 2 members when it was acknowledged* — as in
  Kafka, set ``min_insync_replicas=2`` to make the broker reject appends
  whenever that precondition doesn't hold (topics created without an
  explicit config get ``min(2, rf)``).
* **Leader election** — when a broker dies or is network-partitioned, every
  partition it led elects the lowest-id in-sync survivor (deterministic),
  bumps the partition **epoch** (fences stale clients), and shrinks the
  ISR. A rejoining broker truncates its log to the HW (discarding unacked
  suffix records, Kafka's log reconciliation) and re-fetches from the new
  leader until it is back in sync.
* :class:`ClusterProducer` / :class:`ClusterConsumer` — failover-aware
  clients: they cache partition metadata, route to the cached leader, and
  on :class:`NotLeaderError` / :class:`BrokerUnavailable` refresh metadata
  and retry — exactly the real Kafka client protocol loop.
  ``ClusterProducer(idempotent=True)`` stamps batches with a
  quorum-committed ``(pid, epoch)`` identity and per-partition sequences,
  turning that retry loop **exactly-once**: a re-sent committed batch
  dedups on the leader (and on any follower that inherits leadership) to
  its original offsets — see DESIGN.md §7.

Concurrency model (DESIGN.md §4). The data plane is partition-parallel:

* a cluster-wide **metadata lock** guards topology only (topic create or
  delete, broker up/down transitions, the consumer-offset store);
* each partition carries its own **controller lock** serializing that
  partition's produces, fetches, replication passes, elections, ISR and
  HW updates. Produces/fetches to *different* partitions never contend.
* The lock hierarchy is strictly ``metadata lock → partition lock``
  (never reversed), so topology events may sweep partitions but
  partition-level work never blocks on topology.
* :class:`ReplicationService` is the background follower-fetch daemon:
  worker threads drive replication passes for every partition on a
  configurable interval, advancing HWs and completing leader elections
  without any client on the hot path.
* **Follower reads** — a fetch addressed to an *in-sync* follower may be
  served from its local log, capped at the high watermark. Records below
  the HW are immutable and identical on every ISR member, so follower
  reads are stale-bounded but never wrong; serving replicas keep
  answering while a leader election is in flight.

The cluster also implements the full :class:`~repro.core.log.StreamBackend`
surface (``produce_batch``/``read``/``read_range``/offset store/…), so the
data pipeline, consumer groups, control plane, trainer and serving engine
all run unchanged against either a bare ``StreamLog`` or a cluster — see
DESIGN.md §"Cluster".

The consumer-offset store (Kafka's ``__consumer_offsets``) is held by the
cluster controller and mirrored onto every live broker, i.e. replicated at
the full cluster width, so committed offsets survive any broker loss.

Transactions (DESIGN.md §8). The cluster doubles as the **transaction
coordinator**: ``begin_txn``/``txn_add_partitions``/``txn_add_offsets``/
``commit_txn``/``abort_txn`` drive a two-phase commit whose every state
transition is a committed ``MetadataCommand`` in the replicated metadata
log — so a transaction whose driver (or controller leader) dies after
the ``PrepareCommit`` decision is finished by any later
``controller_tick``: COMMIT/ABORT control markers land on every
registered partition, attached consumer offsets apply exactly with the
commit, and every touched partition converges to the same outcome.
:class:`ClusterProducer(transactional_id=...)` is the client half
(``begin_txn``/``send_offsets_to_txn``/``commit_txn``/``abort_txn``
layered on the idempotent machinery), and
``ClusterConsumer(isolation_level="read_committed")`` the consumer half
(LSO-capped fetches, aborted ranges filtered).

Control plane (DESIGN.md §5). Topology is no longer mutated in place:
every topology change — broker liveness, partition leadership, ISR
membership, topic create/delete — is a :class:`MetadataCommand` committed
through the :class:`~repro.core.controller.QuorumController`'s replicated
metadata log (majority of N controller nodes) and only then applied to
the partition ctls. The controller itself fails over by quorum election
(``kill_controller`` + a daemon tick), and a partitioned controller
minority can neither elect nor commit, so the control plane has no single
point of failure and no split-brain window. The lock hierarchy gains a
leaf: ``metadata lock → partition lock → controller lock``.
"""

from __future__ import annotations

import hashlib
import json
import os
import threading
import time
import weakref
from dataclasses import asdict, dataclass, replace
from typing import Callable, Iterator, Sequence

from repro.analysis.witness import make_lock, make_rlock
from repro.core.controller import (
    ClusterError,
    ControllerUnavailable,
    MetadataCommand,
    QuorumController,
)
from repro.core.log import (
    LogConfig,
    OffsetOutOfRange,
    ProducerFenced,
    RecordBatch,
    StreamLog,
    TopicPartition,
    default_partition,
)
from repro.core.metrics import METRICS_TOPIC, MetricsRegistry

__all__ = [
    "Broker",
    "BrokerCluster",
    "BrokerUnavailable",
    "ClusterConsumer",
    "ClusterError",
    "ClusterProducer",
    "ControllerUnavailable",
    "InvalidTxnState",
    "METRICS_TOPIC",
    "MetricsReporter",
    "NotEnoughReplicasError",
    "NotLeaderError",
    "PartitionMeta",
    "PartitionOffline",
    "ProducerFenced",
    "ReplicationService",
]

_REPLICA_FETCH_CHUNK = 4096
_ROUTED_RETRIES = 8


# ------------------------------------------------------------------ errors
# ClusterError itself lives in repro.core.controller (the shared base
# module) and is re-exported here; ControllerUnavailable subclasses it so
# `except ClusterError` retry loops cover controller-quorum windows too.
class NotLeaderError(ClusterError):
    """The addressed broker is not the current leader for the partition.

    Carries a ``leader_hint`` (the current leader's broker id, or None)
    so clients can refresh their metadata cache and retry — Kafka's
    NOT_LEADER_OR_FOLLOWER error code.
    """

    def __init__(self, topic: str, partition: int, leader_hint: int | None):
        super().__init__(
            f"not leader for {topic}:{partition} (current leader: {leader_hint})"
        )
        self.topic = topic
        self.partition = partition
        self.leader_hint = leader_hint


class BrokerUnavailable(ClusterError):
    """The addressed broker is dead or unreachable."""


class PartitionOffline(ClusterError):
    """No eligible (in-sync, live) leader candidate exists."""


class NotEnoughReplicasError(ClusterError):
    """acks=all rejected: live ISR smaller than ``min_insync_replicas``."""


class InvalidTxnState(RuntimeError):
    """A transactional operation was attempted in a state that forbids it
    (begin while a transaction is already in progress, commit with no
    transaction, abort of a transaction whose commit is already durably
    decided, ...). Deliberately NOT a ``ClusterError``: retry loops must
    not re-drive a structurally invalid request — the caller's state
    machine is wrong, not the cluster's availability."""


class _TxnState:
    """Coordinator-side state of one producer's transaction, reconstructed
    purely by applying committed txn ``MetadataCommand``s in log order —
    so a controller successor holds exactly the same view.

    ``state``: ``ongoing`` → ``prepare_commit``/``prepare_abort`` (the
    durable decision) → ``complete_commit``/``complete_abort`` (markers
    written everywhere, offsets applied). ``seq`` is the per-pid command
    sequence (the transactional pversion) guarding idempotent replay.
    """

    __slots__ = (
        "pid", "epoch", "state", "seq", "partitions", "offsets", "touched",
    )

    def __init__(self, pid: int, epoch: int, seq: int):
        self.pid = pid
        self.epoch = epoch
        self.state = "ongoing"
        self.seq = seq
        self.partitions: set[tuple[str, int]] = set()
        # group -> {"topic:partition" -> offset} committed atomically
        # with the transaction's produced records
        self.offsets: dict[str, dict[str, int]] = {}
        # local wall-clock of the last applied command for this txn — the
        # transaction-timeout reference (coordinator-local bookkeeping,
        # not replicated state: the timeout *abort* goes through the
        # quorum like any other decision)
        self.touched = 0.0


# ------------------------------------------------------------------- broker
@dataclass
class Broker:
    """One broker: an id plus its local :class:`StreamLog` replica store.

    ``alive`` models a crash (process gone); ``reachable`` models a network
    partition (process up but unreachable). Either way the broker is *down*
    from the cluster's point of view.
    """

    broker_id: int
    log: StreamLog
    alive: bool = True
    reachable: bool = True

    @property
    def up(self) -> bool:
        return self.alive and self.reachable


@dataclass(frozen=True)
class PartitionMeta:
    """Client-visible metadata for one partition (Kafka MetadataResponse)."""

    topic: str
    partition: int
    leader: int | None
    epoch: int
    replicas: tuple[int, ...]
    isr: frozenset[int]
    high_watermark: int


class _PartitionCtl:
    """Controller-side replication state for one partition.

    ``lock`` serializes every data-plane operation touching this partition
    (produce, fetch, replication pass, election, ISR/HW update) — the
    per-partition half of the lock hierarchy. Holders of a partition lock
    must never acquire the cluster metadata lock.
    """

    __slots__ = (
        "topic",
        "partition",
        "replicas",
        "leader",
        "epoch",
        "isr",
        "hw",
        "epoch_starts",
        "synced_epoch",
        "version",
        "gen",
        "lock",
        "m_produce",
        "m_repl",
        "m_fetch",
    )

    def __init__(
        self,
        topic: str,
        partition: int,
        replicas: list[int],
        lock: threading.RLock | None = None,
        gen: int = 0,
    ):
        self.topic = topic
        self.partition = partition
        self.replicas = list(replicas)
        self.leader: int | None = replicas[0]
        self.epoch = 0
        self.isr: set[int] = set(replicas)
        self.hw = 0
        # metadata version: bumped by every applied controller command for
        # this partition; application is guarded by `pversion > version`,
        # which makes controller-failover replay idempotent
        self.version = 0
        # owning topic's generation (fences replays against a same-name
        # recreated topic)
        self.gen = gen
        # Kafka's leader-epoch checkpoint: epoch -> first offset written in
        # that epoch. A rejoining replica truncates to the start of the
        # first epoch it missed — records above may be a deposed leader's
        # divergent unacked suffix, even below the since-advanced HW.
        self.epoch_starts: dict[int, int] = {0: 0}
        # last epoch each replica fully caught up in
        self.synced_epoch: dict[int, int] = {b: 0 for b in replicas}
        self.lock = lock if lock is not None else make_rlock(
            "partition", name=f"partition:{topic}:{partition}")
        # lazily bound per-partition metric handles (produce / replication
        # / fetch record counters): the hot path must not pay a series-key
        # format + registry lookup per batch (DESIGN §9 overhead budget)
        self.m_produce = None
        self.m_repl = None
        self.m_fetch = None

    def meta(self) -> PartitionMeta:
        with self.lock:
            return PartitionMeta(
                topic=self.topic,
                partition=self.partition,
                leader=self.leader,
                epoch=self.epoch,
                replicas=tuple(self.replicas),
                isr=frozenset(self.isr),
                high_watermark=self.hw,
            )


# ------------------------------------------------------- replication daemon
class ReplicationService:
    """Background follower-fetch daemon for a :class:`BrokerCluster`.

    ``workers`` threads share the partition set (partition *i* belongs to
    worker ``i % workers``); each runs a replication pass for its
    partitions every ``interval_s`` seconds, advancing high watermarks,
    pruning dead followers from ISRs and — because a pass resolves the
    partition leader — completing leader elections for partitions whose
    leader died, all off the client hot path. This replaces the explicit
    ``replicate_all()`` ticks (which remain available) with the same
    leader-epoch reconciliation guarantees: a pass is exactly
    ``BrokerCluster.replicate_partition`` under the partition lock.

    Worker 0 additionally drives the **controller heartbeat**
    (``BrokerCluster.controller_tick``) once per sweep: quorum lease
    renewal, controller-leader election on failure, and application of
    any committed-but-unapplied metadata backlog — so a controller-leader
    kill fails over within one daemon interval with no client involved.

    ``start``/``stop`` are idempotent; the service is also a context
    manager. Unexpected per-partition errors are collected on ``errors``
    (bounded) instead of killing the worker. The service holds its
    cluster only weakly: workers exit on their own once every other
    reference to the cluster is dropped, so a caller that forgets
    ``stop_replication()`` leaks neither the cluster nor a busy loop.
    """

    def __init__(
        self,
        cluster: "BrokerCluster",
        *,
        interval_s: float = 0.02,
        workers: int = 2,
    ):
        self._cluster_ref = weakref.ref(cluster)
        self.interval_s = interval_s
        self.workers = max(1, int(workers))
        self.errors: list[BaseException] = []
        self.passes = 0  # completed sweeps by worker 0 (progress probe)
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []

    @property
    def cluster(self) -> "BrokerCluster | None":
        return self._cluster_ref()

    @property
    def running(self) -> bool:
        return bool(self._threads) and not self._stop.is_set()

    def start(self) -> "ReplicationService":
        if self._threads:
            return self
        # a fresh Event per worker generation: a worker that outlived a
        # stop() join timeout stays bound to its own (set) event and can
        # never be resurrected by a later start() clearing a shared flag
        self._stop = stop = threading.Event()
        for i in range(self.workers):
            t = threading.Thread(
                target=self._run,
                args=(i, stop),
                name=f"replication-worker-{i}",
                daemon=True,
            )
            t.start()
            self._threads.append(t)
        return self

    def stop(self, timeout: float = 5.0) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout)
        self._threads = []

    def _run(self, idx: int, stop: threading.Event) -> None:
        while not stop.is_set():
            cluster = self._cluster_ref()
            if cluster is None:
                return  # cluster dropped without stop_replication()
            if idx == 0:
                try:
                    cluster.controller_tick()
                except (ClusterError, ControllerUnavailable):
                    # no controller quorum yet — next sweep retries
                    cluster.metrics.counter(
                        "daemon_retries_total", daemon="replication"
                    ).inc()
            for j, (topic, p) in enumerate(cluster.partition_ids()):
                if j % self.workers != idx:
                    continue
                if stop.is_set():
                    return
                try:
                    cluster.replicate_partition(topic, p)
                except (ClusterError, ControllerUnavailable, KeyError, IndexError):
                    # offline/deleted partition — next pass retries
                    cluster.metrics.counter(
                        "daemon_retries_total", daemon="replication"
                    ).inc()
                    continue
                except BaseException as e:  # pragma: no cover - diagnostics
                    cluster.metrics.counter(
                        "daemon_errors_total", daemon="replication"
                    ).inc()
                    if len(self.errors) < 16:
                        self.errors.append(e)
            if idx == 0:
                self.passes += 1
            del cluster  # don't pin the cluster across the sleep
            stop.wait(self.interval_s)

    def __enter__(self) -> "ReplicationService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


# -------------------------------------------------------- metrics reporter
class MetricsReporter:
    """Background observability daemon: periodically snapshots the
    cluster's metrics registry and publishes it to the replicated
    internal ``__metrics`` topic (DESIGN.md §9).

    The observability plane is itself a data stream: any plain consumer
    (or a future Web UI) can subscribe to ``__metrics`` and decode each
    record with :meth:`MetricsRegistry.decode_snapshot`. Publishing goes
    through the normal routed produce path, so snapshots keep flowing
    across broker leader kills — exactly when they are needed most; a
    publish that cannot complete right now (no quorum, partition offline
    mid-election) is recorded on ``errors`` (bounded) and retried on the
    next interval, never crashing the daemon.

    Lifecycle mirrors :class:`ReplicationService`: idempotent
    ``start``/``stop``, context manager, weak cluster reference (the
    daemon exits on its own once every other reference to the cluster is
    dropped), and a fresh stop event per start generation so a worker
    that outlived a ``stop()`` join timeout can never be resurrected.
    """

    def __init__(
        self,
        cluster: "BrokerCluster",
        *,
        interval_s: float = 0.05,
    ):
        self._cluster_ref = weakref.ref(cluster)
        self.interval_s = interval_s
        self.errors: list[BaseException] = []
        self.published = 0  # snapshots that reached the __metrics topic
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    @property
    def cluster(self) -> "BrokerCluster | None":
        return self._cluster_ref()

    @property
    def running(self) -> bool:
        return self._thread is not None and not self._stop.is_set()

    def start(self) -> "MetricsReporter":
        if self._thread is not None:
            return self
        self._stop = stop = threading.Event()
        t = threading.Thread(
            target=self._run, args=(stop,), name="metrics-reporter",
            daemon=True,
        )
        t.start()
        self._thread = t
        return self

    def stop(self, timeout: float = 5.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
        self._thread = None

    def _run(self, stop: threading.Event) -> None:
        while not stop.is_set():
            cluster = self._cluster_ref()
            if cluster is None:
                return  # cluster dropped without stop()
            try:
                cluster.publish_metrics()
                self.published += 1
            except (ClusterError, ControllerUnavailable):
                # quorum/election window — next interval retries
                cluster.metrics.counter(
                    "daemon_retries_total", daemon="metrics-reporter"
                ).inc()
            except BaseException as e:  # pragma: no cover - diagnostics
                cluster.metrics.counter(
                    "daemon_errors_total", daemon="metrics-reporter"
                ).inc()
                if len(self.errors) < 16:
                    self.errors.append(e)
            del cluster  # don't pin the cluster across the sleep
            stop.wait(self.interval_s)

    def __enter__(self) -> "MetricsReporter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


# ------------------------------------------------------------------ cluster
class BrokerCluster:
    """N replicated brokers behind a single :class:`StreamBackend` surface.

    Drop-in for :class:`StreamLog` in every upper layer; additionally
    exposes the broker-level protocol (``broker_append``/``broker_fetch``
    with leader checks and epoch fencing) used by the failover-aware
    clients, plus chaos hooks (``kill_broker``/``partition_broker``/
    ``restart_broker``/``heal_broker``) used by the fault-tolerance tests.

    ``follower_reads=True`` (default) lets the ``StreamBackend`` read path
    fall back to an in-sync follower — capped at the high watermark — when
    the partition leader is down, so consumers keep draining committed
    records while an election is pending. ``legacy_global_lock=True``
    restores the PR-1 data plane (one cluster-wide lock, fetch-based
    synchronous replication); it exists so ``benchmarks/replication.py``
    can measure the concurrent data plane against its own baseline.
    """

    def __init__(
        self,
        num_brokers: int = 3,
        *,
        default_replication_factor: int | None = None,
        default_acks: int | str = "all",
        allow_unclean_election: bool = False,
        follower_reads: bool = True,
        legacy_global_lock: bool = False,
        controller_nodes: int = 3,
        controller_lease_s: float = 1.0,
        txn_timeout_s: float = 60.0,
        metrics_enabled: bool = True,
        clock: Callable[[], float] | None = None,
    ):
        if num_brokers < 1:
            raise ValueError("need at least one broker")
        self._clock = clock or time.time
        # cluster-wide observability registry (DESIGN.md §9), shared with
        # every broker's log; metrics_enabled=False turns every probe
        # into a near-free no-op (the benchmark's control arm)
        self.metrics = MetricsRegistry(enabled=metrics_enabled)
        # bound hot-path handles (no registry lookup per produce/fetch);
        # harmless null singletons when the registry is disabled. The
        # latency histograms sample 1-in-8 after warm-up (see
        # metrics.Histogram) to stay inside the ≤5% overhead budget.
        self._h_produce_latency = self.metrics.histogram(
            "produce_latency_seconds", sample=8
        )
        self._h_commit_latency = self.metrics.histogram(
            "commit_latency_seconds", sample=8
        )
        self._h_fetch_latency = self.metrics.histogram(
            "fetch_latency_seconds", sample=8
        )
        self._c_produce_dups = self.metrics.counter("produce_duplicates_total")
        self.brokers: dict[int, Broker] = {
            i: Broker(i, StreamLog(clock=self._clock)) for i in range(num_brokers)
        }
        for br in self.brokers.values():
            br.log.metrics = self.metrics
        self.default_replication_factor = (
            num_brokers if default_replication_factor is None
            else default_replication_factor
        )
        self.default_acks = default_acks
        self.allow_unclean_election = allow_unclean_election
        self.follower_reads = follower_reads
        self._legacy = legacy_global_lock
        self._meta: dict[tuple[str, int], _PartitionCtl] = {}
        self._configs: dict[str, LogConfig] = {}
        self._topic_gens: dict[str, int] = {}  # name -> creation generation
        self._committed: dict[str, dict[TopicPartition, int]] = {}
        self._topic_seq = 0  # staggers replica placement across topics
        # idempotent-producer id space: grants are AllocatePid commands
        # committed to the metadata log, so ids stay unique across
        # controller failovers. _producer_epochs is the cluster-wide fence
        # (pid -> newest granted epoch): an append from an older epoch is
        # a zombie and rejected before it touches any partition.
        self._next_pid = 0
        self._producer_names: dict[str, tuple[int, int]] = {}
        self._producer_epochs: dict[int, int] = {}
        # transaction coordinator state: pid -> _TxnState, mutated only by
        # applying committed txn MetadataCommands (see _apply_txn). A
        # transaction in a prepare_* state whose driver died is finished
        # by controller_tick (_resume_pending_txns) — the decision is in
        # the replicated log, so the outcome survives any failover.
        self._txns: dict[int, _TxnState] = {}
        # per-pid phase-two serialization: _finish_txn's marker writes
        # run outside the metadata lock, so a client driver and the
        # controller tick can race into phase two for the same pid; the
        # per-pid lock makes the loser re-read coordinator state AFTER
        # the winner completed (it then sees complete/ongoing and backs
        # off) instead of resolving a successor transaction of the same
        # (pid, epoch) with the predecessor's snapshot. Acquired BEFORE
        # the metadata lock, never while holding it.
        self._txn_locks: dict[int, threading.Lock] = {}
        # a transaction left ongoing longer than this (its producer died
        # without ever re-initializing) is fenced and aborted by the
        # controller tick — Kafka's transaction.timeout.ms; without it an
        # abandoned txn would pin the partition LSO forever and stall
        # every read_committed consumer behind it
        self.txn_timeout_s = txn_timeout_s
        # chaos hook: the next _end_txn dies right after its prepare
        # decision commits, before any marker is written (models a
        # coordinator crash mid two-phase commit)
        self.crash_after_prepare = False
        # topology lock: topic create/delete, broker up/down, offset store.
        # Data-plane work runs under per-partition ctl locks instead; in
        # legacy mode every ctl shares _data_lock, restoring one-big-lock.
        self._meta_lock = make_rlock("metadata")
        self._data_lock = (
            make_rlock("partition", name="partition:legacy-global")
            if legacy_global_lock else None
        )
        self._services: list[ReplicationService] = []
        self._reporters: list[MetricsReporter] = []
        # the replicated control plane: every topology mutation below goes
        # through a command committed to this quorum's metadata log
        self.controller = QuorumController(
            controller_nodes, lease_s=controller_lease_s, clock=self._clock
        )
        # open 2PC trace spans (pid -> Span), begun at BeginTxn and ended
        # when CompleteTxn commits; coordinator-local bookkeeping only
        self._txn_spans: dict[int, object] = {}
        # (topic, partition) -> monotonic time its leader was observed
        # down, consumed by the elect_leader apply to measure election
        # duration (detection -> committed new leader)
        self._election_pending: dict[tuple[str, int], float] = {}
        self._register_gauges()

    def _register_gauges(self) -> None:
        """Register lazy gauge callbacks: expensive-to-compute state is
        evaluated only at snapshot/render time, never on the hot path.
        Closures hold the cluster weakly so the registry (owned by the
        cluster) never pins it into a reference cycle."""
        ref = weakref.ref(self)

        def controller_stat(name: str) -> Callable[[], float]:
            def fn() -> float:
                c = ref()
                return 0.0 if c is None else float(
                    getattr(c.controller, name)
                )
            return fn

        m = self.metrics
        m.gauge_fn("controller_elections", controller_stat("elections"))
        m.gauge_fn("controller_term_changes", controller_stat("term_changes"))
        m.gauge_fn("controller_quorum_rpcs", controller_stat("quorum_rpcs"))

        def apply_lag() -> float:
            c = ref()
            return 0.0 if c is None else float(c.controller.apply_lag())

        m.gauge_fn("controller_apply_lag", apply_lag)

        def log_stat(broker_id: int, key: str) -> Callable[[], float]:
            def fn() -> float:
                c = ref()
                if c is None:
                    return 0.0
                return float(c.brokers[broker_id].log.stats()[key])
            return fn

        for bid in self.brokers:
            for key in ("segments", "producer_state_entries", "open_txns"):
                m.gauge_fn(f"log_{key}", log_stat(bid, key), broker=bid)

    # ------------------------------------------------------------------ admin
    def create_topic(self, name: str, cfg: LogConfig | None = None) -> None:
        with self._meta_lock:
            if name in self._configs:
                raise ValueError(f"topic {name!r} already exists")
            cfg = replace(cfg) if cfg is not None else LogConfig()
            n = len(self.brokers)
            if cfg.replication_factor is None:
                # unspecified -> cluster default (as Kafka's broker-side
                # default.replication.factor), so a config written for
                # partitioning/retention never opts out of replication
                cfg.replication_factor = self.default_replication_factor
            rf = cfg.replication_factor
            if rf < 1 or rf > n:
                raise ValueError(
                    f"replication_factor {rf} not in [1, {n}] for {name!r}"
                )
            if cfg.min_insync_replicas is None:
                # default topics enforce the durability the docs promise:
                # acks=all is only accepted while >= 2 replicas are in sync
                # (so the ack implies single-broker-loss survival)
                cfg.min_insync_replicas = min(2, rf)
            cmd = MetadataCommand(
                kind="create_topic", topic=name, cfg=asdict(cfg),
                gen=self._topic_seq,
            )
            self.controller.submit(cmd)
            self._apply_metadata(cmd)

    def ensure_topic(self, name: str, cfg: LogConfig | None = None) -> None:
        with self._meta_lock:
            if name not in self._configs:
                self.create_topic(name, cfg)

    def delete_topic(self, name: str) -> None:
        with self._meta_lock:
            if name not in self._configs:
                return
            cmd = MetadataCommand(
                kind="delete_topic", topic=name, gen=self._topic_gens[name]
            )
            self.controller.submit(cmd)
            self._apply_metadata(cmd)

    def _apply_create_topic(self, cmd: MetadataCommand) -> None:
        with self._meta_lock:
            if cmd.topic in self._configs:
                return  # replay of an already-applied creation
            cfg = LogConfig(**cmd.cfg)
            n = len(self.brokers)
            rf = cfg.replication_factor
            seed = cmd.gen
            self._topic_seq = max(self._topic_seq, seed + 1)
            self._topic_gens[cmd.topic] = seed
            self._configs[cmd.topic] = cfg
            # every broker materializes the topic locally; only replica-set
            # members ever hold data for a given partition. Spill files are
            # namespaced per broker — replicas seal segments with identical
            # (topic, partition, base_offset) names and must not clobber
            # each other's files.
            for br in self.brokers.values():
                local = replace(cfg)
                if cfg.spill_dir is not None:
                    local.spill_dir = os.path.join(
                        cfg.spill_dir, f"broker-{br.broker_id}"
                    )
                br.log.ensure_topic(cmd.topic, local)
            for p in range(cfg.num_partitions):
                start = (p + seed) % n
                replicas = [(start + j) % n for j in range(rf)]
                ctl = _PartitionCtl(
                    cmd.topic, p, replicas, lock=self._data_lock, gen=seed
                )
                self._meta[(cmd.topic, p)] = ctl
                if not self.brokers[ctl.leader].up:
                    with ctl.lock:
                        try:
                            self._elect(ctl)
                        except ControllerUnavailable:
                            pass  # lazy paths elect once quorum returns

    def _apply_delete_topic(self, cmd: MetadataCommand) -> None:
        with self._meta_lock:
            if self._topic_gens.get(cmd.topic) != cmd.gen:
                return  # replay against a later same-name incarnation
            self._topic_gens.pop(cmd.topic, None)
            cfg = self._configs.pop(cmd.topic, None)
            if cfg is None:
                return
            ctls = [
                self._meta.pop((cmd.topic, p), None)
                for p in range(cfg.num_partitions)
            ]
            # sweep the partition locks (sanctioned meta→partition order)
            # before tearing down broker logs: any in-flight data-plane
            # operation finishes its current critical section against
            # intact logs, and its next one — it may still hold the popped
            # ctl — sees the offline fence instead of appending into a
            # recreated topic's logs behind the new ctl's accounting
            for ctl in ctls:
                if ctl is None:
                    continue
                with ctl.lock:
                    ctl.leader = None
                    ctl.isr = set()
                    # also empty the replica set: with unclean election
                    # enabled, a bare leader=None fence could be re-elected
                    # through from live replicas by a stale holder
                    ctl.replicas = []
            for br in self.brokers.values():
                br.log.delete_topic(cmd.topic)

    def init_producer(self, name: str | None = None) -> tuple[int, int]:
        """Grant an idempotent-producer identity: ``(pid, epoch)``.

        The grant is an ``AllocatePid`` command committed to the
        controller quorum's metadata log before it is usable, so producer
        ids stay unique across controller failovers (a successor inherits
        every committed grant and allocates above them).

        ``name`` opts into *named* re-initialization (Kafka's
        transactional.id shape): re-initializing an existing name returns
        the same pid with a bumped epoch, and the bump — also committed —
        fences the previous incarnation cluster-wide: any append it still
        has in flight fails with :class:`~repro.core.log.ProducerFenced`
        (zombie fencing). Raises :class:`ControllerUnavailable` with no
        quorum — an unfenced identity must never be handed out.
        """
        with self._meta_lock:
            if name is not None and name in self._producer_names:
                pid, ep = self._producer_names[name]
                ep += 1
            else:
                pid, ep = self._next_pid, 0
            cmd = MetadataCommand(
                kind="allocate_pid", pid=pid, producer_epoch=ep, name=name
            )
            self.controller.submit(cmd)
            self._apply_metadata(cmd)
            return pid, ep

    def _apply_allocate_pid(self, cmd: MetadataCommand) -> None:
        with self._meta_lock:
            self._next_pid = max(self._next_pid, cmd.pid + 1)
            if cmd.producer_epoch > self._producer_epochs.get(cmd.pid, -1):
                self._producer_epochs[cmd.pid] = cmd.producer_epoch
            if cmd.name is not None:
                known = self._producer_names.get(cmd.name)
                if known is None or cmd.producer_epoch >= known[1]:
                    self._producer_names[cmd.name] = (
                        cmd.pid, cmd.producer_epoch
                    )

    # ------------------------------------------------ transaction coordinator
    def _fence_pid(self, pid: int, epoch: int) -> None:
        known = self._producer_epochs.get(pid)
        if known is not None and epoch < known:
            raise ProducerFenced(
                f"producer {pid} epoch {epoch} fenced by granted epoch {known}"
            )

    def _submit_txn(self, cmd: MetadataCommand) -> None:
        """Commit one txn command to the metadata log and apply it.
        Caller holds the metadata lock."""
        self.controller.submit(cmd)
        self._apply_metadata(cmd)

    def begin_txn(self, pid: int, epoch: int) -> None:
        """Open a transaction for ``(pid, epoch)`` — a committed
        ``BeginTxn`` command. A stale incarnation's unfinished transaction
        is resolved first: a prepared one is driven to completion (its
        outcome is already durably decided), an ongoing one is aborted
        (its producer is fenced — it can never commit)."""
        with self._meta_lock:
            self._fence_pid(pid, epoch)
            st = self._txns.get(pid)
            stale = st.state if st is not None else None
            stale_epoch = st.epoch if st is not None else -1
        if stale is not None and stale.startswith("prepare"):
            self._finish_txn(pid)
        elif stale == "ongoing":
            if stale_epoch >= epoch:
                raise InvalidTxnState(
                    f"producer {pid} already has a transaction in "
                    f"progress (epoch {stale_epoch})"
                )
            self._end_txn(pid, stale_epoch, commit=False, internal=True)
        with self._meta_lock:
            st = self._txns.get(pid)
            if st is not None and st.state == "ongoing" and st.epoch >= epoch:
                raise InvalidTxnState(
                    f"producer {pid} already has a transaction in "
                    f"progress (epoch {st.epoch})"
                )
            seq = st.seq + 1 if st is not None else 0
            self._submit_txn(MetadataCommand(
                kind="begin_txn", pid=pid, producer_epoch=epoch, txn_seq=seq
            ))
            if self.metrics.enabled:
                # 2PC trace span: BeginTxn -> prepare -> markers ->
                # complete, with per-phase timings (DESIGN.md §9)
                self._txn_spans[pid] = self.metrics.span("txn_2pc", pid=pid)

    def _require_ongoing(self, pid: int, epoch: int) -> _TxnState:
        st = self._txns.get(pid)
        if st is None or st.state != "ongoing" or st.epoch != epoch:
            raise InvalidTxnState(
                f"producer {pid} (epoch {epoch}) has no ongoing transaction"
                f" (state: {st.state if st is not None else 'none'})"
            )
        return st

    def txn_add_partitions(
        self, pid: int, epoch: int, parts: Sequence[tuple[str, int]]
    ) -> None:
        """Register partitions the transaction will write to (Kafka's
        AddPartitionsToTxn) — the set the coordinator must put markers on
        at resolution, durably in the metadata log *before* the first
        transactional append lands on them."""
        with self._meta_lock:
            self._fence_pid(pid, epoch)
            st = self._require_ongoing(pid, epoch)
            new = [tuple(p) for p in parts if tuple(p) not in st.partitions]
            if not new:
                return
            self._submit_txn(MetadataCommand(
                kind="add_partitions_to_txn", pid=pid, producer_epoch=epoch,
                partitions=tuple(new), txn_seq=st.seq + 1,
            ))

    def txn_add_offsets(
        self,
        pid: int,
        epoch: int,
        group: str,
        offsets: dict[TopicPartition, int],
    ) -> None:
        """Attach consumer offsets to the transaction (Kafka's
        AddOffsetsToTxn + TxnOffsetCommit): they are applied to the
        replicated offset store if — and only if — the transaction
        commits, which is what makes read-process-write atomic."""
        with self._meta_lock:
            self._fence_pid(pid, epoch)
            st = self._require_ongoing(pid, epoch)
            enc = {f"{tp.topic}:{tp.partition}": off for tp, off in offsets.items()}
            self._submit_txn(MetadataCommand(
                kind="add_offsets_to_txn", pid=pid, producer_epoch=epoch,
                group=group, offsets=enc, txn_seq=st.seq + 1,
            ))

    def commit_txn(self, pid: int, epoch: int) -> None:
        """Two-phase commit: (1) commit a ``PrepareCommit`` decision to
        the controller quorum — from here the transaction WILL commit,
        whatever fails next; (2) write COMMIT markers on every registered
        partition, apply the attached consumer offsets, and commit
        ``CompleteTxn``. A crash between the phases leaves a prepared
        transaction that ``controller_tick`` finishes (idempotently) on
        any later heartbeat, so every touched partition converges to the
        same outcome across controller and broker failovers."""
        self._end_txn(pid, epoch, commit=True)

    def abort_txn(self, pid: int, epoch: int) -> None:
        """Two-phase abort: durable ``PrepareAbort`` decision, then ABORT
        markers — read_committed consumers never see the records."""
        self._end_txn(pid, epoch, commit=False)

    def resolve_txn(self, pid: int) -> None:
        """Finish a *prepared* transaction at its own recorded epoch —
        the recovery entry point for a restarted driver whose producer
        epoch has moved past the transaction it inherited (its
        ``commit_txn(pid, new_epoch)`` would be rejected as an epoch
        mismatch). No-op unless a prepare decision is pending; raises
        ``ClusterError`` when the cluster cannot complete it right now."""
        self._finish_txn(pid)

    def _end_txn(
        self, pid: int, epoch: int, *, commit: bool, internal: bool = False
    ) -> None:
        with self._meta_lock:
            if not internal:
                self._fence_pid(pid, epoch)
            st = self._txns.get(pid)
            prepared = "prepare_commit" if commit else "prepare_abort"
            if st is None or st.epoch != epoch:
                raise InvalidTxnState(
                    f"producer {pid} (epoch {epoch}) has no transaction"
                )
            if st.state == ("complete_commit" if commit else "complete_abort"):
                return  # a retried end of an already-finished transaction
            if st.state == "ongoing":
                self._submit_txn(MetadataCommand(
                    kind=prepared, pid=pid, producer_epoch=epoch,
                    txn_seq=st.seq + 1,
                ))
                sp = self._txn_spans.get(pid)
                if sp is not None:
                    sp.phase("prepare")
            elif st.state != prepared:
                # the opposite decision (or completion) is already durable
                raise InvalidTxnState(
                    f"transaction of producer {pid} is {st.state}; "
                    f"cannot {'commit' if commit else 'abort'}"
                )
            if self.crash_after_prepare:
                self.crash_after_prepare = False
                raise ControllerUnavailable(
                    "injected: transaction coordinator crashed after the "
                    "prepare decision committed, before marker writes"
                )
        self._finish_txn(pid)

    def _finish_txn(self, pid: int) -> None:
        """Phase two: write markers on every registered partition, apply
        offsets (commit only), record ``CompleteTxn``. Idempotent — every
        step no-ops where a previous attempt already succeeded (a racing
        second driver's duplicate ``CompleteTxn`` is dropped by the
        ``txn_seq`` guard) — and restartable: any ClusterError propagates
        with the transaction still in its prepare state for the next
        ``controller_tick`` (or a client retry) to re-drive. The marker
        writes deliberately run OUTSIDE the metadata lock (partition +
        controller locks only): a slow failover inside one transaction's
        phase two must not stall every other producer, consumer-offset
        commit and admin call on the cluster-wide lock. Concurrent
        finishers of the same pid serialize on its phase-two lock: the
        state snapshot happens inside it, so a finisher that lost the
        race observes the completed (or successor) state and backs off."""
        with self._meta_lock:
            lock = self._txn_locks.setdefault(
                pid, make_lock("txn", name=f"txn:{pid}"))
        with lock:
            with self._meta_lock:
                st = self._txns.get(pid)
                if st is None or not st.state.startswith("prepare"):
                    return  # already complete (or never prepared)
                commit = st.state == "prepare_commit"
                epoch = st.epoch
                parts = sorted(st.partitions)
                offsets = {g: dict(o) for g, o in st.offsets.items()}
            for topic, p in parts:
                self._write_marker(topic, p, pid, epoch, commit=commit)
            sp = self._txn_spans.get(pid)
            if sp is not None:
                sp.phase("markers")
            with self._meta_lock:
                st = self._txns.get(pid)
                if st is None or not st.state.startswith("prepare"):
                    return  # a concurrent driver completed it meanwhile
                if commit:
                    for group, offs in offsets.items():
                        for tps, off in offs.items():
                            t, _, pstr = tps.rpartition(":")
                            self.commit_offset(
                                group, TopicPartition(t, int(pstr)), off
                            )
                self._submit_txn(MetadataCommand(
                    kind="complete_txn", pid=pid, producer_epoch=epoch,
                    committed=commit, txn_seq=st.seq + 1,
                ))
                sp = self._txn_spans.pop(pid, None)
                if sp is not None:
                    sp.phase("complete")
                    sp.end("commit" if commit else "abort")
                self.metrics.counter(
                    "txn_commit_total" if commit else "txn_abort_total"
                ).inc()

    def _write_marker(
        self, topic: str, partition: int, pid: int, epoch: int, *, commit: bool
    ) -> None:
        """Write one COMMIT/ABORT control marker on a partition's leader
        and replicate it into the ISR (the marker is only 'written' once
        it is below the HW — an unreplicated marker on a dying leader is
        truncated and must be re-driven). No-ops when the partition has
        no open transaction for the pid: the marker already landed (this
        is a recovery re-drive), the partition never saw an append, or
        the topic is gone."""
        try:
            ctl = self._ctl(topic, partition)
        except (KeyError, IndexError):
            return  # topic deleted since the partition was registered
        last_err: ClusterError | None = None
        for _ in range(_ROUTED_RETRIES):
            with ctl.lock:
                try:
                    leader = self._leader_broker(ctl)
                    off = leader.log.append_control(
                        topic, partition, pid, epoch, abort=not commit
                    )
                    if off is None:
                        # no open transaction on the leader: either this
                        # partition never saw an append, or the marker
                        # already landed — possibly on a PREVIOUS attempt
                        # that never replicated it. Only a HW at or past
                        # the leader's end proves the close is durable
                        # (an unreplicated marker on a dying leader would
                        # be truncated, silently re-opening the txn on
                        # the survivors); force a pass otherwise.
                        if ctl.hw >= leader.log.end_offset(topic, partition):
                            return
                        self._replicate_partition(ctl)
                        if ctl.hw >= leader.log.end_offset(topic, partition):
                            return
                        last_err = NotLeaderError(topic, partition, ctl.leader)
                        continue
                    # push the marker straight to caught-up ISR followers
                    # (the acks=all hot-path shape): the one-record fetch
                    # carries its ctrl metadata verbatim, so follower txn
                    # state and timestamps track the leader's exactly;
                    # any lagging follower falls back to a full pass
                    vals, keys, ts, prods, _offs, _nxt, sbase = (
                        leader.log.replica_fetch(topic, partition, off, 1)
                    )
                    need_full = self._legacy
                    for bid in sorted(ctl.isr):
                        if bid == ctl.leader or need_full:
                            continue
                        fbr = self.brokers[bid]
                        if (
                            not fbr.up
                            or ctl.synced_epoch.get(bid) != ctl.epoch
                            or fbr.log.end_offset(topic, partition) != off
                        ):
                            need_full = True
                            continue
                        fbr.log.replica_append(
                            topic, partition, vals, keys, ts, prods=prods,
                            seg_base=sbase,
                        )
                    if need_full:
                        self._replicate_partition(ctl)
                    else:
                        ctl.hw = max(ctl.hw, off + 1)
                    if ctl.hw > off:
                        return
                    last_err = NotLeaderError(topic, partition, ctl.leader)
                except ClusterError as e:
                    # leadership in flux / no quorum for the ISR change:
                    # retry — the next pass elects through dead leaders
                    last_err = e
        raise last_err

    def _resume_pending_txns(self) -> None:
        """Finish transactions whose prepare decision is durable but
        whose driver died before markers landed everywhere — the
        controller-failover half of the two-phase commit — and fence +
        abort transactions left *ongoing* past ``txn_timeout_s`` (the
        producer died without re-initializing; its open txn would pin
        the LSO forever). Driven by ``controller_tick``."""
        now = self._clock()
        with self._meta_lock:
            pending = [
                pid for pid, st in self._txns.items()
                if st.state.startswith("prepare")
            ]
            expired = [
                (pid, st.epoch) for pid, st in self._txns.items()
                if st.state == "ongoing"
                and now - st.touched > self.txn_timeout_s
            ]
        for pid in pending:
            try:
                self._finish_txn(pid)
            except (ClusterError, ControllerUnavailable):
                continue  # partition/quorum unavailable: next tick retries
        for pid, ep in expired:
            try:
                with self._meta_lock:
                    st = self._txns.get(pid)
                    if st is None or st.state != "ongoing" or st.epoch != ep:
                        continue  # resolved since the snapshot
                    if self._producer_epochs.get(pid, -1) <= ep:
                        # fence the timed-out incarnation BEFORE aborting
                        # (Kafka bumps the producer epoch on transaction
                        # timeout): its late appends must not re-open the
                        # transaction after the abort markers land
                        cmd = MetadataCommand(
                            kind="allocate_pid", pid=pid,
                            producer_epoch=ep + 1,
                        )
                        self.controller.submit(cmd)
                        self._apply_metadata(cmd)
                # abort outside the metadata lock (phase two takes
                # partition locks; see _finish_txn)
                self._end_txn(pid, ep, commit=False, internal=True)
                self.metrics.counter("txn_timeout_total").inc()
            except (ClusterError, ControllerUnavailable, InvalidTxnState):
                continue  # next tick retries (fence bump is idempotent)

    def _apply_txn(self, cmd: MetadataCommand) -> None:
        """Apply one committed txn command — the coordinator state
        machine. Replay-idempotent via the per-pid ``txn_seq`` guard."""
        with self._meta_lock:
            st = self._txns.get(cmd.pid)
            if cmd.kind == "begin_txn":
                if st is not None and (
                    cmd.txn_seq <= st.seq or cmd.producer_epoch < st.epoch
                ):
                    return
                st = _TxnState(cmd.pid, cmd.producer_epoch, cmd.txn_seq)
                st.touched = self._clock()
                self._txns[cmd.pid] = st
                return
            if st is None or cmd.txn_seq is None or cmd.txn_seq <= st.seq:
                return
            st.seq = cmd.txn_seq
            st.touched = self._clock()
            if cmd.kind == "add_partitions_to_txn":
                st.partitions |= {tuple(p) for p in cmd.partitions}
            elif cmd.kind == "add_offsets_to_txn":
                st.offsets.setdefault(cmd.group, {}).update(cmd.offsets)
            elif cmd.kind == "prepare_commit":
                st.state = "prepare_commit"
            elif cmd.kind == "prepare_abort":
                st.state = "prepare_abort"
            elif cmd.kind == "complete_txn":
                st.state = (
                    "complete_commit" if cmd.committed else "complete_abort"
                )

    def txn_state(self, pid: int) -> str | None:
        """Coordinator state for a producer id (test/observability hook)."""
        with self._meta_lock:
            st = self._txns.get(pid)
            return st.state if st is not None else None

    def topics(self) -> list[str]:
        with self._meta_lock:
            return sorted(self._configs)

    def num_partitions(self, topic: str) -> int:
        with self._meta_lock:
            try:
                return self._configs[topic].num_partitions
            except KeyError:
                raise KeyError(f"unknown topic {topic!r}") from None

    def partition_ids(self) -> list[tuple[str, int]]:
        """Snapshot of every (topic, partition) — the daemon's work list."""
        with self._meta_lock:
            return list(self._meta)

    # --------------------------------------------------------------- metadata
    def _ctl(self, topic: str, partition: int) -> _PartitionCtl:
        try:
            return self._meta[(topic, partition)]
        except KeyError:
            if topic not in self._configs:
                raise KeyError(f"unknown topic {topic!r}") from None
            raise IndexError(f"{topic} has no partition {partition}") from None

    def metadata(self, topic: str) -> dict[int, PartitionMeta]:
        """MetadataResponse: partition -> (leader, epoch, replicas, isr, hw)."""
        with self._meta_lock:
            # ctl lookup is atomic with the partition count, so a racing
            # delete_topic yields a clean KeyError from num_partitions on
            # the next refresh, never a torn half-deleted view
            n = self.num_partitions(topic)
            ctls = [self._meta.get((topic, p)) for p in range(n)]
        return {p: ctl.meta() for p, ctl in enumerate(ctls) if ctl is not None}

    def partition_meta(self, topic: str, partition: int) -> PartitionMeta:
        """One partition's MetadataResponse — touches only its ctl lock."""
        return self._ctl(topic, partition).meta()

    def leader_for(self, topic: str, partition: int) -> int | None:
        ctl = self._ctl(topic, partition)
        with ctl.lock:
            return ctl.leader

    def describe(self) -> dict[str, dict[int, PartitionMeta]]:
        return {t: self.metadata(t) for t in self.topics()}

    # ------------------------------------------------------------ replication
    def _leader_broker(self, ctl: _PartitionCtl) -> Broker:
        if ctl.leader is None:
            # leaderless (offline) partition: recover lazily when an
            # eligible candidate exists — e.g. a replica rejoined while
            # the controller quorum was down, so no election could commit
            # at rejoin time. Never submit a None-leader election here:
            # that would churn epochs on every read of an offline
            # partition.
            cmd = self._election_command(ctl)
            if cmd.leader is not None:
                self.controller.submit(cmd)
                self._apply_metadata(cmd)
            if ctl.leader is None:
                raise PartitionOffline(
                    f"{ctl.topic}:{ctl.partition} has no leader"
                )
        br = self.brokers[ctl.leader]
        if not br.up:
            # the controller notices the dead leader lazily (e.g. a client
            # addressed the partition before any explicit failure event)
            self._elect(ctl)
            if ctl.leader is None:
                raise PartitionOffline(
                    f"{ctl.topic}:{ctl.partition} has no leader"
                )
            br = self.brokers[ctl.leader]
        return br

    def _replicate_partition(self, ctl: _PartitionCtl) -> None:
        """One follower-fetch pass: copy leader records to live followers,
        refresh ISR membership (any change routes through the controller
        quorum as a ``ShrinkIsr``/``ExpandIsr`` command — with no quorum
        the committed ISR stands and the HW simply stops advancing), and
        advance the high watermark."""
        with ctl.lock:
            leader = self._leader_broker(ctl)
            leo = leader.log.end_offset(ctl.topic, ctl.partition)
            new_isr = set(ctl.isr)
            copied = 0
            for bid in ctl.replicas:
                if bid == ctl.leader:
                    continue
                br = self.brokers[bid]
                if not br.up:
                    new_isr.discard(bid)
                    continue
                local_end = br.log.end_offset(ctl.topic, ctl.partition)
                last_synced = ctl.synced_epoch.get(bid, -1)
                if last_synced < ctl.epoch:
                    # leader-epoch reconciliation: this replica missed one or
                    # more elections, so records above the first missed
                    # epoch's start may be a divergent unacked suffix from
                    # its own time as leader — even below the since-advanced
                    # HW. Truncate to that point before fetching.
                    cut = min(
                        (
                            start
                            for e, start in ctl.epoch_starts.items()
                            if e > last_synced
                        ),
                        default=None,
                    )
                    if cut is not None and cut < local_end:
                        local_end = br.log.truncate_to(
                            ctl.topic, ctl.partition, cut
                        )
                if local_end > leo:
                    # deposed leader with an unacked suffix: reconcile
                    local_end = br.log.truncate_to(ctl.topic, ctl.partition, leo)
                lstart = leader.log.start_offset(ctl.topic, ctl.partition)
                if local_end < lstart:
                    # fell behind the leader's retention point while down:
                    # drop everything and re-fetch from the leader's log start
                    local_end = br.log.reset_to(ctl.topic, ctl.partition, lstart)
                while local_end < leo:
                    values, keys, timestamps, prods, offs, nxt, sbase = (
                        leader.log.replica_fetch(
                            ctl.topic, ctl.partition, local_end,
                            _REPLICA_FETCH_CHUNK,
                        )
                    )
                    if nxt <= local_end:
                        break
                    if values:
                        br.log.replica_append(
                            ctl.topic, ctl.partition, values, keys,
                            timestamps, prods=prods, offsets=offs,
                            seg_base=sbase,
                        )
                        copied += len(values)
                    # advance by the covered raw window, not the record
                    # count — a compacted range can deliver fewer records
                    # than offsets (or none at all)
                    local_end = nxt
                if local_end >= leo:
                    new_isr.add(bid)
                    ctl.synced_epoch[bid] = ctl.epoch
                else:
                    new_isr.discard(bid)
                # propagate the leader's compact point: the keep rule is
                # deterministic over the replicated records, so followers
                # cleaning to the same horizon converge on the same
                # surviving records (DESIGN.md §11)
                cp = leader.log.compact_point(ctl.topic, ctl.partition)
                if cp > br.log.compact_point(ctl.topic, ctl.partition):
                    br.log.compact_to(ctl.topic, ctl.partition, cp)
            new_isr.add(ctl.leader)
            ctl.synced_epoch[ctl.leader] = ctl.epoch
            if copied and self.metrics.enabled:
                mr = ctl.m_repl
                if mr is None:
                    mr = ctl.m_repl = self.metrics.counter(
                        "replication_records_total", topic=ctl.topic,
                        partition=ctl.partition,
                    )
                mr.inc(copied)
            self._propose_isr(ctl, new_isr)
            # the HW derives from the *committed* ISR: if the quorum was
            # unavailable and a dead member is still in the ISR, its stale
            # end pins the HW (safety: nothing is acked that could be lost)
            isr_ends = [
                self.brokers[b].log.end_offset(ctl.topic, ctl.partition)
                for b in ctl.isr
            ]
            # HW never regresses below what consumers may already have read
            ctl.hw = max(ctl.hw, min(isr_ends)) if isr_ends else ctl.hw

    def _propose_isr(self, ctl: _PartitionCtl, new_isr: set[int]) -> None:
        """Route an ISR membership change through the metadata log (Kafka's
        AlterPartition). Caller holds the partition lock. No-op when the
        membership is unchanged; swallowed when the controller quorum is
        unavailable — the committed ISR then stands, which only ever
        *withholds* HW advances and acks (safe)."""
        if new_isr == ctl.isr:
            return
        removed = ctl.isr - new_isr
        added = new_isr - ctl.isr
        try:
            if removed:
                cmd = MetadataCommand(
                    kind="shrink_isr", topic=ctl.topic, partition=ctl.partition,
                    epoch=ctl.epoch, isr=tuple(sorted(ctl.isr - removed)),
                    pversion=ctl.version + 1, gen=ctl.gen,
                )
                self.controller.submit(cmd)
                self._apply_metadata(cmd)
                self.metrics.counter(
                    "isr_shrink_total", topic=ctl.topic,
                    partition=ctl.partition,
                ).inc()
            if added:
                cmd = MetadataCommand(
                    kind="expand_isr", topic=ctl.topic, partition=ctl.partition,
                    epoch=ctl.epoch, isr=tuple(sorted(ctl.isr | added)),
                    pversion=ctl.version + 1, gen=ctl.gen,
                )
                self.controller.submit(cmd)
                self._apply_metadata(cmd)
                self.metrics.counter(
                    "isr_expand_total", topic=ctl.topic,
                    partition=ctl.partition,
                ).inc()
        except ControllerUnavailable:
            pass

    def _commit_batch(
        self,
        ctl: _PartitionCtl,
        values: Sequence[bytes],
        keys: Sequence[bytes | None] | None,
        now_ms: int,
        first: int,
        last: int,
        producer: tuple[int, int, int] | None = None,
        txn: bool = False,
    ) -> None:
        """Synchronous ISR replication for one acked batch (caller holds
        the partition lock and just appended ``[first, last]`` on the
        leader).

        Hot path: the records are still in hand, so push them straight to
        every caught-up ISR follower — no leader re-fetch, no per-record
        materialization — and advance the HW. Any follower that lagged
        (acks<all appends in between, missed epochs, just rejoined) falls
        back to a full reconciliation pass, which re-derives ISR and HW
        from scratch.

        Invariant this relies on (and preserves): between replication
        passes, every non-leader ISR member holds the same prefix-
        consistent log with the same end offset — followers only advance
        via full passes (which equalize them at the leader's end) or via
        this push (all caught-up followers, or the full-pass fallback).
        Election survivors are therefore prefix-identical, which is what
        makes the caller's ``hw > last`` ack test exact: the HW can only
        pass ``last`` if the committed records at ``[first, last]`` are
        this batch.
        """
        if self._legacy:
            self._replicate_partition(ctl)
            return
        need_full = False
        pushed = 0
        for bid in sorted(ctl.isr):
            if bid == ctl.leader:
                continue
            fbr = self.brokers[bid]
            if (
                not fbr.up
                or ctl.synced_epoch.get(bid) != ctl.epoch
                or fbr.log.end_offset(ctl.topic, ctl.partition) != first
            ):
                need_full = True
                continue
            # the push carries the batch's producer stamp, so the
            # follower's dedup table tracks the leader's — if this
            # follower wins a mid-append election, the client's retry of
            # this very batch resolves to these offsets instead of
            # re-appending (exactly-once through failover)
            fbr.log.replica_append(
                ctl.topic, ctl.partition, values, keys, now_ms,
                producer=producer, txn=txn,
            )
            pushed += 1
        if pushed and self.metrics.enabled:
            mr = ctl.m_repl
            if mr is None:
                mr = ctl.m_repl = self.metrics.counter(
                    "replication_records_total", topic=ctl.topic,
                    partition=ctl.partition,
                )
            mr.inc(pushed * len(values))
        if need_full:
            self._replicate_partition(ctl)
        else:
            # leader + every ISR follower now hold [.., last]
            ctl.hw = max(ctl.hw, last + 1)

    def replicate_partition(self, topic: str, partition: int) -> None:
        """One replication pass for one partition (daemon work unit)."""
        self._replicate_partition(self._ctl(topic, partition))

    def replicate_all(self) -> None:
        """Drive one replication pass for every partition (an explicit
        cluster-wide tick; the background daemon does the same per
        partition on an interval)."""
        for topic, p in self.partition_ids():
            try:
                self.replicate_partition(topic, p)
            except PartitionOffline:
                continue  # no live leader to fetch from — skip, not abort
            except ControllerUnavailable:
                continue  # no controller quorum — leadership frozen for now
            except (KeyError, IndexError):
                continue  # topic deleted since the snapshot

    # ------------------------------------------------------- daemon lifecycle
    def start_replication(
        self, *, interval_s: float = 0.02, workers: int = 2
    ) -> ReplicationService:
        """Start (and register) a background replication daemon."""
        svc = ReplicationService(self, interval_s=interval_s, workers=workers)
        self._services.append(svc)
        return svc.start()

    def stop_replication(self) -> None:
        """Stop every registered replication daemon."""
        for svc in self._services:
            svc.stop()
        self._services = []

    @property
    def _daemon_active(self) -> bool:
        return any(s.running for s in self._services)

    # ---------------------------------------------------------- observability
    def metrics_text(self) -> str:
        """Prometheus-style text dump of every metric series (zero
        dependencies) — for humans and CI artifacts."""
        return self.metrics.render_text()

    def metrics_snapshot(self) -> dict:
        """JSON-safe point-in-time dump of the cluster registry."""
        return self.metrics.snapshot()

    def publish_metrics(self) -> tuple[int, int]:
        """Snapshot the registry and produce it to the replicated
        internal ``__metrics`` topic (creating it on first use,
        ``rf=min(3, brokers)``). Returns ``(partition, offset)``. Goes
        through the routed produce path, so a snapshot lands even while
        a broker leader election is being completed lazily. Raises
        ``ClusterError``/``ControllerUnavailable`` when the cluster
        cannot accept it right now — callers (the reporter daemon)
        retry on the next interval."""
        payload = json.dumps(
            self.metrics.snapshot(), sort_keys=True
        ).encode("utf-8")
        if METRICS_TOPIC not in self._configs:
            self.ensure_topic(METRICS_TOPIC, LogConfig(
                num_partitions=1,
                replication_factor=min(3, len(self.brokers)),
            ))
        return self.produce(METRICS_TOPIC, payload)

    def start_metrics_reporter(
        self, *, interval_s: float = 0.05
    ) -> MetricsReporter:
        """Start (and register) a background metrics reporter daemon."""
        rep = MetricsReporter(self, interval_s=interval_s)
        self._reporters.append(rep)
        return rep.start()

    def stop_metrics_reporter(self) -> None:
        """Stop every registered metrics reporter."""
        for rep in self._reporters:
            rep.stop()
        self._reporters = []

    # ----------------------------------------------------------- elections
    def _election_command(self, ctl: _PartitionCtl) -> MetadataCommand:
        """Deterministic leader choice: lowest-id live ISR member wins
        (unclean election falls back to any live replica). Caller holds
        the partition lock; the choice becomes an ``ElectLeader`` command
        that must commit to the controller quorum before it applies."""
        candidates = sorted(
            b for b in ctl.isr if self.brokers[b].up and b != ctl.leader
        )
        if not candidates and self.allow_unclean_election:
            # last resort: any live replica, acked records may be lost
            candidates = sorted(
                b for b in ctl.replicas if self.brokers[b].up
            )
        new_leader = candidates[0] if candidates else None
        # live ISR survivors stay in-sync (they reconcile against the new
        # leader on the next replication pass); a leaderless (offline)
        # partition keeps its last-known ISR as the eligibility list
        isr = None
        if new_leader is not None:
            isr = tuple(sorted(
                {b for b in ctl.isr if self.brokers[b].up} | {new_leader}
            ))
        return MetadataCommand(
            kind="elect_leader", topic=ctl.topic, partition=ctl.partition,
            leader=new_leader, epoch=ctl.epoch + 1, isr=isr,
            pversion=ctl.version + 1, gen=ctl.gen,
        )

    def _elect(self, ctl: _PartitionCtl) -> None:
        """Change partition leadership through the replicated control
        plane: the election decision commits to the controller quorum's
        metadata log, then applies. Caller holds the partition lock.
        Raises :class:`ControllerUnavailable` (leadership unchanged) when
        the command cannot reach a controller majority — a partitioned
        controller minority can never move a leader (split-brain safety).
        """
        cmd = self._election_command(ctl)
        self.controller.submit(cmd)
        self._apply_metadata(cmd)

    # ------------------------------------------------------------ chaos hooks
    def kill_broker(self, broker_id: int, *, defer_election: bool = False) -> None:
        """Hard-crash a broker: every partition it led fails over.

        The liveness transition routes through the controller quorum as a
        ``RegisterBroker`` command; its application shrinks ISRs and
        elects through the dead leader. ``defer_election=True`` models the
        detection gap before the controller notices (Kafka's session
        timeout): the broker is down but nothing is registered — elections
        wait for the next replication pass (a daemon tick or explicit
        ``replicate_all``) or the next *StreamBackend-facade* produce/read
        to that partition, which elect through the dead leader lazily.
        Direct broker-protocol clients (``ClusterProducer``/
        ``ClusterConsumer``) see :class:`BrokerUnavailable` until one of
        those runs — the window follower reads are designed to bridge.
        With no controller quorum the registration itself is deferred the
        same way (the daemon retries once quorum returns).
        """
        with self._meta_lock:
            self.brokers[broker_id].alive = False
            self._note_leader_down(broker_id)
            if not defer_election:
                self._register_broker(broker_id, up=False)

    def partition_broker(self, broker_id: int, *, defer_election: bool = False) -> None:
        """Network-partition a broker away from the cluster."""
        with self._meta_lock:
            self.brokers[broker_id].reachable = False
            self._note_leader_down(broker_id)
            if not defer_election:
                self._register_broker(broker_id, up=False)

    def restart_broker(self, broker_id: int) -> None:
        """Bring a crashed broker back; it rejoins as a follower."""
        with self._meta_lock:
            self.brokers[broker_id].alive = True
            if not self._register_broker(broker_id, up=True):
                # no controller quorum: still catch up physically — ISR
                # re-entry (a quorum-committed ExpandIsr) waits for quorum
                self._rejoin(broker_id)

    def heal_broker(self, broker_id: int) -> None:
        """Heal a network partition; the broker rejoins as a follower."""
        with self._meta_lock:
            self.brokers[broker_id].reachable = True
            if not self._register_broker(broker_id, up=True):
                self._rejoin(broker_id)

    def _note_leader_down(self, broker_id: int) -> None:
        """Stamp election-duration start for every partition the dying
        broker leads (detection time; the matching elect_leader apply
        records the duration). Caller holds the metadata lock; ctl.leader
        is read without the ctl lock — this is observability bookkeeping,
        a torn read only mis-times one measurement."""
        if not self.metrics.enabled:
            return
        now = time.monotonic()
        for (topic, p), ctl in self._meta.items():
            if ctl.leader == broker_id:
                self._election_pending.setdefault((topic, p), now)

    def _register_broker(self, broker_id: int, *, up: bool) -> bool:
        """Commit a broker liveness transition to the metadata log and
        apply it. Returns False (transition stays pending) when there is
        no controller quorum — lazy election / rejoin paths complete the
        work once quorum returns."""
        cmd = MetadataCommand(kind="register_broker", broker_id=broker_id, up=up)
        try:
            self.controller.submit(cmd)
        except ControllerUnavailable:
            return False
        self._apply_metadata(cmd)
        return True

    def _apply_register_broker(self, cmd: MetadataCommand) -> None:
        bid = cmd.broker_id
        if cmd.up:
            self._rejoin(bid)
            return
        with self._meta_lock:
            ctls = list(self._meta.values())
        for ctl in ctls:
            with ctl.lock:
                if bid in ctl.isr and bid != ctl.leader:
                    self._propose_isr(ctl, set(ctl.isr) - {bid})
                if ctl.leader == bid and not self.brokers[bid].up:
                    try:
                        self._elect(ctl)
                    except ControllerUnavailable:
                        # quorum lost mid-sweep: this partition's election
                        # stays pending; daemon/lazy paths retry
                        continue

    def _rejoin(self, broker_id: int) -> None:
        br = self.brokers[broker_id]
        with self._meta_lock:
            ctls = list(self._meta.values())
        for ctl in ctls:
            with ctl.lock:
                if broker_id not in ctl.replicas:
                    continue
                if ctl.leader is None:
                    # partition was offline — the rejoining replica restores it
                    try:
                        self._elect(ctl)
                    except ControllerUnavailable:
                        pass
                    continue
                if ctl.leader == broker_id:
                    continue
                try:
                    # catch up as a follower; _replicate_partition performs
                    # the leader-epoch truncation before fetching
                    self._replicate_partition(ctl)
                except PartitionOffline:
                    # recorded leader dead (deferred election) with no other
                    # live ISR member: this partition stays offline, but the
                    # rejoin sweep — and the offset mirror below — continue
                    continue
                except ControllerUnavailable:
                    continue
        # mirror the (cluster-wide replicated) offset store back onto it
        with self._meta_lock:
            committed = {g: dict(o) for g, o in self._committed.items()}
        for group, offsets in committed.items():
            for tp, off in offsets.items():
                br.log.commit_offset(group, tp, off)

    # -------------------------------------------------- metadata application
    def _apply_metadata(self, cmd: MetadataCommand) -> None:
        """Apply one COMMITTED metadata command to cluster state — the
        state-machine half of the replicated control plane. Idempotent:
        partition commands are guarded by ``pversion``/topic generation,
        topic commands by existence, broker commands by liveness checks —
        so controller-failover replay (``controller_tick`` draining the
        committed-but-unapplied backlog) can never half-apply or
        double-apply a transition."""
        kind = cmd.kind
        if kind == "noop":
            return
        if kind == "register_broker":
            self._apply_register_broker(cmd)
            return
        if kind == "create_topic":
            self._apply_create_topic(cmd)
            return
        if kind == "delete_topic":
            self._apply_delete_topic(cmd)
            return
        if kind == "allocate_pid":
            self._apply_allocate_pid(cmd)
            return
        if kind in (
            "begin_txn", "add_partitions_to_txn", "add_offsets_to_txn",
            "prepare_commit", "prepare_abort", "complete_txn",
        ):
            self._apply_txn(cmd)
            return
        # partition-scoped commands
        key = (cmd.topic, cmd.partition)
        ctl = self._meta.get(key)
        if ctl is None:
            return
        with ctl.lock:
            # re-validate under the ctl lock: a concurrent delete_topic
            # pops the ctl from _meta (under the metadata lock) before
            # fencing it under this lock — a backlog replay that applied
            # past that check could un-fence a deleted partition
            if self._meta.get(key) is not ctl:
                return  # deleted (and fenced) since the lookup
            if cmd.gen is not None and self._topic_gens.get(cmd.topic) != cmd.gen:
                return  # topic deleted/recreated since the command committed
            if cmd.pversion is None or cmd.pversion <= ctl.version:
                return  # already applied (or a stale duplicate)
            ctl.version = cmd.pversion
            if kind == "elect_leader":
                ctl.epoch = cmd.epoch
                ctl.leader = cmd.leader
                if self.metrics.enabled:
                    # inside the pversion guard, so controller-failover
                    # replay of the same committed election can never
                    # double-count (exactly once per election)
                    self.metrics.counter(
                        "partition_elections_total", topic=ctl.topic,
                        partition=ctl.partition,
                    ).inc()
                    since = self._election_pending.pop(key, None)
                    if since is not None:
                        self.metrics.histogram(
                            "election_duration_seconds"
                        ).record(time.monotonic() - since)
                if cmd.leader is None:
                    return  # offline fence: epoch bumped, ISR retained
                ctl.isr = set(cmd.isr)
                new_leo = self.brokers[cmd.leader].log.end_offset(
                    ctl.topic, ctl.partition
                )
                ctl.epoch_starts[cmd.epoch] = new_leo
                ctl.synced_epoch[cmd.leader] = cmd.epoch
                # at acks=all the new leader holds every record below the
                # HW, so the HW is stable; an unclean (or acks<all)
                # election may regress it
                ctl.hw = min(ctl.hw, new_leo)
                # a deposed-but-live old leader (healed network partition)
                # is reconciled as a follower on the next replication pass
            elif kind in ("shrink_isr", "expand_isr"):
                ctl.isr = set(cmd.isr)

    # -------------------------------------------------- controller lifecycle
    def controller_tick(self) -> bool:
        """One control-plane heartbeat: quorum lease renewal / controller
        election, then apply any committed-but-unapplied metadata backlog
        (commands a dead controller leader committed but never applied),
        then — when controller leadership changed — complete partition
        elections the dead controller left pending. Returns True on a
        controller leadership change. Driven by the replication daemon."""
        changed = self.controller.tick()
        for entry in self.controller.take_unapplied():
            self._apply_metadata(entry.command)
        if changed:
            self._complete_pending_elections()
        # two-phase-commit recovery: transactions whose prepare decision
        # is durable but whose driver died finish here, on any tick — not
        # just leadership changes (the driver may have died without its
        # controller)
        self._resume_pending_txns()
        return changed

    def _complete_pending_elections(self) -> None:
        """Elect through every dead partition leader — and restore
        leaderless (offline) partitions that regained an eligible replica
        while the quorum was down (a new controller leader's first duty
        after winning its own election)."""
        with self._meta_lock:
            ctls = list(self._meta.values())
        for ctl in ctls:
            with ctl.lock:
                leader_down = (
                    ctl.leader is not None and not self.brokers[ctl.leader].up
                )
                if not leader_down and ctl.leader is not None:
                    continue
                cmd = self._election_command(ctl)
                if ctl.leader is None and cmd.leader is None:
                    continue  # still no eligible candidate: stay offline
                try:
                    self.controller.submit(cmd)
                    self._apply_metadata(cmd)
                except ControllerUnavailable:
                    return

    def kill_controller(self) -> int:
        """Chaos hook: crash the current controller-leader node (electing
        one first if the quorum is fresh). Returns the killed node id; the
        surviving quorum elects a successor on the next controller tick
        and completes any partition elections left pending."""
        lid = self.controller.ensure_leader()
        self.controller.kill_node(lid)
        return lid

    def restart_controller(self, node_id: int) -> None:
        """Bring a crashed controller node back; it rejoins as a follower
        and its log is reconciled by the next leader heartbeat."""
        self.controller.restart_node(node_id)

    def live_brokers(self) -> list[int]:
        return sorted(b.broker_id for b in self.brokers.values() if b.up)

    # ------------------------------------------- broker-level client protocol
    def _check_leader(self, broker_id: int, ctl: _PartitionCtl) -> Broker:
        br = self.brokers.get(broker_id)
        if br is None or not br.up:
            raise BrokerUnavailable(f"broker {broker_id} is down")
        if ctl.leader != broker_id:
            raise NotLeaderError(ctl.topic, ctl.partition, ctl.leader)
        return br

    def broker_append(
        self,
        broker_id: int,
        topic: str,
        partition: int,
        values: Sequence[bytes],
        *,
        keys: Sequence[bytes | None] | None = None,
        acks: int | str | None = None,
        epoch: int | None = None,
        producer: tuple[int, int, int] | None = None,
        transactional: bool = False,
    ) -> tuple[int, int]:
        """Leader-side ProduceRequest. Returns ``(first, last)`` offsets.

        ``transactional=True`` (requires ``producer``) marks the batch as
        part of the producer's open transaction: it replicates and acks
        like any idempotent batch, but stays above the LSO — invisible to
        ``read_committed`` consumers — until the transaction coordinator
        writes its COMMIT/ABORT marker.

        ``acks='all'`` replicates to every live ISR follower and advances
        the high watermark before returning — the acknowledged records are
        then on every ISR member, so they survive any single broker loss
        whenever the ISR held >= 2 members at ack time
        (``min_insync_replicas=2`` makes that a hard precondition).

        If leadership moves mid-append (the addressed broker died between
        the leader check and the HW advance) and the batch did not commit,
        the ack is withheld and :class:`NotLeaderError` raised instead —
        the records sit only on the deposed leader, where epoch
        reconciliation will truncate them, and clients retry against the
        new leader. The commit test is ``hw > last``: the partition lock
        is held across append+commit, so offsets ``[first, last]`` can
        hold no other producer's records — if the HW passed ``last``, the
        committed records *are* this batch (even when a direct-pushed
        follower won the election mid-call) and acking is exact, never
        duplicated. Zero-acked-loss therefore holds under concurrent
        broker failures without re-append duplicates.

        ``producer=(pid, epoch, base_seq)`` makes the append idempotent:
        the leader's per-partition producer-state table resolves a retried
        batch — same pid/epoch/sequences, e.g. the response to an append
        that *did* commit was lost, so the client re-sent it — to its
        original offsets instead of re-appending. That closes the one
        duplicate window the ``hw > last`` test cannot: a committed append
        whose ack never reached the client. A stale producer epoch raises
        :class:`~repro.core.log.ProducerFenced` (zombie fencing; fatal,
        never retried); a sequence gap raises
        :class:`~repro.core.log.OutOfOrderSequence`.
        """
        acks = self.default_acks if acks is None else acks
        if acks not in (0, 1, "all", -1):
            raise ValueError(f"bad acks {acks!r}; want 0, 1, or 'all'")
        ctl = self._ctl(topic, partition)
        m = self.metrics
        t0 = time.perf_counter() if m.enabled else 0.0
        with ctl.lock:
            br = self._check_leader(broker_id, ctl)
            if epoch is not None and epoch != ctl.epoch:
                raise NotLeaderError(topic, partition, ctl.leader)
            if acks in ("all", -1):
                cfg = self._configs.get(topic)  # plain dict read: no meta
                if cfg is None:                 # lock under a ctl lock
                    # topic deleted under us — surface the offline fence,
                    # not a raw KeyError the client retry loops don't know
                    raise PartitionOffline(f"{topic}:{partition} was deleted")
                live_isr = [b for b in ctl.isr if self.brokers[b].up]
                if len(live_isr) < cfg.min_insync_replicas:
                    raise NotEnoughReplicasError(
                        f"{topic}:{partition} ISR {sorted(live_isr)} below "
                        f"min.insync.replicas={cfg.min_insync_replicas}"
                    )
            # stamp the batch once so leader and followers agree on record
            # timestamps (and therefore on retention_ms expiry)
            now_ms = int(self._clock() * 1000)
            if producer is not None:
                pid, pep, pseq = producer
                known = self._producer_epochs.get(pid)  # plain dict read
                if known is not None and pep < known:
                    # cluster-wide zombie fence: a newer incarnation of
                    # this producer id was granted (AllocatePid with a
                    # bumped epoch) — reject even on partitions the new
                    # incarnation has not written to yet
                    raise ProducerFenced(
                        f"producer {pid} epoch {pep} fenced by granted "
                        f"epoch {known}"
                    )
                first, last, dup = br.log.producer_append(
                    topic, partition, values, keys, now_ms, pid, pep, pseq,
                    txn=transactional,
                )
                if dup:
                    # the batch is already in the log from a previous
                    # delivery; make sure it is *committed* before acking
                    # its original offsets (it may have ridden a direct
                    # push whose HW advance died with the old leader)
                    if acks in ("all", -1) and ctl.hw <= last:
                        self._replicate_partition(ctl)
                        if ctl.hw <= last:
                            raise NotLeaderError(topic, partition, ctl.leader)
                    if m.enabled:
                        self._c_produce_dups.inc()
                        self._h_produce_latency.record(
                            time.perf_counter() - t0
                        )
                    return first, last
            else:
                first, last = br.log.replica_append(
                    topic, partition, values, keys, now_ms
                )
            if acks in ("all", -1):
                tc = time.perf_counter() if m.enabled else 0.0
                self._commit_batch(
                    ctl, values, keys, now_ms, first, last, producer,
                    txn=transactional,
                )
                if m.enabled:
                    # acks=all commit latency: ISR push + HW advance
                    self._h_commit_latency.record(time.perf_counter() - tc)
                if ctl.hw <= last:
                    # leadership moved under us mid-append and the batch
                    # did not commit: it must not be acknowledged (a new
                    # leader without it caps the HW at `first` or below)
                    raise NotLeaderError(topic, partition, ctl.leader)
            if m.enabled:
                mp = ctl.m_produce
                if mp is None:
                    mp = ctl.m_produce = m.counter(
                        "produce_records_total", topic=topic,
                        partition=partition,
                    )
                mp.inc(len(values))
                self._h_produce_latency.record(time.perf_counter() - t0)
            return first, last

    def broker_fetch(
        self,
        broker_id: int,
        topic: str,
        partition: int,
        offset: int,
        max_records: int = 1024,
        *,
        allow_follower: bool = False,
        isolation: str | None = None,
    ) -> RecordBatch:
        """Leader-side FetchRequest, capped at the high watermark.

        With ``allow_follower=True`` a fetch addressed to an **in-sync**
        follower is served from that follower's local log (still capped at
        the HW) instead of raising :class:`NotLeaderError` — records below
        the HW are on every ISR member and immutable, so the response is
        stale-bounded but never divergent. Out-of-sync replicas never
        serve: their log may hold a deposed leader's suffix below the HW.

        ``isolation="read_committed"`` additionally caps the read at the
        serving replica's last stable offset (LSO) and filters out control
        markers and aborted transactions' records. Below the HW every ISR
        member derives the identical transaction state from its identical
        log, so follower reads stay exact at read_committed too.
        """
        ctl = self._ctl(topic, partition)
        m = self.metrics
        t0 = time.perf_counter() if m.enabled else 0.0
        with ctl.lock:
            br = self.brokers.get(broker_id)
            if br is None or not br.up:
                raise BrokerUnavailable(f"broker {broker_id} is down")
            if ctl.leader == broker_id:
                if not self._daemon_active or ctl.hw <= offset:
                    self._replicate_partition(ctl)  # opportunistic HW advance
                batch = self._read_visible(
                    br, ctl, offset, max_records, isolation
                )
            elif not allow_follower or broker_id not in ctl.isr:
                raise NotLeaderError(topic, partition, ctl.leader)
            else:
                batch = self._read_visible(
                    br, ctl, offset, max_records, isolation
                )
            if m.enabled:
                self._h_fetch_latency.record(time.perf_counter() - t0)
            return batch

    def _serving_follower(self, ctl: _PartitionCtl) -> Broker | None:
        """Lowest-id live in-sync non-leader replica, or None — the single
        eligibility rule for every follower-read fallback path. Caller
        holds the ctl lock."""
        for bid in sorted(ctl.isr):
            if bid != ctl.leader and self.brokers[bid].up:
                return self.brokers[bid]
        return None

    def _read_visible(
        self,
        br: Broker,
        ctl: _PartitionCtl,
        offset: int,
        max_records: int,
        isolation: str | None = None,
    ) -> RecordBatch:
        """Serve a read from ``br``'s local log, capped at the high
        watermark. ``br`` is the leader or an in-sync follower — an ISR
        member's log always extends to the HW, so bounding by its own end
        offset is equivalent for both. read_committed caps additionally
        at the serving replica's LSO."""
        end = br.log.end_offset(ctl.topic, ctl.partition)
        if offset > end:
            raise OffsetOutOfRange(
                f"{ctl.topic}:{ctl.partition} offset {offset} > end {end}"
            )
        cap = min(ctl.hw, end)
        if isolation == "read_committed":
            cap = min(cap, br.log.last_stable_offset(ctl.topic, ctl.partition))
        n = min(max_records, max(cap - offset, 0))
        if n <= 0:
            return RecordBatch(
                topic=ctl.topic,
                partition=ctl.partition,
                first_offset=offset,
                values=[],
                timestamps=[],
            )
        batch = br.log.read(ctl.topic, ctl.partition, offset, n, isolation)
        if self.metrics.enabled and len(batch):
            mf = ctl.m_fetch
            if mf is None:
                mf = ctl.m_fetch = self.metrics.counter(
                    "fetch_records_total", topic=ctl.topic,
                    partition=ctl.partition,
                )
            mf.inc(len(batch))
        return batch

    # ------------------------------------- StreamBackend facade (StreamLog)
    # Everything below makes the cluster a drop-in for StreamLog: internal
    # routing retries through leader changes, so the pipeline/trainer/server
    # survive a broker loss mid-call without knowing about brokers at all.
    def _routed_append(
        self,
        topic: str,
        values: Sequence[bytes],
        keys: Sequence[bytes | None] | None,
        partition: int | None,
        acks: int | str | None = None,
    ) -> tuple[int, int, int]:
        nparts = self.num_partitions(topic)
        if partition is None:
            partition = default_partition(
                keys, nparts, int(self._clock() * 1000)
            )
        ctl = self._ctl(topic, partition)
        last_err: ClusterError | None = None
        # Leadership is pinned while the partition lock is held, but the
        # addressed broker may die between the leader check and the ack
        # (flags flip without the partition lock) — re-resolve and retry;
        # _leader_broker elects through the dead leader. PartitionOffline
        # propagates: there is nothing to retry against.
        for _ in range(_ROUTED_RETRIES):
            with ctl.lock:
                leader = self._leader_broker(ctl)
                try:
                    first, last = self.broker_append(
                        leader.broker_id, topic, partition, values,
                        keys=keys, acks=acks,
                    )
                    return partition, first, last
                except (NotLeaderError, BrokerUnavailable) as e:
                    last_err = e
        raise last_err

    def produce(
        self,
        topic: str,
        value: bytes,
        *,
        key: bytes | None = None,
        partition: int | None = None,
        acks: int | str | None = None,
    ) -> tuple[int, int]:
        p, first, _ = self._routed_append(topic, [value], [key], partition, acks)
        return p, first

    def produce_batch(
        self,
        topic: str,
        values: Sequence[bytes],
        *,
        keys: Sequence[bytes | None] | None = None,
        partition: int | None = None,
        acks: int | str | None = None,
    ) -> tuple[int, int, int]:
        return self._routed_append(topic, values, keys, partition, acks)

    def read(
        self,
        topic: str,
        partition: int,
        offset: int,
        max_records: int = 1024,
        isolation: str | None = None,
    ) -> RecordBatch:
        ctl = self._ctl(topic, partition)
        with ctl.lock:
            leader_id = ctl.leader
            if leader_id is not None and self.brokers[leader_id].up:
                # live leader: serve from it; skip the inline replication
                # pass when a daemon is advancing the HW in the background
                # (unless the read would come back empty without it)
                if not self._daemon_active or ctl.hw <= offset:
                    self._replicate_partition(ctl)
                return self._read_visible(
                    self.brokers[ctl.leader], ctl, offset, max_records, isolation
                )
            if self.follower_reads:
                # leader down/None: keep serving committed records from an
                # in-sync follower while the election is pending
                follower = self._serving_follower(ctl)
                if follower is not None:
                    return self._read_visible(
                        follower, ctl, offset, max_records, isolation
                    )
            leader = self._leader_broker(ctl)  # lazy election / offline
            self._replicate_partition(ctl)
            return self._read_visible(leader, ctl, offset, max_records, isolation)

    def read_range(
        self, topic: str, partition: int, offset: int, length: int
    ) -> RecordBatch:
        # the window is counted in raw offsets: a filtered batch's
        # `scanned` — not its delivered record count — says how much of
        # it was actually readable (control markers occupy offsets but
        # are never delivered; see StreamLog.read_range)
        def covered(b: RecordBatch) -> int:
            return b.scanned if b.scanned is not None else len(b)

        batch = self.read(topic, partition, offset, length)
        if covered(batch) < length:
            # the shortfall may just be a daemon-stale HW (read() skips the
            # inline pass when some records are visible): force one pass
            # and retry before declaring the range unreadable
            ctl = self._ctl(topic, partition)
            try:
                with ctl.lock:
                    self._replicate_partition(ctl)
            except PartitionOffline:
                pass  # follower reads may still serve below the HW
            batch = self.read(topic, partition, offset, length)
        if covered(batch) < length:
            ctl = self._ctl(topic, partition)
            with ctl.lock:
                hw = ctl.hw
            raise OffsetOutOfRange(
                f"{topic}:{partition} range [{offset}, {offset + length}) extends "
                f"past high watermark {hw}"
            )
        return batch

    def iter_range(
        self,
        topic: str,
        partition: int,
        offset: int,
        length: int,
        chunk: int = 4096,
    ) -> Iterator[RecordBatch]:
        done = 0
        while done < length:
            take = min(chunk, length - done)
            yield self.read_range(topic, partition, offset + done, take)
            done += take

    def start_offset(self, topic: str, partition: int) -> int:
        ctl = self._ctl(topic, partition)
        with ctl.lock:
            leader_id = ctl.leader
            if leader_id is None or not self.brokers[leader_id].up:
                if self.follower_reads:
                    follower = self._serving_follower(ctl)
                    if follower is not None:
                        return follower.log.start_offset(topic, partition)
                leader_id = self._leader_broker(ctl).broker_id
            return self.brokers[leader_id].log.start_offset(topic, partition)

    def end_offset(self, topic: str, partition: int) -> int:
        """Consumer-visible end: the high watermark (not the leader LEO)."""
        ctl = self._ctl(topic, partition)
        with ctl.lock:
            leader_id = ctl.leader
            if (
                self.follower_reads
                and (leader_id is None or not self.brokers[leader_id].up)
                and self._serving_follower(ctl) is not None
            ):
                # leader down but in-sync followers serve: report the HW
                # as-is rather than forcing an election from the read path
                return ctl.hw
            self._leader_broker(ctl)  # refresh leadership if stale
            if not self._daemon_active:
                self._replicate_partition(ctl)
            return ctl.hw

    def log_end_offset(self, topic: str, partition: int) -> int:
        """Leader log end offset (includes not-yet-replicated records)."""
        ctl = self._ctl(topic, partition)
        with ctl.lock:
            leader = self._leader_broker(ctl)
            return leader.log.end_offset(topic, partition)

    def last_stable_offset(self, topic: str, partition: int) -> int:
        """Consumer-visible read_committed bound: min(HW, leader LSO)."""
        ctl = self._ctl(topic, partition)
        with ctl.lock:
            leader = self._leader_broker(ctl)
            return min(ctl.hw, leader.log.last_stable_offset(topic, partition))

    def size_bytes(self, topic: str, partition: int | None = None) -> int:
        if partition is not None:
            ctl = self._ctl(topic, partition)
            with ctl.lock:
                return self._leader_broker(ctl).log.size_bytes(topic, partition)
        return sum(
            self.size_bytes(topic, p)
            for p in range(self.num_partitions(topic))
        )

    # -------------------------------------------------- consumer offset store
    # Kafka's `__consumer_offsets`, replicated at cluster width: commits
    # fan out to every live broker (and are re-mirrored on rejoin), and
    # reads are served from a live broker's replica — so committed offsets
    # survive any broker loss. The controller dict is the recovery fallback
    # for the no-live-broker window.
    def commit_offset(self, group: str, tp: TopicPartition, offset: int) -> None:
        with self._meta_lock:
            self._committed.setdefault(group, {})[tp] = offset
            for br in self.brokers.values():
                if br.up:
                    br.log.commit_offset(group, tp, offset)

    def committed_offset(self, group: str, tp: TopicPartition) -> int | None:
        with self._meta_lock:
            for bid in sorted(self.brokers):
                if self.brokers[bid].up:
                    return self.brokers[bid].log.committed_offset(group, tp)
            return self._committed.get(group, {}).get(tp)


# ------------------------------------------------------------------ clients
class _MetadataCache:
    """Client-side partition→leader cache shared by producer and consumer.

    ``leader`` serves from cache (refreshing a whole topic on miss);
    ``note_leader_hint`` applies a NotLeaderError's hint; ``invalidate``
    drops an entry so the next lookup refreshes. ``metadata_refreshes``
    counts round-trips, the client-observable cost of failover.
    """

    def __init__(self, cluster: BrokerCluster):
        self.cluster = cluster
        self._leaders: dict[tuple[str, int], int | None] = {}
        self.metadata_refreshes = 0

    def leader(self, topic: str, partition: int) -> int:
        key = (topic, partition)
        if key not in self._leaders:
            self.metadata_refreshes += 1
            for p, meta in self.cluster.metadata(topic).items():
                self._leaders[(topic, p)] = meta.leader
        leader = self._leaders.get(key)
        if leader is None:
            raise PartitionOffline(f"{topic}:{partition} has no leader")
        return leader

    def note_leader_hint(self, topic: str, partition: int, hint: int | None) -> None:
        self._leaders[(topic, partition)] = hint

    def invalidate(self, topic: str, partition: int) -> None:
        self._leaders.pop((topic, partition), None)


class ClusterProducer:
    """Failover-aware producer: metadata cache + leader routing + retry.

    The client-side half of the Kafka produce protocol: it routes every
    batch to the cached leader broker, and when the cluster answers
    :class:`NotLeaderError` (stale cache after an election) or
    :class:`BrokerUnavailable` (cached leader died), it refreshes metadata
    and retries — so a broker loss mid-stream costs one round-trip, not the
    stream.

    ``idempotent=True`` upgrades that retry loop from at-least-once to
    **exactly-once**: the producer asks the cluster for a committed
    ``(pid, epoch)`` identity (:meth:`BrokerCluster.init_producer`) and
    stamps every batch with per-partition sequence numbers, so a retry of
    a batch whose ack was lost — or that landed on a deposed leader whose
    direct push already committed it — resolves to the *original* offsets
    instead of re-appending. ``producer_name`` additionally pins a stable
    identity: re-initializing the same name bumps the epoch and fences the
    previous incarnation (its in-flight appends raise
    :class:`~repro.core.log.ProducerFenced`, which is fatal and never
    retried here). Each producer instance is single-threaded, like the
    rest of the client surface.
    """

    def __init__(
        self,
        cluster: BrokerCluster,
        *,
        acks: int | str = "all",
        retries: int = 5,
        idempotent: bool = False,
        producer_name: str | None = None,
        transactional_id: str | None = None,
    ):
        self.cluster = cluster
        self.acks = acks
        self.retries = retries
        # a transactional producer IS an idempotent producer whose stable
        # name is the transactional id (Kafka's transactional.id): the
        # committed epoch bump on re-initialization is what fences a
        # zombie's in-flight transaction
        self.transactional_id = transactional_id
        if transactional_id is not None:
            producer_name = transactional_id
        self.idempotent = idempotent or producer_name is not None
        if self.idempotent and acks not in ("all", -1):
            # as in Kafka: idempotence requires acks=all. At acks=0/1 an
            # acked suffix may be truncated by reconciliation, after which
            # the producer's next sequence looks like a gap and dies with
            # OutOfOrderSequence — turning permitted acks<all loss into a
            # fatal client error. Refuse the combination up front.
            raise ValueError(
                f"idempotent producers require acks='all' (got {acks!r})"
            )
        self.producer_id: int | None = None
        self.producer_epoch: int | None = None
        if self.idempotent:
            self.producer_id, self.producer_epoch = cluster.init_producer(
                producer_name
            )
        self._seqs: dict[tuple[str, int], int] = {}  # next seq per partition
        # an idempotent send that failed is *unresolved*: some attempt may
        # have appended the batch under its sequence even though no ack
        # arrived. Re-using that sequence for a DIFFERENT batch could
        # silently dedup the new data against the old batch's offsets
        # (data loss), so the partition is pinned to a same-batch
        # continuation: tp -> (seq, payload digest). Re-sending the
        # identical batch resumes the retry exactly-once; anything else
        # raises ProducerFenced (recovery: a new producer, fresh pid).
        self._unresolved: dict[tuple[str, int], tuple[int, bytes]] = {}
        self._meta = _MetadataCache(cluster)
        self._sticky: dict[str, int] = {}
        self._in_txn = False
        self._txn_parts: set[tuple[str, int]] = set()

    # ------------------------------------------------------------ transactions
    def begin_txn(self) -> None:
        """Open a transaction: every ``send``/``send_batch`` until
        ``commit_txn``/``abort_txn`` becomes atomic with the others (and
        with any offsets attached via :meth:`send_offsets_to_txn`)."""
        if self.transactional_id is None:
            raise InvalidTxnState(
                "transactions require ClusterProducer(transactional_id=...)"
            )
        if self._in_txn:
            raise InvalidTxnState("transaction already in progress")
        self.cluster.begin_txn(self.producer_id, self.producer_epoch)
        self._in_txn = True
        self._txn_parts = set()

    def send_offsets_to_txn(
        self, group_id: str, offsets: dict[TopicPartition, int]
    ) -> None:
        """Attach consumer offsets to the open transaction — they commit
        to the replicated offset store atomically with the produced
        records (the read-process-write exactly-once primitive)."""
        if not self._in_txn:
            raise InvalidTxnState("no transaction in progress")
        self.cluster.txn_add_offsets(
            self.producer_id, self.producer_epoch, group_id, offsets
        )

    @property
    def in_txn(self) -> bool:
        return self._in_txn

    def commit_txn(self) -> None:
        """Commit the open transaction. Raises ``ClusterError`` when the
        cluster cannot complete the two-phase commit right now — the
        transaction is then either still ongoing (prepare never committed;
        retry or abort) or durably prepared (the cluster finishes it on a
        controller tick; a retry here also re-drives it, idempotently)."""
        if not self._in_txn:
            raise InvalidTxnState("no transaction in progress")
        try:
            self.cluster.commit_txn(self.producer_id, self.producer_epoch)
        except (InvalidTxnState, ProducerFenced):
            # the transaction is beyond this operation (opposite outcome
            # decided, or a newer incarnation fenced us): locally over
            self._in_txn = False
            raise
        self._in_txn = False

    def abort_txn(self) -> None:
        """Abort the open transaction: its records become permanently
        invisible to read_committed consumers, its offsets never apply.
        Raises :class:`InvalidTxnState` when a COMMIT is already durably
        decided — the transaction will complete as committed regardless;
        the local transaction is considered over either way."""
        if not self._in_txn:
            raise InvalidTxnState("no transaction in progress")
        try:
            self.cluster.abort_txn(self.producer_id, self.producer_epoch)
        except (InvalidTxnState, ProducerFenced):
            self._in_txn = False
            raise
        self._in_txn = False

    @property
    def metadata_refreshes(self) -> int:
        return self._meta.metadata_refreshes

    def _pick_partition(self, topic: str, key: bytes | None) -> int:
        n = self.cluster.num_partitions(topic)
        if key is not None:
            return default_partition([key], n, 0)  # same key→partition map
        # sticky partitioner: stay on one partition per topic per producer
        return self._sticky.setdefault(topic, hash(id(self)) % n)

    def send(
        self,
        topic: str,
        value: bytes,
        *,
        key: bytes | None = None,
        partition: int | None = None,
    ) -> tuple[int, int]:
        p, first, _ = self.send_batch(topic, [value], keys=[key], partition=partition)
        return p, first

    def send_batch(
        self,
        topic: str,
        values: Sequence[bytes],
        *,
        keys: Sequence[bytes | None] | None = None,
        partition: int | None = None,
    ) -> tuple[int, int, int]:
        if partition is None:
            k = keys[0] if keys else None
            partition = self._pick_partition(topic, k)
        producer = None
        if self.idempotent:
            tp = (topic, partition)
            pending = self._unresolved.get(tp)
            if pending is not None:
                if self._fingerprint(values, keys) != pending[1]:
                    raise ProducerFenced(
                        f"producer {self.producer_id} has an unresolved "
                        f"send on {topic}:{partition} (ack never arrived; "
                        "the batch may be committed under its sequence): "
                        "only an identical re-send may continue — create "
                        "a new producer to move on"
                    )
                seq = pending[0]  # continuation of the unresolved retry
            else:
                seq = self._seqs.get(tp, 0)
            # the same (pid, epoch, seq) stamp rides every retry of this
            # batch, so a re-send of an already-committed append dedups on
            # the broker and returns the original offsets; the sequence
            # only advances once the batch is acknowledged
            producer = (self.producer_id, self.producer_epoch, seq)
        if self._in_txn and (topic, partition) not in self._txn_parts:
            # the partition joins the transaction's registered set (a
            # committed AddPartitionsToTxn) BEFORE its first append, so
            # the coordinator knows where markers must go even if this
            # producer dies one line down
            self.cluster.txn_add_partitions(
                self.producer_id, self.producer_epoch, [(topic, partition)]
            )
            self._txn_parts.add((topic, partition))
        last_err: ClusterError | None = None
        try:
            for _ in range(self.retries + 1):
                try:
                    leader = self._meta.leader(topic, partition)
                    first, last = self.cluster.broker_append(
                        leader, topic, partition, values, keys=keys,
                        acks=self.acks, producer=producer,
                        transactional=self._in_txn,
                    )
                    if producer is not None:
                        self._unresolved.pop((topic, partition), None)
                        self._seqs[(topic, partition)] = (
                            producer[2] + len(values)
                        )
                    return partition, first, last
                except NotLeaderError as e:
                    self._meta.note_leader_hint(topic, partition, e.leader_hint)
                    last_err = e
                except (BrokerUnavailable, PartitionOffline) as e:
                    self._meta.invalidate(topic, partition)
                    last_err = e
            raise last_err  # exhausted retries
        except BaseException:
            if producer is not None:
                # ANY non-success exit — exhausted retries, or an error
                # outside the retried set (NotEnoughReplicasError, a
                # quorum window, ...) escaping after an earlier attempt
                # may already have appended — leaves the outcome unknown:
                # pin this partition's sequence to an identical re-send
                # of this batch
                self._unresolved[(topic, partition)] = (
                    producer[2], self._fingerprint(values, keys)
                )
            raise

    @staticmethod
    def _fingerprint(
        values: Sequence[bytes], keys: Sequence[bytes | None] | None
    ) -> bytes:
        """Identity of a batch's contents, used only around unresolved
        sends (never on the happy path): a continuation re-send must carry
        the same payload or the pinned sequence would ack wrong data. A
        real digest, not Python's ``hash()`` — a collision here acks new
        data at old offsets, the exact loss this mechanism prevents."""
        h = hashlib.sha256()
        for v in values:
            h.update(len(v).to_bytes(4, "big"))
            h.update(v)
        h.update(b"\xffK")
        # keys=None and keys=[None]*n append identically, so they must
        # fingerprint identically too (a continuation must not be wedged
        # by spelling the same batch the other way)
        if keys is not None and any(k is not None for k in keys):
            for k in keys:
                if k is None:
                    h.update(b"\xff\xff\xff\xff")
                else:
                    kb = bytes(k)
                    h.update(len(kb).to_bytes(4, "big"))
                    h.update(kb)
        return h.digest()


class ClusterConsumer:
    """Failover-aware fetcher: routes reads to the partition leader and
    retries through elections; offsets commit to the replicated store.

    ``follower_reads=True`` adds the Kafka 2.4 "fetch from follower" mode:
    when the leader is unreachable (or the partition is leaderless
    mid-election), the fetch falls back to an in-sync follower, capped at
    the high watermark — bounded staleness, never divergence.

    ``isolation_level="read_committed"`` caps every fetch at the last
    stable offset and filters out control markers and aborted
    transactions' records: the consumer observes a transaction's records
    only after its COMMIT marker, and never observes an aborted one.
    """

    def __init__(self, cluster: BrokerCluster, *, group_id: str | None = None,
                 retries: int = 5, follower_reads: bool = False,
                 isolation_level: str | None = None):
        self.cluster = cluster
        self.group_id = group_id
        self.retries = retries
        self.follower_reads = follower_reads
        self.isolation_level = isolation_level
        self.follower_fetches = 0
        self._meta = _MetadataCache(cluster)

    @property
    def metadata_refreshes(self) -> int:
        return self._meta.metadata_refreshes

    def fetch(
        self, topic: str, partition: int, offset: int, max_records: int = 1024
    ) -> RecordBatch:
        last_err: ClusterError | None = None
        for _ in range(self.retries + 1):
            try:
                leader = self._meta.leader(topic, partition)
                return self.cluster.broker_fetch(
                    leader, topic, partition, offset, max_records,
                    isolation=self.isolation_level,
                )
            except NotLeaderError as e:
                self._meta.note_leader_hint(topic, partition, e.leader_hint)
                last_err = e
            except (BrokerUnavailable, PartitionOffline) as e:
                self._meta.invalidate(topic, partition)
                last_err = e
                if self.follower_reads:
                    batch = self._follower_fetch(
                        topic, partition, offset, max_records
                    )
                    if batch is not None:
                        return batch
        raise last_err

    def _follower_fetch(
        self, topic: str, partition: int, offset: int, max_records: int
    ) -> RecordBatch | None:
        """Try each in-sync replica in turn; None if none can serve."""
        try:
            # single-partition metadata: touches only this partition's lock
            meta = self.cluster.partition_meta(topic, partition)
        except (KeyError, IndexError):
            return None
        for b in sorted(meta.isr):
            if b == meta.leader:
                continue
            try:
                batch = self.cluster.broker_fetch(
                    b, topic, partition, offset, max_records,
                    allow_follower=True, isolation=self.isolation_level,
                )
            except ClusterError:
                continue
            self.follower_fetches += 1
            return batch
        return None

    def position_bounds(self, topic: str, partition: int) -> tuple[int, int]:
        """(log start, high watermark) for the partition."""
        return (
            self.cluster.start_offset(topic, partition),
            self.cluster.end_offset(topic, partition),
        )

    def commit(self, tp: TopicPartition, offset: int) -> None:
        if self.group_id is None:
            raise ValueError("consumer has no group_id")
        self.cluster.commit_offset(self.group_id, tp, offset)

    def committed(self, tp: TopicPartition) -> int | None:
        if self.group_id is None:
            raise ValueError("consumer has no group_id")
        return self.cluster.committed_offset(self.group_id, tp)

    def lag(self, topic: str, partition: int, *,
            offset: int | None = None) -> int:
        """LSO-aware consumer lag for one partition.

        Lag is measured against min(HW, LSO) for ``read_committed``
        consumers — records behind an open transaction are not
        consumable, so they must not count as lag — and against the
        high watermark otherwise. ``offset`` overrides the consumer
        position; by default the group's committed offset is used
        (0 when nothing has been committed). Never negative.
        """
        if offset is None:
            if self.group_id is not None:
                offset = self.cluster.committed_offset(
                    self.group_id, TopicPartition(topic, partition)
                ) or 0
            else:
                offset = 0
        if self.isolation_level == "read_committed":
            bound = self.cluster.last_stable_offset(topic, partition)
        else:
            bound = self.cluster.end_offset(topic, partition)
        lag = max(0, bound - offset)
        m = self.cluster.metrics
        if m.enabled and self.group_id is not None:
            m.gauge(
                "consumer_lag", group=self.group_id,
                topic=topic, partition=str(partition),
            ).set(lag)
        return lag
