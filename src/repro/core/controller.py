"""Quorum controller — a Raft-style replicated control plane.

PR-1/PR-2 hung the whole cluster's fault-tolerance story off a single
in-process controller that could not itself fail: broker liveness,
partition leadership, ISR sets and leader epochs were mutated directly
under the metadata lock. This module replaces that with the KRaft-shaped
design the paper's availability claims actually need:

* **Replicated metadata log.** Controller state changes are *commands*
  (:class:`MetadataCommand`: ``RegisterBroker``, ``ElectLeader``,
  ``ShrinkIsr``, ``ExpandIsr``, ``CreateTopic``, ``DeleteTopic``,
  ``AllocatePid``, and the transaction-coordinator commands
  ``BeginTxn``/``AddPartitionsToTxn``/``AddOffsetsToTxn``/
  ``PrepareCommit``/``PrepareAbort``/``CompleteTxn``)
  appended to a log replicated across N controller nodes. Each node's
  log **is** a :class:`~repro.core.log.StreamLog` topic
  (``__cluster_metadata``) — the same segment substrate the data plane
  uses, reusing its append/point-read/``truncate_to`` machinery for
  Raft's log reconciliation.
* **Term-based elections.** A candidate bumps the term and requests
  votes; a voter grants only if the candidate's log is at least as
  up-to-date as its own (Raft's §5.4.1 election restriction, which is
  what guarantees committed commands survive controller failover). A
  candidate that cannot see a majority doesn't bump terms at all
  (pre-vote), so a partitioned minority node can neither elect itself
  nor disrupt the quorum's term sequence.
* **Majority commit.** A command is *committed* once it is on a majority
  of nodes; only committed commands are ever applied to cluster state.
  A new leader appends a no-op barrier entry in its own term — when that
  commits, every inherited entry commits with it (Raft's
  no-direct-commit-of-prior-term-entries rule). A command submitted to a
  leader that dies mid-commit is therefore either durably applied by the
  new leader (it reached a majority-electable node) or cleanly truncated
  (it lived only on the dead leader) — never half-applied.
* **Leader lease.** The leader holds a wall-clock lease renewed on every
  majority round (commit or heartbeat). A *partitioned* leader blocks
  elections until its lease expires (no dual-leader window); a *dead*
  leader is replaced immediately. A deposed leader's late writes are
  fenced twice over: it cannot reach a majority, and any node that
  observed a higher term rejects its entries outright.
* **Snapshots + install (DESIGN.md §11).** A long-lived quorum folds its
  applied committed prefix into a snapshot (``ControllerNode.snap_*``):
  superseded per-partition commands collapse to the newest one, barriers
  drop, and the node's metadata log physically restarts at the snapshot
  index (offsets stay Raft indexes). A follower missing the folded
  prefix — or conflicting below it — receives InstallSnapshot before
  normal AppendEntries resumes, so restarted controllers recover from
  snapshot + suffix replay instead of full history.

The controller is a pure consensus module: it never touches partition or
cluster-metadata locks. :class:`~repro.core.cluster.BrokerCluster`
submits commands (possibly while holding a partition lock — the lock
hierarchy is ``metadata lock → partition lock → controller lock``) and
applies each committed command itself; committed-but-unapplied backlog
(controller failover with the submitter gone) is drained by
``BrokerCluster.controller_tick``, which the replication daemon drives.
"""

from __future__ import annotations

import json
import threading
import time
from dataclasses import asdict, dataclass
from typing import Callable, Iterator

from repro.analysis.witness import make_rlock
from repro.core.log import METADATA_TOPIC, LogConfig, StreamLog

__all__ = [
    "ClusterError",
    "ControllerNode",
    "ControllerUnavailable",
    "LogEntry",
    "MetadataCommand",
    "QuorumController",
]


class ClusterError(RuntimeError):
    """Base class for cluster-level failures. Defined here (the module
    both :mod:`repro.core.cluster` and this one can import) and
    re-exported by ``cluster``, so client retry loops written against
    ``except ClusterError`` also cover controller-quorum conditions."""


class ControllerUnavailable(ClusterError):
    """No controller leader can commit: quorum lost, lease held by an
    unreachable leader, or the submitting node was fenced/deposed. The
    submitted command is NOT committed (it may sit uncommitted on a
    minority of nodes, where log reconciliation will truncate it)."""


@dataclass(frozen=True)
class MetadataCommand:
    """One replicated control-plane command (the metadata-log record).

    ``kind`` selects the state transition; the remaining fields are its
    payload (unused ones stay None). ``pversion`` is the per-partition
    metadata version the command produces — application is guarded by
    ``pversion > ctl.version``, which makes replay after controller
    failover idempotent and makes lost (uncommitted) commands harmless:
    their version number is simply reissued by the next command.
    """

    kind: str  # register_broker | elect_leader | shrink_isr | expand_isr
    #          | create_topic | delete_topic | allocate_pid | noop
    #          | begin_txn | add_partitions_to_txn | add_offsets_to_txn
    #          | prepare_commit | prepare_abort | complete_txn
    topic: str | None = None
    partition: int | None = None
    broker_id: int | None = None
    up: bool | None = None
    leader: int | None = None
    epoch: int | None = None
    isr: tuple[int, ...] | None = None
    pversion: int | None = None
    cfg: dict | None = None  # create_topic: LogConfig fields
    gen: int | None = None  # topic generation (fences delete-vs-recreate)
    note: str | None = None  # free-form tag (tests mark entries with it)
    # allocate_pid: producer-id grants are metadata commands, so ids stay
    # unique across controller failovers (the grant is in the replicated
    # log a successor inherits) and a named re-initialization's epoch bump
    # (zombie fencing) is durable
    pid: int | None = None
    producer_epoch: int | None = None
    name: str | None = None
    # transaction-coordinator commands (DESIGN.md §8): the coordinator's
    # whole state machine — ongoing partition set, consumer offsets to
    # commit with the transaction, and the prepare/complete decisions —
    # lives in these replicated commands, so a controller successor
    # reconstructs every in-flight transaction from the metadata log
    partitions: tuple[tuple[str, int], ...] | None = None
    group: str | None = None  # add_offsets_to_txn: consumer group id
    offsets: dict | None = None  # "topic:partition" -> offset
    committed: bool | None = None  # complete_txn outcome
    # per-pid txn command sequence: application is guarded by
    # ``txn_seq > state.seq`` (the transactional pversion), making
    # failover replay idempotent
    txn_seq: int | None = None

    def to_bytes(self, term: int) -> bytes:
        body = {k: v for k, v in asdict(self).items() if v is not None}
        if self.isr is not None:
            body["isr"] = list(self.isr)
        if self.partitions is not None:
            body["partitions"] = [list(p) for p in self.partitions]
        return json.dumps({"term": term, "cmd": body}, sort_keys=True).encode()

    @staticmethod
    def from_bytes(payload: bytes) -> tuple[int, "MetadataCommand"]:
        obj = json.loads(bytes(payload).decode())
        body = obj["cmd"]
        if "isr" in body:
            body["isr"] = tuple(body["isr"])
        if "partitions" in body:
            body["partitions"] = tuple(
                (t, int(p)) for t, p in body["partitions"]
            )
        return obj["term"], MetadataCommand(**body)


def _is_barrier(cmd: MetadataCommand) -> bool:
    """A new leader's untagged no-op barrier entry (pure consensus
    bookkeeping — never surfaced to the state machine or log readers)."""
    return cmd.kind == "noop" and cmd.note is None


# Auto-snapshot thresholds: fold once the applied committed prefix
# exceeds _SNAPSHOT_ENTRIES live entries, keeping the newest
# _SNAPSHOT_RETAIN committed entries un-folded (recent history stays
# individually addressable for reconciliation and debugging).
_SNAPSHOT_ENTRIES = 1024
_SNAPSHOT_RETAIN = 256


def _fold_commands(cmds: list[MetadataCommand]) -> list[MetadataCommand]:
    """Collapse a committed command prefix to its net effect, order
    preserved. Deliberately conservative: only commands whose application
    is last-writer-wins are folded — ``register_broker`` (latest per
    broker) and the ``pversion``-guarded partition commands
    ``elect_leader``/``shrink_isr``/``expand_isr`` (latest per kind and
    partition, so a trailing ISR change never erases the leader/epoch the
    preceding election carries). Everything else — topic lifecycle,
    ``allocate_pid`` (an epoch-bump grant carries no ``name``; folding
    would lose the name→pid binding), the transaction-coordinator
    commands, tagged no-ops — replays verbatim. Barriers drop."""
    last: dict[tuple, int] = {}
    for i, c in enumerate(cmds):
        if c.kind == "register_broker":
            last[("register_broker", c.broker_id)] = i
        elif c.kind in ("elect_leader", "shrink_isr", "expand_isr"):
            last[(c.kind, c.topic, c.partition)] = i
    out = []
    for i, c in enumerate(cmds):
        if _is_barrier(c):
            continue
        if c.kind == "register_broker":
            if last[("register_broker", c.broker_id)] != i:
                continue
        elif c.kind in ("elect_leader", "shrink_isr", "expand_isr"):
            if last[(c.kind, c.topic, c.partition)] != i:
                continue
        out.append(c)
    return out


@dataclass(frozen=True)
class LogEntry:
    """One committed metadata-log entry as handed to the state machine."""

    term: int
    index: int
    command: MetadataCommand


class ControllerNode:
    """One controller node: durable term/vote plus its metadata log.

    The log is a real :class:`StreamLog` topic — offsets are Raft log
    indexes, ``truncate_to`` is Raft's conflict-suffix truncation, and a
    killed node that restarts keeps its durable state (log, term, vote),
    exactly the persistence Raft assumes.

    Snapshot state (DESIGN.md §11): entries below ``snap_index`` have
    been folded into ``snap_commands`` (their net effect, order
    preserved); ``snap_term`` is the term of the boundary entry
    ``snap_index - 1``, which is all AppendEntries consistency checks
    need about the folded prefix (Raft's Log Matching Property: a
    matching boundary entry implies the whole prefix matched).
    ``_terms`` covers only the live suffix ``[snap_index, end())``.

    ``alive`` models a crashed controller process; ``reachable`` models a
    network partition. Either way the node is invisible to its peers.
    """

    __slots__ = ("node_id", "term", "voted_for", "won_term", "log", "_terms",
                 "commit_count", "alive", "reachable",
                 "snap_index", "snap_term", "snap_commands")

    def __init__(self, node_id: int, clock: Callable[[], float] | None = None):
        self.node_id = node_id
        self.term = 0
        self.voted_for: int | None = None
        # highest term this node won an election for: a node may only act
        # as leader (append + replicate outward) in a term it won — a
        # restarted follower sharing the leader's term must never push its
        # divergent same-term log at peers (it could truncate committed
        # entries, since conflict detection is by term)
        self.won_term = -1
        # appended to while the controller lock is held → distinct lock
        # class nested strictly inside "controller" (repro.analysis.ranks)
        self.log = StreamLog(clock=clock, lock_class="ctl-log")
        self.log.create_topic(METADATA_TOPIC, LogConfig(num_partitions=1))
        self._terms: list[int] = []  # term of live entry i - snap_index
        self.commit_count = 0  # entries [0, commit_count) are committed
        self.snap_index = 0  # entries below this are folded into the snapshot
        self.snap_term = 0  # term of entry snap_index - 1
        self.snap_commands: list[MetadataCommand] = []
        self.alive = True
        self.reachable = True

    @property
    def up(self) -> bool:
        return self.alive and self.reachable

    def end(self) -> int:
        return self.snap_index + len(self._terms)

    def last_term(self) -> int:
        return self._terms[-1] if self._terms else self.snap_term

    def term_at(self, index: int) -> int:
        """Term of entry ``index`` — the boundary entry just below the
        snapshot answers from ``snap_term``; anything deeper is folded
        away (and never needed: folded entries are committed, and
        committed prefixes agree by Leader Completeness)."""
        if index == self.snap_index - 1:
            return self.snap_term
        return self._terms[index - self.snap_index]

    def append(self, term: int, cmd: MetadataCommand) -> int:
        """Append one entry; returns its index (== StreamLog offset)."""
        _p, offset = self.log.produce(METADATA_TOPIC, cmd.to_bytes(term))
        assert offset == self.end()
        self._terms.append(term)
        return offset

    def entry(self, index: int) -> LogEntry:
        if index < self.snap_index:
            raise LookupError(
                f"entry {index} folded into snapshot @ {self.snap_index}"
            )
        rec = self.log.read_one(METADATA_TOPIC, 0, index)
        term, cmd = MetadataCommand.from_bytes(rec.value)
        return LogEntry(term=term, index=index, command=cmd)

    def entries(
        self, start: int | None = None, stop: int | None = None
    ) -> Iterator[LogEntry]:
        """Live (non-folded) entries in ``[start, stop)``; ``start``
        defaults to the snapshot boundary."""
        start = self.snap_index if start is None else start
        stop = self.end() if stop is None else stop
        for i in range(max(start, self.snap_index), stop):
            yield self.entry(i)

    def truncate(self, index: int) -> None:
        """Drop entries at ``index`` and beyond (conflict reconciliation).
        Never reaches into the snapshot: folded entries are committed,
        and Raft never truncates committed entries."""
        assert index >= self.snap_index
        self.log.truncate_to(METADATA_TOPIC, 0, index)
        del self._terms[index - self.snap_index:]
        self.commit_count = min(self.commit_count, index)

    def install_snapshot(
        self, index: int, term: int, commands: list[MetadataCommand]
    ) -> None:
        """Replace this node's log wholesale with a leader's snapshot
        (Raft InstallSnapshot): the local log restarts empty at ``index``
        — StreamLog offsets stay Raft indexes — and AppendEntries copies
        the live suffix afterwards."""
        self.snap_index = index
        self.snap_term = term
        self.snap_commands = list(commands)
        self.log.reset_to(METADATA_TOPIC, 0, index)
        self._terms = []
        self.commit_count = index  # a snapshot only ever covers committed

    def compact_to_snapshot(
        self, upto: int, folded: list[MetadataCommand]
    ) -> None:
        """Fold this node's own prefix ``[snap_index, upto)`` into the
        snapshot and physically drop it from the log: the live suffix is
        re-appended into a log restarted at ``upto``, so offsets still
        equal Raft indexes. Caller provides the folded commands and
        guarantees the prefix is committed and applied."""
        boundary = self.term_at(upto - 1)
        windows = []  # fetch is capped per segment: gather the suffix
        pos, end = upto, self.end()
        while pos < end:
            vals, keys, ts, prods, offs, nxt, _ = self.log.replica_fetch(
                METADATA_TOPIC, 0, pos, end - pos
            )
            if nxt <= pos:
                break
            if vals:
                windows.append((vals, keys, ts, prods, offs))
            pos = nxt
        self.log.reset_to(METADATA_TOPIC, 0, upto)
        for vals, keys, ts, prods, offs in windows:
            self.log.replica_append(
                METADATA_TOPIC, 0, vals, keys, ts, prods=prods, offsets=offs
            )
        self._terms = self._terms[upto - self.snap_index:]
        self.snap_commands = folded
        self.snap_term = boundary
        self.snap_index = upto


class QuorumController:
    """N-node Raft-style quorum over the cluster metadata log.

    All public methods are safe to call from data-plane threads: the
    single internal lock is a leaf in the cluster lock hierarchy
    (``metadata lock → partition lock → controller lock``) — no method
    ever calls back into cluster or partition state.

    This is an in-process model of the consensus protocol, not a wire
    implementation: RPCs are direct method calls gated by a visibility
    rule (two nodes exchange messages iff both are alive and both are
    reachable), which is exactly the level the chaos suite needs to
    prove split-brain safety and failover liveness.
    """

    def __init__(
        self,
        num_nodes: int = 3,
        *,
        lease_s: float = 1.0,
        clock: Callable[[], float] | None = None,
    ):
        if num_nodes < 1:
            raise ValueError("need at least one controller node")
        self._clock = clock or time.time
        self.lease_s = lease_s
        self.nodes: dict[int, ControllerNode] = {
            i: ControllerNode(i, clock=self._clock) for i in range(num_nodes)
        }
        self._majority = num_nodes // 2 + 1
        self.leader_id: int | None = None
        self._lease_until = 0.0
        self.elections = 0  # completed leadership changes (observability)
        self.term_changes = 0  # election rounds that bumped the term
        self.quorum_rpcs = 0  # AppendEntries-shaped node-to-node calls
        self.snapshots_taken = 0  # leader-side log folds
        self.snapshot_installs = 0  # InstallSnapshot pushes to followers
        # last-observed leader for read-only metadata queries: unlike
        # ``leader_id`` (reset to None on fencing/deposal) this sticks
        # around, so reads keep routing to one node instead of probing
        # all N — falling back to a full probe only when the observed
        # leader can no longer serve. The counters prove the reduction.
        self._observed_leader: int | None = None
        self.observed_reads = 0  # reads served by the observed leader alone
        self.probe_reads = 0  # reads that fell back to probing every node
        self._applied: set[int] = set()  # entry indexes handed to the SM
        self._lock = make_rlock("controller")
        # test hook: crash the leader mid-commit ("append": before any
        # replication; "replicate": after reaching exactly one follower)
        self.crash_leader_after: str | None = None

    # ------------------------------------------------------------- topology
    @staticmethod
    def _visible(a: ControllerNode, b: ControllerNode) -> bool:
        if a is b:
            return a.alive
        return a.alive and b.alive and a.reachable and b.reachable

    def leader(self) -> int | None:
        with self._lock:
            return self.leader_id

    def term(self) -> int:
        """Current controller term — an observed-leader read: served from
        the last-observed leader's state alone when that node is still the
        serving leader (one node touched), probing every node only when it
        is not. A serving leader's term is the quorum's term (any higher
        term would have fenced it), so the routed answer is never stale."""
        with self._lock:
            obs = self._observed_node_locked()
            if obs is not None:
                self.observed_reads += 1
                return obs.term
            self.probe_reads += 1
            return max(n.term for n in self.nodes.values())

    def _observed_node_locked(self) -> ControllerNode | None:
        """The last-observed leader, iff it can still serve reads (up and
        the elected leader for its own term)."""
        if self._observed_leader is None:
            return None
        obs = self.nodes.get(self._observed_leader)
        if obs is not None and obs.up and obs.won_term == obs.term:
            return obs
        return None

    def apply_lag(self) -> int:
        """Committed-but-unapplied metadata entries (state-machine backlog
        a ``controller_tick`` still has to drain)."""
        with self._lock:
            ldr = (
                self.nodes.get(self.leader_id)
                if self.leader_id is not None
                else None
            )
            if ldr is None or not ldr.up:
                return 0
            return sum(
                1
                for i in range(ldr.snap_index, ldr.commit_count)
                if i not in self._applied
            )

    def describe(self) -> dict:
        with self._lock:
            return {
                "leader": self.leader_id,
                "elections": self.elections,
                "term_changes": self.term_changes,
                "quorum_rpcs": self.quorum_rpcs,
                "observed_reads": self.observed_reads,
                "probe_reads": self.probe_reads,
                "snapshots_taken": self.snapshots_taken,
                "snapshot_installs": self.snapshot_installs,
                "lease_until": self._lease_until,
                "nodes": {
                    n.node_id: {
                        "term": n.term,
                        "end": n.end(),
                        "commit": n.commit_count,
                        "snap_index": n.snap_index,
                        "alive": n.alive,
                        "reachable": n.reachable,
                    }
                    for n in self.nodes.values()
                },
            }

    # ---------------------------------------------------------- chaos hooks
    def kill_node(self, node_id: int) -> None:
        """Crash a controller node (durable state survives for restart)."""
        with self._lock:
            self.nodes[node_id].alive = False

    def restart_node(self, node_id: int) -> None:
        with self._lock:
            self.nodes[node_id].alive = True

    def partition_node(self, node_id: int) -> None:
        """Isolate a node from every peer (it may still act locally)."""
        with self._lock:
            self.nodes[node_id].reachable = False

    def heal_node(self, node_id: int) -> None:
        with self._lock:
            self.nodes[node_id].reachable = True

    # ------------------------------------------------------------ elections
    def _try_elect_locked(self, candidate_id: int | None = None) -> bool:
        """One election round. Without an explicit candidate, up nodes run
        in most-up-to-date-log-first order (then lowest id — deterministic),
        so the first eligible candidate wins whenever a majority is up."""
        if candidate_id is not None:
            cands = [self.nodes[candidate_id]]
        else:
            cands = sorted(
                (n for n in self.nodes.values() if n.up),
                key=lambda n: (-n.last_term(), -n.end(), n.node_id),
            )
        for cand in cands:
            if not cand.alive:
                continue
            visible = [n for n in self.nodes.values() if self._visible(cand, n)]
            if len(visible) < self._majority:
                continue  # pre-vote: cannot win, don't disturb terms
            term = max(n.term for n in visible) + 1
            self.term_changes += 1
            votes = 0
            for n in visible:
                # grant iff the candidate's log is at least as up-to-date
                # (Raft §5.4.1) — the voter's term advances either way
                grant = (cand.last_term(), cand.end()) >= (n.last_term(), n.end())
                n.term = term
                n.voted_for = cand.node_id if grant else None
                votes += 1 if grant else 0
            if votes < self._majority:
                continue
            self.leader_id = cand.node_id
            self._observed_leader = cand.node_id
            cand.won_term = term
            self.elections += 1
            self._lease_until = self._clock() + self.lease_s
            # no-op barrier in the new term: when it commits, every entry
            # inherited from prior terms commits with it
            cand.append(term, MetadataCommand(kind="noop"))
            self._heartbeat_locked(cand)
            return True
        if candidate_id is None:
            self.leader_id = None
        return False

    def try_elect(self, candidate_id: int) -> bool:
        """Run an election round with an explicit candidate (chaos tests:
        a partitioned minority node must fail here)."""
        with self._lock:
            return self._try_elect_locked(candidate_id)

    def _ensure_leader_locked(self) -> ControllerNode:
        ldr = self.nodes.get(self.leader_id) if self.leader_id is not None else None
        if ldr is not None and ldr.up and ldr.won_term == ldr.term:
            return ldr
        if (
            ldr is not None
            and ldr.alive
            and not ldr.reachable
            and self._clock() < self._lease_until
        ):
            # a partitioned (not crashed) leader may still be serving its
            # own minority view: its lease must expire before a new leader
            # can be chosen (no dual-leader window)
            raise ControllerUnavailable(
                f"controller {ldr.node_id} unreachable; lease not expired"
            )
        self._try_elect_locked()
        if self.leader_id is None:
            raise ControllerUnavailable("no controller quorum")
        return self.nodes[self.leader_id]

    def ensure_leader(self) -> int:
        """Elect (if needed) and return the current leader node id."""
        with self._lock:
            return self._ensure_leader_locked().node_id

    # ---------------------------------------------------------- replication
    def _replicate_to_locked(self, ldr: ControllerNode, f: ControllerNode) -> bool:
        """Bring follower ``f`` up to ``ldr``'s log (AppendEntries):
        truncate the conflicting suffix, copy missing entries, propagate
        the commit index. Returns False when unreachable or fenced."""
        self.quorum_rpcs += 1
        if not self._visible(ldr, f):
            return False
        if f.term > ldr.term:
            return False  # higher term: the caller must step down
        f.term = ldr.term
        if ldr.snap_index > f.snap_index and (
            f.end() < ldr.snap_index
            or f.term_at(ldr.snap_index - 1) != ldr.snap_term
        ):
            # the follower is missing — or conflicts inside — the prefix
            # the leader folded away: InstallSnapshot, then AppendEntries
            # resumes for the live suffix
            self.snapshot_installs += 1
            f.install_snapshot(
                ldr.snap_index, ldr.snap_term, ldr.snap_commands
            )
        # longest common prefix by entry term (logs are small — the
        # in-memory term index makes this a list comparison). Entries
        # below both snapshot boundaries are committed on both sides and
        # agree by Leader Completeness; the comparison starts above them.
        lo = max(ldr.snap_index, f.snap_index)
        n = min(f.end(), ldr.end())
        common = n
        for i in range(lo, n):
            if f.term_at(i) != ldr.term_at(i):
                common = i
                break
        if f.end() > common:
            f.truncate(common)
        if common < ldr.end():
            pos, end = common, ldr.end()
            while pos < end:  # fetch is capped per segment: loop
                values, keys, timestamps, prods, offs, nxt, sbase = (
                    ldr.log.replica_fetch(METADATA_TOPIC, 0, pos, end - pos)
                )
                if nxt <= pos:
                    break
                if values:
                    f.log.replica_append(
                        METADATA_TOPIC, 0, values, keys, timestamps,
                        prods=prods, offsets=offs, seg_base=sbase,
                    )
                pos = nxt
            f._terms.extend(ldr._terms[common - ldr.snap_index:])
        # never below the snapshot boundary (a snapshot covers committed
        # entries only — a new leader whose commit index lags behind an
        # old quorum's snapshot catches up at its first barrier commit)
        f.commit_count = max(f.snap_index, min(ldr.commit_count, f.end()))
        return True

    def _heartbeat_locked(self, ldr: ControllerNode) -> bool:
        """One majority round from ``ldr``: replicate the log, advance the
        commit index, renew the lease. Returns True on majority ack."""
        acks = 1
        for n in self.nodes.values():
            if n is ldr:
                continue
            if self._visible(ldr, n) and n.term > ldr.term:
                # fenced: a higher-term leader exists somewhere
                ldr.term = n.term
                if self.leader_id == ldr.node_id:
                    self.leader_id = None
                return False
            if self._replicate_to_locked(ldr, n):
                acks += 1
        if acks < self._majority:
            return False
        if ldr._terms and ldr._terms[-1] == ldr.term:
            # every entry is on a majority, and the tail is own-term: the
            # whole log commits (the no-op barrier guarantees this holds
            # from the first round of any new term)
            ldr.commit_count = ldr.end()
        if self.leader_id == ldr.node_id:
            self._lease_until = max(
                self._lease_until, self._clock() + self.lease_s
            )
        return True

    def tick(self) -> bool:
        """One controller heartbeat: renew the lease, catch followers up,
        and run an election when the leader is dead (immediately) or
        unreachable (after lease expiry). Returns True when leadership
        changed — the cluster then completes pending partition elections.
        Driven by :class:`~repro.core.cluster.ReplicationService`."""
        with self._lock:
            ldr = (
                self.nodes.get(self.leader_id)
                if self.leader_id is not None
                else None
            )
            if ldr is not None and ldr.up and ldr.won_term == ldr.term:
                self._heartbeat_locked(ldr)
                if self.leader_id == ldr.node_id:
                    # steady-state housekeeping: fold a long applied
                    # prefix so restarts replay a snapshot + short
                    # suffix, not the full history
                    if ldr.commit_count - ldr.snap_index > _SNAPSHOT_ENTRIES:
                        self._snapshot_locked(ldr, _SNAPSHOT_RETAIN)
                    return False
                # fenced mid-heartbeat: fall through to re-elect
            elif (
                ldr is not None
                and ldr.alive
                and not ldr.reachable
                and self._clock() < self._lease_until
            ):
                return False  # partitioned leader still holds its lease
            old = self.leader_id
            self._try_elect_locked()
            return self.leader_id is not None and self.leader_id != old

    # --------------------------------------------------------------- submit
    def submit(self, cmd: MetadataCommand) -> LogEntry:
        """Append ``cmd`` to the current leader's log and replicate it to
        a majority. Returns the committed entry; the caller applies it to
        cluster state. Raises :class:`ControllerUnavailable` when no
        leader can be established or the command cannot reach a majority
        — in that case the command is NOT committed and must not be
        applied."""
        with self._lock:
            ldr = self._ensure_leader_locked()
            return self._submit_from_locked(ldr, cmd)

    def submit_from(self, node_id: int, cmd: MetadataCommand) -> LogEntry:
        """Submit acting as a specific node (chaos tests: a deposed leader
        attempting a late write must be fenced)."""
        with self._lock:
            node = self.nodes[node_id]
            if not node.alive:
                raise ControllerUnavailable(f"controller {node_id} is dead")
            return self._submit_from_locked(node, cmd)

    def _submit_from_locked(
        self, ldr: ControllerNode, cmd: MetadataCommand
    ) -> LogEntry:
        if ldr.won_term != ldr.term:
            # not the elected leader for its current term (e.g. a restarted
            # follower that missed same-term commits): letting it replicate
            # outward could truncate committed entries on its peers
            raise ControllerUnavailable(
                f"controller {ldr.node_id} is not the leader for term "
                f"{ldr.term}"
            )
        term = ldr.term
        index = ldr.append(term, cmd)
        if self.crash_leader_after == "append":
            # die before any replication: the entry lives only on this
            # node and will be truncated by the next leader's heartbeat
            self.crash_leader_after = None
            ldr.alive = False
            raise ControllerUnavailable(
                f"controller {ldr.node_id} crashed before replicating"
            )
        acks = 1
        for n in self.nodes.values():
            if n is ldr:
                continue
            if self._visible(ldr, n) and n.term > ldr.term:
                # fenced: step down, refuse the write
                ldr.term = n.term
                if self.leader_id == ldr.node_id:
                    self.leader_id = None
                raise ControllerUnavailable(
                    f"controller {ldr.node_id} deposed (term {n.term} observed)"
                )
            if self._replicate_to_locked(ldr, n):
                acks += 1
                if self.crash_leader_after == "replicate":
                    # die after reaching one follower but before commit:
                    # the entry is on a majority-electable node, so the
                    # next leader inherits and commits it
                    self.crash_leader_after = None
                    ldr.alive = False
                    raise ControllerUnavailable(
                        f"controller {ldr.node_id} crashed mid-commit"
                    )
        if acks < self._majority:
            raise ControllerUnavailable(
                f"metadata command reached {acks}/{len(self.nodes)} nodes; "
                f"majority is {self._majority}"
            )
        ldr.commit_count = ldr.end()
        if self.leader_id == ldr.node_id:
            self._lease_until = max(
                self._lease_until, self._clock() + self.lease_s
            )
        self._applied.add(index)  # the submitting caller applies it now
        return LogEntry(term=term, index=index, command=cmd)

    # -------------------------------------------------------- state machine
    def take_unapplied(self) -> list[LogEntry]:
        """Committed entries not yet handed to the state machine, in log
        order (controller-failover backlog: committed by a dead leader,
        or inherited and committed via the no-op barrier). Entries are
        marked as handed out; application itself is idempotent
        (``pversion`` guards), so a duplicate hand-out would be harmless."""
        with self._lock:
            ldr = (
                self.nodes.get(self.leader_id)
                if self.leader_id is not None
                else None
            )
            if ldr is None or not ldr.up:
                return []
            out = []
            # folded entries (below snap_index) are applied by the
            # snapshot-creation precondition — only the live tail can
            # hold backlog
            for i in range(ldr.snap_index, ldr.commit_count):
                if i in self._applied:
                    continue
                entry = ldr.entry(i)
                self._applied.add(i)
                if not _is_barrier(entry.command):
                    out.append(entry)
            return out

    def committed_commands(self) -> list[MetadataCommand]:
        """The committed metadata log (minus no-ops), from the leader:
        the snapshot's folded commands followed by the live committed
        suffix — the replay a fresh state machine consumes."""
        with self._lock:
            ldr = (
                self.nodes.get(self.leader_id)
                if self.leader_id is not None
                else None
            )
            if ldr is None:
                return []
            out = [c for c in ldr.snap_commands if not _is_barrier(c)]
            out.extend(
                e.command
                for e in ldr.entries(ldr.snap_index, ldr.commit_count)
                if not _is_barrier(e.command)
            )
            return out

    # ------------------------------------------------------------- snapshots
    def snapshot(self, retain: int = _SNAPSHOT_RETAIN) -> bool:
        """Fold the leader's applied committed prefix into a snapshot,
        keeping the newest ``retain`` committed entries live. Returns
        True when a fold happened. Followers receive the snapshot via
        InstallSnapshot on their next AppendEntries only if they diverge
        below the boundary; an up-to-date follower just keeps its own
        (longer) log until it snapshots too."""
        with self._lock:
            ldr = (
                self.nodes.get(self.leader_id)
                if self.leader_id is not None
                else None
            )
            if ldr is None or not ldr.up or ldr.won_term != ldr.term:
                return False
            return self._snapshot_locked(ldr, retain)

    def _snapshot_locked(self, ldr: ControllerNode, retain: int) -> bool:
        limit = ldr.commit_count - retain
        # fold only entries the state machine has consumed: a snapshot
        # claims its prefix is applied, so stop at the first un-applied
        upto = ldr.snap_index
        while upto < limit and upto in self._applied:
            upto += 1
        if upto <= ldr.snap_index:
            return False
        folded = _fold_commands(
            ldr.snap_commands
            + [e.command for e in ldr.entries(ldr.snap_index, upto)]
        )
        ldr.compact_to_snapshot(upto, folded)
        self.snapshots_taken += 1
        return True
