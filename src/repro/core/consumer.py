"""Consumer groups — load balancing + fault tolerance (paper §II, §IV-D).

Kafka-ML leans on the Kafka consumer-group feature twice:

* inference *replicas* join one group so partitions (and therefore request
  load) are spread across them, and a dead replica's partitions are
  reassigned to the survivors;
* committed offsets give at-least-once delivery: a restarted member resumes
  from its group's committed offset rather than re-reading the stream.

This module implements the group coordinator: deterministic *range*
assignment (Kafka's default), generation-numbered rebalances on
join/leave/failure, heartbeat-based failure detection, and offset commit
backed by the log's offset store.

Groups run against any :class:`~repro.core.log.StreamBackend` — a bare
:class:`StreamLog` or a replicated :class:`~repro.core.cluster.BrokerCluster`.
On a cluster, reads route to partition leaders through elections and
committed offsets live in the cluster-replicated offset store, so a group
resumes from its committed offsets on the new leader after a broker loss.
A partition that is momentarily unavailable (leader election in flight,
no in-sync follower to serve) is skipped for that poll rather than
failing the member — the next poll retries it.

The coordinator is thread-safe; each :class:`GroupConsumer` is owned by
one member thread (positions are member-local), so N members may poll the
same group concurrently — the serving engine's parallel replica polling
relies on exactly that.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Sequence

from repro.core.cluster import ClusterError
from repro.core.log import (
    OffsetOutOfRange,
    RecordBatch,
    StreamBackend,
    TopicPartition,
)

__all__ = ["ConsumerGroup", "GroupConsumer", "range_assign"]


def range_assign(
    members: Sequence[str], partitions: Sequence[TopicPartition]
) -> dict[str, list[TopicPartition]]:
    """Kafka's range assignor: sort both sides, give each member a
    contiguous slice; the first ``len(partitions) % len(members)`` members
    get one extra partition.

    Invariants (property-tested): every partition assigned exactly once;
    member loads differ by at most one; deterministic in its inputs.
    """
    out: dict[str, list[TopicPartition]] = {m: [] for m in members}
    if not members:
        return out
    ms = sorted(members)
    ps = sorted(partitions, key=lambda tp: (tp.topic, tp.partition))
    base, extra = divmod(len(ps), len(ms))
    start = 0
    for i, m in enumerate(ms):
        take = base + (1 if i < extra else 0)
        out[m] = ps[start : start + take]
        start += take
    return out


@dataclass
class _Member:
    member_id: str
    last_heartbeat: float


class ConsumerGroup:
    """Group coordinator for one consumer group over a :class:`StreamBackend`."""

    def __init__(
        self,
        log: StreamBackend,
        group_id: str,
        topics: Sequence[str],
        *,
        session_timeout_s: float = 10.0,
        clock: Callable[[], float] | None = None,
    ):
        self.log = log
        self.group_id = group_id
        self.topics = list(topics)
        self.session_timeout_s = session_timeout_s
        self._clock = clock or time.monotonic
        self._members: dict[str, _Member] = {}
        self._assignment: dict[str, list[TopicPartition]] = {}
        self.generation = 0
        self._lock = threading.RLock()

    # ------------------------------------------------------------ membership
    def _partitions(self) -> list[TopicPartition]:
        tps: list[TopicPartition] = []
        for t in self.topics:
            tps.extend(TopicPartition(t, p) for p in range(self.log.num_partitions(t)))
        return tps

    def join(self, member_id: str) -> "GroupConsumer":
        with self._lock:
            self._members[member_id] = _Member(member_id, self._clock())
            self._rebalance()
            return GroupConsumer(self, member_id)

    def leave(self, member_id: str) -> None:
        with self._lock:
            if self._members.pop(member_id, None) is not None:
                self._rebalance()

    def heartbeat(self, member_id: str) -> None:
        with self._lock:
            m = self._members.get(member_id)
            if m is None:
                raise KeyError(f"{member_id} not in group {self.group_id}")
            m.last_heartbeat = self._clock()

    def expire_dead_members(self) -> list[str]:
        """Failure detection: drop members whose heartbeat lapsed, rebalance.

        Returns the expired member ids. This is the fault-tolerance path the
        paper gets from Kafka: a crashed inference replica's partitions move
        to live replicas within a session timeout.
        """
        with self._lock:
            now = self._clock()
            dead = [
                m.member_id
                for m in self._members.values()
                if now - m.last_heartbeat > self.session_timeout_s
            ]
            for mid in dead:
                self._members.pop(mid)
            if dead:
                self._rebalance()
            return dead

    def _rebalance(self) -> None:
        self.generation += 1
        self._assignment = range_assign(list(self._members), self._partitions())

    def assignment(self, member_id: str) -> list[TopicPartition]:
        with self._lock:
            return list(self._assignment.get(member_id, []))

    @property
    def members(self) -> list[str]:
        with self._lock:
            return sorted(self._members)

    # ---------------------------------------------------------------- offsets
    def committed(self, tp: TopicPartition) -> int:
        off = self.log.committed_offset(self.group_id, tp)
        return off if off is not None else self.log.start_offset(tp.topic, tp.partition)

    def commit(self, tp: TopicPartition, offset: int) -> None:
        self.log.commit_offset(self.group_id, tp, offset)


class GroupConsumer:
    """One member's view: poll assigned partitions from committed offsets.

    ``poll`` returns record batches and advances *local* positions;
    ``commit`` publishes them (at-least-once: a crash between poll and
    commit re-delivers).
    """

    def __init__(self, group: ConsumerGroup, member_id: str):
        self.group = group
        self.member_id = member_id
        self._positions: dict[TopicPartition, int] = {}
        self._generation_seen = -1

    def _sync_assignment(self) -> list[TopicPartition]:
        assignment = self.group.assignment(self.member_id)
        if self.group.generation != self._generation_seen:
            # after a rebalance, restart from the group's committed offsets
            self._positions = {tp: self.group.committed(tp) for tp in assignment}
            self._generation_seen = self.group.generation
        return assignment

    def poll(self, max_records: int = 1024) -> list[RecordBatch]:
        self.group.heartbeat(self.member_id)
        batches: list[RecordBatch] = []
        for tp in self._sync_assignment():
            pos = self._positions[tp]
            try:
                batch = self.group.log.read(tp.topic, tp.partition, pos, max_records)
            except OffsetOutOfRange:
                try:
                    # evicted under us — jump to log start (auto.offset.reset)
                    pos = self.group.log.start_offset(tp.topic, tp.partition)
                    batch = self.group.log.read(
                        tp.topic, tp.partition, pos, max_records
                    )
                except ClusterError:
                    continue  # leader lost mid-recovery: retry next poll
            except ClusterError:
                # partition unavailable mid-election (offline, no serving
                # follower): skip it this round, keep the member alive
                continue
            if len(batch):
                self._positions[tp] = batch.next_offset
                batches.append(batch)
        return batches

    def commit(self) -> None:
        for tp, pos in self._positions.items():
            self.group.commit(tp, pos)

    def close(self) -> None:
        self.commit()
        self.group.leave(self.member_id)
