"""Consumer groups — load balancing + fault tolerance (paper §II, §IV-D).

Kafka-ML leans on the Kafka consumer-group feature twice:

* inference *replicas* join one group so partitions (and therefore request
  load) are spread across them, and a dead replica's partitions are
  reassigned to the survivors;
* committed offsets give at-least-once delivery: a restarted member resumes
  from its group's committed offset rather than re-reading the stream.

This module implements the group coordinator: deterministic *range*
assignment (Kafka's default), generation-numbered rebalances on
join/leave/failure, heartbeat-based failure detection, and offset commit
backed by the log's offset store.

Groups run against any :class:`~repro.core.log.StreamBackend` — a bare
:class:`StreamLog` or a replicated :class:`~repro.core.cluster.BrokerCluster`.
On a cluster, reads route to partition leaders through elections and
committed offsets live in the cluster-replicated offset store, so a group
resumes from its committed offsets on the new leader after a broker loss.
A partition that is momentarily unavailable (leader election in flight,
no in-sync follower to serve) is skipped for that poll rather than
failing the member — the next poll retries it; the same applies to
resolving its committed offset after a rebalance. Offset commits are
fenced on the generation the positions were polled under, so a zombie
member (evicted, or holding positions from before a rebalance) can never
rewind the committed offset under a partition's new owner; eviction
surfaces as a typed :class:`RebalanceError` with
:meth:`GroupConsumer.rejoin` as the recovery path.

The coordinator is thread-safe; each :class:`GroupConsumer` is owned by
one member thread (positions are member-local), so N members may poll the
same group concurrently — the serving engine's parallel replica polling
relies on exactly that.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Sequence

from repro.analysis.witness import make_rlock
from repro.core.cluster import ClusterError
from repro.core.log import (
    OffsetOutOfRange,
    RecordBatch,
    StreamBackend,
    TopicPartition,
)

__all__ = ["ConsumerGroup", "GroupConsumer", "RebalanceError", "range_assign"]


class RebalanceError(RuntimeError):
    """The member was evicted from its group (missed heartbeats — another
    member now owns its partitions) or tried to act under a stale
    generation. Deliberately NOT a ``ClusterError``: cluster retry loops
    must not blindly re-poll as a zombie. Recover with
    :meth:`GroupConsumer.rejoin` and poll again — positions restart from
    the group's committed offsets (at-least-once)."""


def range_assign(
    members: Sequence[str], partitions: Sequence[TopicPartition]
) -> dict[str, list[TopicPartition]]:
    """Kafka's range assignor: sort both sides, give each member a
    contiguous slice; the first ``len(partitions) % len(members)`` members
    get one extra partition.

    Invariants (property-tested): every partition assigned exactly once;
    member loads differ by at most one; deterministic in its inputs.
    """
    out: dict[str, list[TopicPartition]] = {m: [] for m in members}
    if not members:
        return out
    ms = sorted(members)
    ps = sorted(partitions, key=lambda tp: (tp.topic, tp.partition))
    base, extra = divmod(len(ps), len(ms))
    start = 0
    for i, m in enumerate(ms):
        take = base + (1 if i < extra else 0)
        out[m] = ps[start : start + take]
        start += take
    return out


@dataclass
class _Member:
    member_id: str
    last_heartbeat: float


class ConsumerGroup:
    """Group coordinator for one consumer group over a :class:`StreamBackend`."""

    def __init__(
        self,
        log: StreamBackend,
        group_id: str,
        topics: Sequence[str],
        *,
        session_timeout_s: float = 10.0,
        clock: Callable[[], float] | None = None,
    ):
        self.log = log
        self.group_id = group_id
        self.topics = list(topics)
        self.session_timeout_s = session_timeout_s
        self._clock = clock or time.monotonic
        self._members: dict[str, _Member] = {}
        self._assignment: dict[str, list[TopicPartition]] = {}
        self.generation = 0
        self.rebalances = 0
        self._lock = make_rlock("group", name=f"group:{group_id}")

    # ------------------------------------------------------------ membership
    def _partitions(self) -> list[TopicPartition]:
        tps: list[TopicPartition] = []
        for t in self.topics:
            tps.extend(TopicPartition(t, p) for p in range(self.log.num_partitions(t)))
        return tps

    def join(
        self,
        member_id: str,
        *,
        on_revoked: Callable[[list[TopicPartition]], None] | None = None,
        on_assigned: Callable[[list[TopicPartition]], None] | None = None,
        isolation_level: str | None = None,
    ) -> "GroupConsumer":
        """Add a member; returns its :class:`GroupConsumer` view.

        ``on_revoked`` / ``on_assigned`` are rebalance listener hooks
        (Kafka's ConsumerRebalanceListener): when the member observes a
        generation change at its next poll, ``on_revoked`` fires with the
        partitions it lost *before* positions reset, ``on_assigned`` with
        the new assignment after.

        ``isolation_level="read_committed"`` makes the member's polls
        stop at each partition's last stable offset and skip control
        markers and aborted transactions' records — it never observes a
        transaction that has not committed.
        """
        with self._lock:
            self._members[member_id] = _Member(member_id, self._clock())
            self._rebalance()
            return GroupConsumer(
                self, member_id,
                on_revoked=on_revoked, on_assigned=on_assigned,
                isolation_level=isolation_level,
            )

    def rejoin(self, member_id: str) -> None:
        """Re-register an evicted member (recovery after
        :class:`RebalanceError`); triggers a rebalance like any join."""
        with self._lock:
            self._members[member_id] = _Member(member_id, self._clock())
            self._rebalance()

    def leave(self, member_id: str) -> None:
        with self._lock:
            if self._members.pop(member_id, None) is not None:
                self._rebalance()

    def heartbeat(self, member_id: str) -> None:
        with self._lock:
            m = self._members.get(member_id)
            if m is None:
                # typed, recoverable: the poll loop can rejoin() instead
                # of dying on a raw KeyError (the member was expired by
                # failure detection between two polls)
                raise RebalanceError(
                    f"{member_id} evicted from group {self.group_id}"
                )
            m.last_heartbeat = self._clock()

    def expire_dead_members(self) -> list[str]:
        """Failure detection: drop members whose heartbeat lapsed, rebalance.

        Returns the expired member ids. This is the fault-tolerance path the
        paper gets from Kafka: a crashed inference replica's partitions move
        to live replicas within a session timeout.
        """
        with self._lock:
            now = self._clock()
            dead = [
                m.member_id
                for m in self._members.values()
                if now - m.last_heartbeat > self.session_timeout_s
            ]
            for mid in dead:
                self._members.pop(mid)
            if dead:
                self._rebalance()
            return dead

    def _rebalance(self) -> None:
        self.generation += 1
        self.rebalances += 1
        self._assignment = range_assign(list(self._members), self._partitions())
        # backends without a registry (bare StreamLog default) skip this
        m = getattr(self.log, "metrics", None)
        if m is not None and m.enabled:
            m.counter("consumer_rebalances_total", group=self.group_id).inc()

    def assignment(self, member_id: str) -> list[TopicPartition]:
        with self._lock:
            return list(self._assignment.get(member_id, []))

    def assignment_with_generation(
        self, member_id: str
    ) -> tuple[int, list[TopicPartition]]:
        """Assignment plus the generation it belongs to, read atomically —
        the pair a member needs to fence its commits on."""
        with self._lock:
            return self.generation, list(self._assignment.get(member_id, []))

    @property
    def members(self) -> list[str]:
        with self._lock:
            return sorted(self._members)

    # ---------------------------------------------------------------- offsets
    def committed(self, tp: TopicPartition) -> int:
        off = self.log.committed_offset(self.group_id, tp)
        return off if off is not None else self.log.start_offset(tp.topic, tp.partition)

    def commit(self, tp: TopicPartition, offset: int) -> None:
        self.log.commit_offset(self.group_id, tp, offset)

    def commit_member(
        self,
        member_id: str,
        generation: int,
        positions: dict[TopicPartition, int],
    ) -> bool:
        """Generation-fenced offset commit (Kafka's OffsetCommit with
        ``generation_id`` validation). Publishes ``positions`` only when
        they were polled under the **current** generation by a member
        that is still in the group *and* still owns each partition —
        otherwise nothing commits and False returns. This is what stops a
        zombie (a member that kept stale positions across a rebalance)
        from rewinding the committed offset under the partition's new
        owner. Atomic with the membership/assignment check: the group
        lock is held across validation and the commits, so a rebalance
        cannot interleave between them."""
        with self._lock:
            if generation != self.generation or member_id not in self._members:
                return False
            assigned = set(self._assignment.get(member_id, ()))
            for tp, off in positions.items():
                if tp in assigned:
                    self.log.commit_offset(self.group_id, tp, off)
            return True


class GroupConsumer:
    """One member's view: poll assigned partitions from committed offsets.

    ``poll`` returns record batches and advances *local* positions;
    ``commit`` publishes them (at-least-once: a crash between poll and
    commit re-delivers). Commits are **generation-fenced**: positions
    only publish under the generation they were polled in, for partitions
    this member still owns — a zombie's stale commit is dropped (returns
    False) instead of rewinding the new owner's offset. An evicted member
    sees a typed :class:`RebalanceError` from ``poll`` and can
    :meth:`rejoin` instead of dying.
    """

    def __init__(
        self,
        group: ConsumerGroup,
        member_id: str,
        *,
        on_revoked: Callable[[list[TopicPartition]], None] | None = None,
        on_assigned: Callable[[list[TopicPartition]], None] | None = None,
        isolation_level: str | None = None,
    ):
        self.group = group
        self.member_id = member_id
        self.isolation_level = isolation_level
        self._positions: dict[TopicPartition, int] = {}
        self._assigned: list[TopicPartition] = []  # last observed assignment
        self._generation_seen = -1
        self._paused = False
        self._on_revoked = on_revoked
        self._on_assigned = on_assigned

    def _sync_assignment(self) -> list[TopicPartition]:
        # generation and assignment are read atomically: racing on the
        # two separately could pair a new assignment with a stale
        # generation and mis-fence the next commit
        gen, assignment = self.group.assignment_with_generation(self.member_id)
        if gen != self._generation_seen:
            if self._on_revoked is not None and self._generation_seen >= 0:
                # diff against the previously *observed assignment*, not
                # _positions: a partition whose committed offset never
                # resolved (mid-election skips) was still owned and must
                # still be reported revoked, or listeners doing
                # per-partition cleanup leak it
                revoked = sorted(
                    set(self._assigned) - set(assignment),
                    key=lambda tp: (tp.topic, tp.partition),
                )
                if revoked:
                    self._on_revoked(revoked)
            # after a rebalance, restart from the group's committed offsets
            self._positions = {}
            self._assigned = list(assignment)
            self._generation_seen = gen
            if self._on_assigned is not None:
                self._on_assigned(list(assignment))
        for tp in assignment:
            if tp not in self._positions:
                try:
                    self._positions[tp] = self.group.committed(tp)
                except ClusterError:
                    # committed offset / log start unreadable mid-election
                    # (leaderless partition, no controller quorum): skip
                    # this partition for the round and retry next poll,
                    # exactly like the read path below — one unavailable
                    # partition must not kill the member's poll loop
                    continue
        return assignment

    def pause(self) -> None:
        """Stop fetching without leaving the group: a paused member's
        ``poll`` still heartbeats and tracks assignment (so it is not
        expired or rebalanced away) but delivers no records and holds its
        positions — Kafka's ``pause()`` backpressure, used by serving
        workers whose request queue is at its high-water mark."""
        self._paused = True

    def resume(self) -> None:
        self._paused = False

    @property
    def paused(self) -> bool:
        return self._paused

    def poll(self, max_records: int = 1024) -> list[RecordBatch]:
        self.group.heartbeat(self.member_id)  # raises RebalanceError if evicted
        batches: list[RecordBatch] = []
        if self._paused:
            self._sync_assignment()  # keep generation/positions fresh
            return batches
        for tp in self._sync_assignment():
            pos = self._positions.get(tp)
            if pos is None:
                continue  # position still unresolved (mid-election skip)
            try:
                batch = self.group.log.read(
                    tp.topic, tp.partition, pos, max_records,
                    isolation=self.isolation_level,
                )
            except OffsetOutOfRange:
                try:
                    # evicted under us — jump to log start (auto.offset.reset)
                    pos = self.group.log.start_offset(tp.topic, tp.partition)
                    batch = self.group.log.read(
                        tp.topic, tp.partition, pos, max_records,
                        isolation=self.isolation_level,
                    )
                    # persist the recovered position even when the read
                    # comes back empty, or every later poll re-raises and
                    # re-recovers (and commit republishes the evicted
                    # offset) until new records arrive
                    self._positions[tp] = pos
                except ClusterError:
                    continue  # leader lost mid-recovery: retry next poll
            except ClusterError:
                # partition unavailable mid-election (offline, no serving
                # follower): skip it this round, keep the member alive
                continue
            if len(batch):
                self._positions[tp] = batch.next_offset
                batches.append(batch)
            elif (batch.scanned or 0) > 0:
                # a read_committed poll that scanned only control markers
                # (or aborted records) delivers nothing but must still
                # advance, or every later poll re-reads the same span
                self._positions[tp] = batch.next_offset
        return batches

    def commit(self) -> bool:
        """Publish polled positions, fenced on the generation they were
        polled under. Returns False — committing nothing — when a
        rebalance has moved on (stale generation or eviction): the new
        owner's committed offsets must not be rewound by a zombie."""
        return self.group.commit_member(
            self.member_id, self._generation_seen, dict(self._positions)
        )

    def lag(self) -> dict[TopicPartition, int]:
        """Per-partition LSO-aware lag for this member's assignment.

        Lag = bound - committed offset, clamped at 0, where the bound is
        min(HW, LSO) for a ``read_committed`` member (records parked
        behind an open transaction are not consumable, so they are not
        lag) and the high watermark otherwise. Uses the *group's
        committed* offsets, not local polled positions: lag is an
        external progress measure, and uncommitted positions would be
        lost on a crash anyway.
        """
        log = self.group.log
        out: dict[TopicPartition, int] = {}
        for tp in self.group.assignment(self.member_id):
            try:
                committed = self.group.committed(tp)
                if (self.isolation_level == "read_committed"
                        and hasattr(log, "last_stable_offset")):
                    bound = log.last_stable_offset(tp.topic, tp.partition)
                else:
                    bound = log.end_offset(tp.topic, tp.partition)
            except ClusterError:
                continue  # partition unavailable mid-election: omit
            out[tp] = max(0, bound - committed)
        m = getattr(log, "metrics", None)
        if m is not None and m.enabled:
            for tp, lag in out.items():
                m.gauge(
                    "consumer_lag", group=self.group.group_id,
                    topic=tp.topic, partition=str(tp.partition),
                ).set(lag)
        return out

    def positions(self) -> dict[TopicPartition, int]:
        """Snapshot of the member's polled positions — what a
        transactional publisher hands to ``send_offsets_to_txn`` so the
        offsets commit atomically with its produced records."""
        return dict(self._positions)

    @property
    def generation(self) -> int:
        """The group generation the current positions were polled under —
        what a transactional publisher checks against the group before
        committing offsets through a transaction (best-effort zombie
        fencing; the generation-atomic path is :meth:`commit`)."""
        return self._generation_seen

    def reset_positions(self) -> None:
        """Forget local positions; the next poll re-resolves them from
        the group's committed offsets (the recovery path after an aborted
        transaction: re-deliver everything the abort un-published)."""
        self._positions = {}

    def rejoin(self) -> None:
        """Recover from :class:`RebalanceError`: re-enter the group and
        restart from committed offsets at the next poll (at-least-once —
        records polled but not committed before eviction re-deliver)."""
        if self._on_revoked is not None and self._assigned:
            # eviction lost every owned partition (Kafka's
            # onPartitionsLost): report them so per-partition listener
            # cleanup runs before the fresh assignment arrives
            self._on_revoked(sorted(
                self._assigned, key=lambda tp: (tp.topic, tp.partition)
            ))
        self.group.rejoin(self.member_id)
        self._positions = {}
        self._assigned = []
        self._generation_seen = -1

    def close(self) -> None:
        self.commit()
        self.group.leave(self.member_id)
