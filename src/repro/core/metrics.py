"""Metrics: a zero-dependency observability plane for the cluster (§9).

The registry is deliberately tiny: counters, gauges, bounded-bucket
latency histograms and lightweight trace spans, all safe to touch from
the produce/fetch hot path.  Cost model:

* ``Counter.inc`` / ``Histogram.record`` — one short lock acquire plus
  integer arithmetic (~1µs under CPython).  Hot paths additionally guard
  timing blocks with ``registry.enabled`` so a disabled registry costs a
  single attribute load.
* ``Gauge`` values that are expensive to compute (producer-state table
  size, metadata apply lag, consumer lag) are registered as *callbacks*
  via :meth:`MetricsRegistry.gauge_fn` and evaluated only at snapshot /
  render time — they never touch the hot path.
* Histograms use fixed geometric buckets (1µs … ~67s, factor 2), so a
  record is an index computation plus one list increment; p50/p99 are
  estimated from bucket upper bounds at snapshot time.

Series are identified Prometheus-style: ``name{label="value",...}``.
``MetricsRegistry.snapshot()`` returns a JSON-safe dict (the payload the
``MetricsReporter`` daemon publishes to the replicated ``__metrics``
topic) and ``render_text()`` emits a Prometheus-compatible text dump for
humans and CI artifacts.
"""

from __future__ import annotations

import json
import math
import time
from collections import deque
from typing import Any, Callable, Iterable

from repro.analysis.witness import make_lock

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "METRICS_TOPIC",
    "default_registry",
    "series_key",
]

# Internal replicated topic the MetricsReporter publishes snapshots to.
METRICS_TOPIC = "__metrics"

# Geometric histogram bucket upper bounds: 1µs .. ~67s, factor 2, then +inf.
_BUCKETS: tuple[float, ...] = tuple(1e-6 * (2.0**i) for i in range(27)) + (
    math.inf,
)


def series_key(name: str, labels: dict[str, Any] | None = None) -> str:
    """Canonical series id: ``name`` or ``name{k="v",...}`` (sorted keys)."""
    if not labels:
        return name
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic counter."""

    __slots__ = ("key", "_lock", "_value")

    def __init__(self, key: str):
        self.key = key
        self._lock = make_lock("metrics", name=f"metrics:{key}")
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("key", "_lock", "_value")

    def __init__(self, key: str):
        self.key = key
        self._lock = make_lock("metrics", name=f"metrics:{key}")
        self._value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Bounded-bucket histogram (geometric buckets, seconds-oriented).

    Tracks count/sum/min/max exactly; percentiles are estimated as the
    upper bound of the bucket containing the requested rank, which for
    factor-2 buckets bounds the error at 2x — plenty for p50/p99 latency
    dashboards, and it keeps ``record`` O(1) with O(28) fixed memory.

    ``sample`` (a power of two) turns on hot-path sampling: after a
    64-observation warm-up every ``sample``-th value is recorded and the
    rest return after one unlocked integer update. Produce/append
    latency distributions are stationary over thousands of batches, so a
    1-in-8 sample leaves p50/p99 statistically unchanged while cutting
    the per-batch cost to a fraction of the ≤5% overhead budget
    (DESIGN.md §9); counters stay exact, so throughput accounting never
    samples.
    """

    __slots__ = ("key", "_lock", "_counts", "_count", "_sum", "_min",
                 "_max", "_tick", "_sample_mask")

    def __init__(self, key: str, sample: int = 1):
        self.key = key
        self._lock = make_lock("metrics", name=f"metrics:{key}")
        self._counts = [0] * len(_BUCKETS)
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._tick = 0
        self._sample_mask = sample - 1  # sample is a power of two

    def record(self, value: float) -> None:
        if self._sample_mask:
            # unlocked tick: sampling is a rate heuristic, a lost update
            # under the GIL only nudges the effective rate
            t = self._tick = self._tick + 1
            if (t & self._sample_mask) and self._count >= 64:
                return
        # index of first bucket whose upper bound >= value
        if value <= 1e-6:
            idx = 0
        else:
            idx = min(
                int(math.log2(value / 1e-6)) + 1, len(_BUCKETS) - 1
            )
            if value > _BUCKETS[idx]:  # guard fp edge cases
                idx = min(idx + 1, len(_BUCKETS) - 1)
        with self._lock:
            self._counts[idx] += 1
            self._count += 1
            self._sum += value
            if value < self._min:
                self._min = value
            if value > self._max:
                self._max = value

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def percentile(self, p: float) -> float:
        """Estimated p-quantile (0 < p <= 1) from bucket upper bounds."""
        with self._lock:
            if self._count == 0:
                return 0.0
            rank = max(1, math.ceil(p * self._count))
            seen = 0
            for i, c in enumerate(self._counts):
                seen += c
                if seen >= rank:
                    # the +inf bucket reports the exact observed max
                    if math.isinf(_BUCKETS[i]):
                        return self._max
                    return min(_BUCKETS[i], self._max)
            return self._max

    def stats(self) -> dict[str, float]:
        with self._lock:
            if self._count == 0:
                return {"count": 0, "sum": 0.0}
            snap_counts = list(self._counts)
            count, total = self._count, self._sum
            lo, hi = self._min, self._max
        out = {
            "count": count,
            "sum": total,
            "min": lo,
            "max": hi,
            "mean": total / count,
        }
        for p, label in ((0.5, "p50"), (0.99, "p99")):
            rank = max(1, math.ceil(p * count))
            seen = 0
            for i, c in enumerate(snap_counts):
                seen += c
                if seen >= rank:
                    out[label] = hi if math.isinf(_BUCKETS[i]) else min(
                        _BUCKETS[i], hi
                    )
                    break
        return out


class Span:
    """Lightweight trace span with named phases.

    ``phase(name)`` closes the running segment and records it into the
    ``<span>_<phase>_seconds`` histogram; ``end()`` records the total
    into ``<span>_seconds`` and remembers the span in the registry's
    bounded recent-span buffer for inspection/tests.
    """

    __slots__ = ("name", "labels", "_registry", "_t0", "_last", "phases", "_done")

    def __init__(
        self,
        registry: "MetricsRegistry",
        name: str,
        labels: dict[str, Any] | None = None,
    ):
        self.name = name
        self.labels = dict(labels or {})
        self._registry = registry
        self._t0 = time.perf_counter()
        self._last = self._t0
        self.phases: list[tuple[str, float]] = []
        self._done = False

    def phase(self, phase_name: str) -> float:
        now = time.perf_counter()
        dur = now - self._last
        self._last = now
        self.phases.append((phase_name, dur))
        self._registry.histogram(
            f"{self.name}_{phase_name}_seconds"
        ).record(dur)
        return dur

    def end(self, outcome: str = "ok") -> float:
        if self._done:
            return 0.0
        self._done = True
        total = time.perf_counter() - self._t0
        self._registry.histogram(f"{self.name}_seconds").record(total)
        self._registry._remember_span(
            {
                "span": self.name,
                "labels": self.labels,
                "outcome": outcome,
                "total_s": total,
                "phases": [
                    {"phase": p, "seconds": s} for p, s in self.phases
                ],
            }
        )
        return total

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.end("error" if exc_type is not None else "ok")


class _NullSpan:
    """No-op span handed out by disabled registries."""

    __slots__ = ()
    phases: list = []

    def phase(self, phase_name: str) -> float:
        return 0.0

    def end(self, outcome: str = "ok") -> float:
        return 0.0

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return None


_NULL_SPAN = _NullSpan()


class _Timer:
    __slots__ = ("_hist", "_t0")

    def __init__(self, hist: Histogram | None):
        self._hist = hist

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        if self._hist is not None:
            self._hist.record(time.perf_counter() - self._t0)


class MetricsRegistry:
    """Thread-safe registry of named metric series.

    ``enabled=False`` turns every accessor into a near-free no-op (hot
    paths also guard timing blocks on :attr:`enabled`); this is what the
    observability benchmark pairs an instrumented cluster against.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        # snapshot() reads series values (their leaf locks) under this,
        # hence the distinct just-below-leaf class (repro.analysis.ranks)
        self._lock = make_lock("metrics-registry")
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}
        self._gauge_fns: dict[str, Callable[[], float]] = {}
        self._spans: deque[dict[str, Any]] = deque(maxlen=256)
        # shared no-op instances for the disabled fast path
        self._null_counter = Counter("__disabled__")
        self._null_gauge = Gauge("__disabled__")
        self._null_histogram = Histogram("__disabled__")

    # -- accessors ---------------------------------------------------

    def counter(self, name: str, **labels: Any) -> Counter:
        if not self.enabled:
            return self._null_counter
        key = series_key(name, labels)
        c = self._counters.get(key)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(key, Counter(key))
        return c

    def gauge(self, name: str, **labels: Any) -> Gauge:
        if not self.enabled:
            return self._null_gauge
        key = series_key(name, labels)
        g = self._gauges.get(key)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(key, Gauge(key))
        return g

    def histogram(
        self, name: str, *, sample: int = 1, **labels: Any
    ) -> Histogram:
        """``sample`` (power of two, set by the first creator of a
        series) enables 1-in-``sample`` hot-path sampling after a
        64-observation warm-up — see :class:`Histogram`."""
        if not self.enabled:
            return self._null_histogram
        key = series_key(name, labels)
        h = self._histograms.get(key)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(key, Histogram(key, sample=sample))
        return h

    def gauge_fn(
        self, name: str, fn: Callable[[], float], **labels: Any
    ) -> None:
        """Register a gauge evaluated lazily at snapshot/render time."""
        if not self.enabled:
            return
        with self._lock:
            self._gauge_fns[series_key(name, labels)] = fn

    def timer(self, name: str, **labels: Any) -> _Timer:
        if not self.enabled:
            return _Timer(None)
        return _Timer(self.histogram(name, **labels))

    def span(self, name: str, **labels: Any) -> Span | _NullSpan:
        if not self.enabled:
            return _NULL_SPAN
        return Span(self, name, labels)

    def _remember_span(self, record: dict[str, Any]) -> None:
        with self._lock:
            self._spans.append(record)

    def recent_spans(self, name: str | None = None) -> list[dict[str, Any]]:
        with self._lock:
            spans = list(self._spans)
        if name is None:
            return spans
        return [s for s in spans if s["span"] == name]

    # -- introspection helpers --------------------------------------

    def counter_value(self, name: str, **labels: Any) -> int:
        c = self._counters.get(series_key(name, labels))
        return c.value if c is not None else 0

    def gauge_value(self, name: str, **labels: Any) -> float:
        key = series_key(name, labels)
        g = self._gauges.get(key)
        if g is not None:
            return g.value
        fn = self._gauge_fns.get(key)
        if fn is not None:
            try:
                return float(fn())
            except Exception:
                return 0.0
        return 0.0

    # -- export ------------------------------------------------------

    def _collect_gauge_fns(self) -> dict[str, float]:
        with self._lock:
            fns = dict(self._gauge_fns)
        out: dict[str, float] = {}
        for key, fn in fns.items():
            try:
                out[key] = float(fn())
            except Exception:
                # a dead callback (e.g. broker being torn down) must not
                # poison the whole snapshot
                continue
        return out

    def snapshot(self) -> dict[str, Any]:
        """JSON-safe point-in-time dump of every series."""
        with self._lock:
            counters = {k: c.value for k, c in self._counters.items()}
            gauges = {k: g.value for k, g in self._gauges.items()}
            hists = dict(self._histograms)
        return {
            "ts": time.time(),
            "counters": counters,
            "gauges": {**gauges, **self._collect_gauge_fns()},
            "histograms": {k: h.stats() for k, h in hists.items()},
        }

    def render_text(self) -> str:
        """Prometheus-style text exposition (zero dependencies)."""
        snap = self.snapshot()
        lines: list[str] = []

        def base_name(key: str) -> str:
            return key.split("{", 1)[0]

        seen_types: set[str] = set()
        for key in sorted(snap["counters"]):
            b = base_name(key)
            if b not in seen_types:
                seen_types.add(b)
                lines.append(f"# TYPE {b} counter")
            lines.append(f"{key} {snap['counters'][key]}")
        for key in sorted(snap["gauges"]):
            b = base_name(key)
            if b not in seen_types:
                seen_types.add(b)
                lines.append(f"# TYPE {b} gauge")
            lines.append(f"{key} {_fmt(snap['gauges'][key])}")
        for key in sorted(snap["histograms"]):
            stats = snap["histograms"][key]
            b = base_name(key)
            if b not in seen_types:
                seen_types.add(b)
                lines.append(f"# TYPE {b} summary")
            name, _, labels = key.partition("{")
            labels = ("{" + labels) if labels else ""
            lines.append(f"{name}_count{labels} {stats['count']}")
            lines.append(f"{name}_sum{labels} {_fmt(stats['sum'])}")
            for q in ("p50", "p99"):
                if q in stats:
                    lines.append(f"{name}_{q}{labels} {_fmt(stats[q])}")
        return "\n".join(lines) + "\n"

    @staticmethod
    def decode_snapshot(payload: bytes) -> dict[str, Any]:
        """Decode one ``__metrics`` record back into a snapshot dict."""
        return json.loads(payload.decode("utf-8"))

    def encode_snapshot(self) -> bytes:
        return json.dumps(self.snapshot(), sort_keys=True).encode("utf-8")


def _fmt(v: float) -> str:
    if isinstance(v, float) and v.is_integer():
        return str(int(v))
    return repr(v)


# Process-wide registry for components that have no cluster to hang a
# registry off (data-pipeline daemons: prefetch workers, device_feed).
# Cluster-scoped series stay on the cluster's own registry.
_default_registry: MetricsRegistry | None = None


def default_registry() -> MetricsRegistry:
    global _default_registry
    if _default_registry is None:
        _default_registry = MetricsRegistry()
    return _default_registry
