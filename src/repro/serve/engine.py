"""Inference serving — the paper's Algorithm 2 + Replication Controller.

An :class:`InferenceDeployment` runs N replicas of a trained model. All
replicas join one consumer group on the input topic, so Kafka's partition
assignment load-balances request batches across them (paper §III-E); a
replica that stops heartbeating loses its partitions to the survivors
(fault tolerance) and committed offsets mean no request is lost.

Each replica is Algorithm 2 verbatim:

    model <- downloadTrainedModelFromBackend(model_url)
    deserializer <- getDeserializer(input_configuration)   # from the
        control message captured at training time (paper §IV-E autoconfig)
    loop: read stream -> decode -> predict -> send to output topic

``predict_fn`` is pluggable: the COPD MLP forward, or an LM decode loop
built by :func:`build_serve_step` (the pjit'd single-token step used by
the dry-run and the serving examples).

Deployments run against any :class:`~repro.core.log.StreamBackend`: on a
:class:`~repro.core.cluster.BrokerCluster` the request and prediction
topics are replicated, replica reads follow partition leaders through
elections, and committed group offsets survive broker loss — replica
failover (consumer-group layer) composes with broker failover (cluster
layer).
"""

from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.cluster import ClusterProducer, InvalidTxnState
from repro.core.consumer import ConsumerGroup, RebalanceError
from repro.core.log import ProducerFenced, StreamBackend
from repro.core.registry import Registry, TrainedResult
from repro.data.formats import codec_from_control, decode_span_fields
from repro.models.model import StreamModel

__all__ = [
    "InferenceDeployment",
    "InferenceReplica",
    "TxnOutputPublisher",
    "build_serve_step",
    "build_prefill_step",
]


class TxnOutputPublisher:
    """Transactional produce-and-commit for one consumer-group worker.

    Wraps the exactly-once publish pattern of DESIGN.md §8: outputs and
    the input offsets they were computed from commit in ONE transaction,
    so a worker crash between "produce outputs" and "commit offsets" can
    neither re-serve a polled batch (duplicates downstream) nor drop
    one. The worker owns a stable transactional id — re-creating the
    publisher fences its zombie. Shared by :class:`InferenceReplica`
    and the LM serving workers (:mod:`repro.serve.lm_engine`).
    """

    def __init__(self, log, consumer, member_id: str, transactional_id: str):
        self.log = log
        self.consumer = consumer
        self.member_id = member_id
        self.producer = ClusterProducer(log, transactional_id=transactional_id)

    def txn_aborted(self) -> bool:
        """Whether the producer's current/last transaction is (or will
        be) aborted — drives whether local positions must rewind. A
        durably-decided COMMIT means the positions stand: rewinding them
        would re-deliver (and re-publish) a batch the commit covers."""
        st = self.log.txn_state(self.producer.producer_id)
        return st not in ("prepare_commit", "complete_commit")

    def recover_txn(self) -> bool:
        """Resolve a transaction a previous tick left behind (commit or
        abort raised mid-flight) before starting a new one. Returns True
        when it ended in an abort — local positions were rewound, so the
        CURRENT tick's computed outputs must be discarded too (their
        source records re-deliver at the next poll; publishing them now
        would commit outputs whose offsets were just reset)."""
        prod = self.producer
        try:
            prod.abort_txn()
            self.consumer.reset_positions()
            return True
        except (InvalidTxnState, ProducerFenced):
            pass  # outcome already decided (or we were fenced)
        except Exception:
            pass  # quorum window: outcome still open, try again next tick
        if self.txn_aborted():
            self.consumer.reset_positions()
            return True
        # commit durably decided: finish it (at the transaction's own
        # recorded epoch) so the committed offsets reflect the previous
        # tick's work before the next poll
        try:
            self.log.resolve_txn(prod.producer_id)
        except Exception:
            pass  # controller_tick recovery finishes it
        return False

    def publish(
        self,
        topic: str,
        batches: list[list[bytes]],
        keys: list[list[bytes]] | None = None,
    ) -> int:
        """Produce ``batches`` and commit the consumer's polled offsets
        in one transaction. With ``keys`` (parallel structure to
        ``batches``) records route by key hash — per-tenant partitioning
        — via per-record sends; without, each batch lands on partition 0
        in one append. Returns records published, or 0 when the tick
        must be discarded (recovery rewound positions, or the group
        moved on mid-compute)."""
        prod = self.producer
        if prod.in_txn:
            if self.recover_txn():
                return 0  # positions rewound: this tick's outs re-derive
            if prod.in_txn:
                return 0  # still unresolved (no quorum): skip this tick
        if not batches:
            return 0  # nothing polled: nothing to publish or commit
        self.log.ensure_topic(topic)
        prod.begin_txn()
        try:
            done = 0
            for i, out in enumerate(batches):
                if keys is None:
                    prod.send_batch(topic, out, partition=0)
                else:
                    # send_batch routes the whole batch by keys[0]; keyed
                    # records must fan out per-record to partition by key
                    for v, k in zip(out, keys[i]):
                        prod.send(topic, v, key=k)
                done += len(out)
            group = self.consumer.group
            if (
                self.member_id not in group.members
                or group.generation != self.consumer.generation
            ):
                # the group moved on while we computed (stall → eviction
                # → rebalance): committing these offsets would rewind the
                # new owner. Abort — the aborted outputs are invisible,
                # and the new owner re-serves the batch. (Best-effort
                # fence, same shape as commit_member's generation check;
                # the generation-atomic variant is the KIP-447 follow-up
                # in ROADMAP.)
                prod.abort_txn()
                self.consumer.reset_positions()
                return 0
            prod.send_offsets_to_txn(group.group_id, self.consumer.positions())
            prod.commit_txn()
        except BaseException:
            try:
                prod.abort_txn()
            except Exception:
                pass  # decided or quorum-blocked: resolved below / next tick
            if self.txn_aborted():
                # the abort un-published this tick's work: rewind to the
                # committed offsets so the next poll re-delivers it
                self.consumer.reset_positions()
            raise
        return done


# ----------------------------------------------------------- pjit serve steps
def build_serve_step(model: StreamModel, mesh: Mesh | None = None):
    """Single-token decode step, sharded: (params, cache, tokens, pos) ->
    (logits, cache). Cache is donated (updated in place device-side)."""

    def step(params, caches, tokens, pos):
        return model.decode_step(params, caches, tokens, pos)

    if mesh is None:
        return jax.jit(step, donate_argnums=(1,))
    pspecs = model.param_pspecs()
    pshard = jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), pspecs, is_leaf=lambda x: isinstance(x, P)
    )
    return jax.jit(step, donate_argnums=(1,)), pshard


def build_prefill_step(model: StreamModel, s_cache: int, mesh: Mesh | None = None):
    def step(params, batch):
        return model.prefill(params, batch, s_cache)

    return jax.jit(step, static_argnums=())


# ------------------------------------------------------------------- replicas
@dataclasses.dataclass
class ReplicaStats:
    processed: int = 0
    batches: int = 0
    errors: int = 0


class InferenceReplica:
    """One containerized inference worker (paper Algorithm 2)."""

    def __init__(
        self,
        replica_id: str,
        log: StreamBackend,
        group: ConsumerGroup,
        result: TrainedResult,
        predict_fn: Callable[[Mapping[str, np.ndarray]], np.ndarray],
        output_topic: str,
        transactional: bool = False,
    ):
        self.replica_id = replica_id
        self.log = log
        # transactional publish (DESIGN.md §8), via TxnOutputPublisher
        txn = transactional and hasattr(log, "init_producer")
        self.consumer = group.join(
            replica_id,
            isolation_level="read_committed" if txn else None,
        )
        self._publisher = (
            TxnOutputPublisher(
                log, self.consumer, replica_id,
                transactional_id=f"{group.group_id}-{replica_id}",
            )
            if txn
            else None
        )
        # getDeserializer(input_configuration): auto-configured from the
        # training control message (paper §IV-E)
        self.codec = codec_from_control(result.input_format, result.input_config)
        self.predict_fn = predict_fn
        self.output_topic = output_topic
        self.stats = ReplicaStats()
        self.alive = True

    def poll_once(self, max_records: int = 256) -> int:
        """One loop iteration: read -> decode -> predict -> produce."""
        return self.publish(self.poll_compute(max_records))

    def poll_compute(self, max_records: int = 256) -> list[list[bytes]] | None:
        """The parallel-safe half of a poll: read assigned partitions,
        decode, predict — everything except publishing. Returns encoded
        output batches for :meth:`publish`, or None if this replica is
        dead. Splitting the tick lets a deployment run every replica's
        compute concurrently while still publishing (and committing) in
        replica order, so the output stream stays deterministic."""
        if not self.alive:
            return None
        if self.replica_id not in self.consumer.group.members:
            # evicted while alive (heartbeats lapsed under load, not a
            # crash): re-enter the group and resume from committed
            # offsets next tick — without this a momentarily-stalled
            # replica would stay silent forever
            self.consumer.rejoin()
            return None
        outs: list[list[bytes]] = []
        # poll-to-predict latency (no-op on backends with no registry)
        reg = getattr(self.log, "metrics", None)
        instrument = reg is not None and reg.enabled
        t0 = time.perf_counter() if instrument else 0.0
        try:
            polled = self.consumer.poll(max_records)
        except RebalanceError:
            # expired between the membership check above and the poll
            # (failure detection ran concurrently): rejoin and skip the
            # tick instead of killing the deployment's poll thread
            self.consumer.rejoin()
            return None
        # dispatch/collect split (DESIGN.md §10): predict for batch i is
        # dispatched before batch i+1 is decoded — with a jitted
        # predict_fn, JAX's async dispatch returns immediately and the
        # device computes batch i while the host zero-copy decodes i+1.
        # Results are collected (np.asarray blocks on the device) only
        # after every dispatch is in flight.
        pending = []
        for batch in polled:
            pending.append(self.predict_fn(self._decode_batch(batch)))
        for preds in pending:
            preds = np.asarray(preds)
            outs.append([preds[i].tobytes() for i in range(preds.shape[0])])
        if instrument and outs:
            reg.histogram(
                "serve_poll_to_predict_seconds", replica=self.replica_id
            ).record(time.perf_counter() - t0)
            reg.counter(
                "serve_predictions_total", replica=self.replica_id
            ).inc(sum(len(o) for o in outs))
        return outs

    def _decode_batch(self, batch) -> dict[str, np.ndarray]:
        """Decode a polled request batch to its data fields, zero-copy
        when framed (DESIGN.md §10).

        Inference streams carry only the data fields; full-record
        streams (training-format replays) are tolerated by slicing the
        data prefix. Either layout takes the framed strided-view path
        when the fetch is contiguous; a filtered/ragged fetch falls back
        to the copying matrix decode.
        """
        data_fields = list(
            getattr(self.codec, "data_fields", self.codec.fields[:-1])
        )
        data_bytes = sum(f.nbytes for f in data_fields)
        if batch.framed(self.codec.record_bytes) is not None:
            full = self.codec.decode_frames(batch)
            return {f.name: full[f.name] for f in data_fields}
        spans = batch.framed(data_bytes)
        if spans is not None:
            # data-only records: frame against the data-prefix layout
            offs, pos = [], 0
            for f in data_fields:
                offs.append(pos)
                pos += f.nbytes
            parts = [
                decode_span_fields(mv, cnt, data_fields, offs, data_bytes)[0]
                for mv, cnt in spans
            ]
            if len(parts) == 1:
                return parts[0]
            return {
                f.name: np.concatenate([p[f.name] for p in parts], axis=0)
                for f in data_fields
            }
        return _decode_data(self.codec, batch.to_matrix(), data_bytes)

    def publish(self, outs: list[list[bytes]] | None) -> int:
        """Produce computed predictions, then commit the read offsets —
        commit-after-produce keeps delivery at-least-once (a crash between
        the two re-polls the batch). A transactional replica upgrades the
        pair to exactly-once: predictions and offsets commit atomically."""
        if outs is None:
            return 0
        if self._publisher is not None:
            done = self._publisher.publish(self.output_topic, outs)
            if done:
                self.stats.processed += done
                self.stats.batches += len(outs)
            return done
        done = 0
        if outs:
            self.log.ensure_topic(self.output_topic)
        for out in outs:
            self.log.produce_batch(self.output_topic, out, partition=0)
            self.stats.processed += len(out)
            self.stats.batches += 1
            done += len(out)
        self.consumer.commit()
        return done

    def kill(self) -> None:
        """Simulated crash: stops heartbeating (the group expires it)."""
        self.alive = False


def _decode_data(codec, mat: np.ndarray, data_bytes: int) -> dict[str, np.ndarray]:
    if mat.shape[1] == codec.record_bytes:
        full = codec.decode_matrix(mat)
        names = [f.name for f in getattr(codec, "data_fields", codec.fields[:-1])]
        return {k: full[k] for k in names}
    # data-only records
    out: dict[str, np.ndarray] = {}
    off = 0
    for f in getattr(codec, "data_fields", codec.fields[:-1]):
        chunk = np.ascontiguousarray(mat[:, off : off + f.nbytes])
        out[f.name] = chunk.view(np.dtype(f.dtype)).reshape((mat.shape[0],) + f.shape)
        off += f.nbytes
    return out


class InferenceDeployment:
    """The Replication Controller: N replicas on one consumer group.

    ``parallel_poll=True`` (default) drives the replicas' compute phases
    (read → decode → predict) concurrently from a worker pool: each
    replica owns disjoint partitions (consumer-group range assignment),
    so on a cluster with per-partition locking their reads don't contend
    and one slow replica no longer stalls the whole tick's compute.
    Outputs are then published — and offsets committed — serially in
    replica order, so the output topic's record order is identical to a
    serial tick's.

    ``transactional=True`` (clusters only) makes each replica publish its
    predictions atomically with the input offsets they answer — a replica
    crash mid-tick can neither duplicate nor drop a served request batch,
    and downstream read_committed consumers of the prediction topic never
    observe a half-published tick. Replicas then also read their input
    read_committed, composing end-to-end exactly-once with a
    transactional upstream (DESIGN.md §8).
    """

    def __init__(
        self,
        log: StreamBackend,
        registry: Registry,
        result_id: str,
        predict_fn,
        *,
        input_topic: str,
        output_topic: str,
        replicas: int = 2,
        session_timeout_s: float = 5.0,
        parallel_poll: bool = True,
        transactional: bool = False,
        clock=None,
    ):
        self.log = log
        self.result = registry.result(result_id)
        self.group = ConsumerGroup(
            log,
            group_id=f"infer-{result_id}",
            topics=[input_topic],
            session_timeout_s=session_timeout_s,
            clock=clock,
        )
        self.replicas = [
            InferenceReplica(
                f"replica-{i}", log, self.group, self.result, predict_fn,
                output_topic, transactional=transactional,
            )
            for i in range(replicas)
        ]
        self.input_topic = input_topic
        self.output_topic = output_topic
        self.parallel_poll = parallel_poll
        self._pool: ThreadPoolExecutor | None = None

    def poll_all(self) -> int:
        """Drive every live replica one iteration (the K8s 'tick')."""
        for r in self.replicas:  # live replicas heartbeat, dead ones don't
            if r.alive and r.replica_id in self.group.members:
                self.group.heartbeat(r.replica_id)
        self.group.expire_dead_members()
        if self.parallel_poll and len(self.replicas) > 1:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=len(self.replicas),
                    thread_name_prefix="replica-poll",
                )
            # compute in parallel, publish+commit in replica order. One
            # replica's failure must not abandon siblings' already-polled
            # work (their consumer positions advanced): publish every
            # healthy result first, then re-raise the first error.
            futs = [self._pool.submit(r.poll_compute) for r in self.replicas]
            total = 0
            first_err: BaseException | None = None
            for r, f in zip(self.replicas, futs):
                try:
                    total += r.publish(f.result())
                except BaseException as e:
                    if first_err is None:
                        first_err = e
            if first_err is not None:
                raise first_err
            return total
        return sum(r.poll_once() for r in self.replicas)

    def close(self) -> None:
        """Release the polling pool (idempotent)."""
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def __del__(self):  # backstop for call sites that never close()
        try:
            if self._pool is not None:
                self._pool.shutdown(wait=False)
        except Exception:
            pass

    def kill_replica(self, idx: int) -> None:
        self.replicas[idx].kill()

    def drain(self, max_iters: int = 100) -> int:
        total = 0
        for _ in range(max_iters):
            got = self.poll_all()
            total += got
            if got == 0:
                break
        return total
