from repro.serve.engine import (
    InferenceDeployment,
    InferenceReplica,
    build_prefill_step,
    build_serve_step,
)
from repro.serve.lm_engine import LMEngine, Request, serve_stream
