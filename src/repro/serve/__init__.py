from repro.serve.engine import (
    InferenceDeployment,
    InferenceReplica,
    TxnOutputPublisher,
    build_prefill_step,
    build_serve_step,
)
from repro.serve.lm_engine import (
    ContinuousLMEngine,
    KVBlockTable,
    LMEngine,
    LMServingGroup,
    LMServingWorker,
    Request,
    decode_completion,
    decode_request,
    encode_completion,
    encode_request,
    serve_stream,
    tenant_key,
)
