"""Wave-batching LM serving engine on the stream pipeline.

Extends the paper's Algorithm 2 from stateless per-batch prediction to
stateful LM generation. Requests are served in **waves**: up to
``n_slots`` equal-length prompts are prefetched from the queue, prefilled
as one batch, then decoded together step by step; sequences that hit
``eos``/``max_new`` early stop contributing (their lanes idle until the
wave ends). The queue refills the next wave.

This is the TPU-simple point on the batching spectrum: fixed shapes, one
fused prefill + one fused decode step per iteration, no per-slot position
bookkeeping. Fully continuous (per-slot) batching needs per-row cache
positions + per-row validity windows in decode attention; measured lane
idle time is bounded by (max_new - mean_new)/max_new per wave, which is
small for tight max_new — recorded as the trade, with per-slot batching
as identified future work (DESIGN.md §4c).

Transport is the paper's: prompts on an input topic (consumer groups load-
balance across engine replicas), completions on the output topic.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.log import StreamLog
from repro.models.model import StreamModel

__all__ = ["LMEngine", "Request", "serve_stream"]


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new: int


class LMEngine:
    """Fixed-slot wave batching around prefill + decode_step."""

    def __init__(
        self,
        model: StreamModel,
        params,
        *,
        n_slots: int = 4,
        s_cache: int = 128,
        eos_id: int | None = None,
    ):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.s_cache = s_cache
        self.eos_id = eos_id
        self.queue: list[Request] = []
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, s_cache, cache_dtype=jnp.float32)
        )
        self._decode = jax.jit(model.decode_step)
        self.waves = 0
        self.lane_steps = 0
        self.useful_steps = 0

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _next_wave(self) -> list[Request]:
        wave: list[Request] = []
        while self.queue and len(wave) < self.n_slots:
            wave.append(self.queue.pop(0))
        return wave

    def run_wave(self) -> list[tuple[int, np.ndarray]]:
        wave = self._next_wave()
        if not wave:
            return []
        self.waves += 1
        plen = len(wave[0].prompt)
        assert all(len(r.prompt) == plen for r in wave), "wave = equal-length prompts"
        # pad the batch up to n_slots with a copy of row 0 (fixed shapes)
        rows = [r.prompt for r in wave] + [wave[0].prompt] * (self.n_slots - len(wave))
        prompts = jnp.asarray(np.stack(rows).astype(np.int32))
        logits, cache = self._prefill(self.params, {"tokens": prompts})
        tok = jnp.argmax(logits, -1)[:, None]
        max_new = max(r.max_new for r in wave)
        gen = np.full((self.n_slots, max_new), -1, np.int32)
        gen[:, 0] = np.asarray(tok[:, 0])
        alive = np.array([r.max_new > 1 for r in wave] + [False] * (self.n_slots - len(wave)))
        if self.eos_id is not None:
            alive &= gen[:, 0] != self.eos_id
        for step in range(1, max_new):
            if not alive.any():
                break
            lg, cache = self._decode(self.params, cache, tok, jnp.int32(plen + step - 1))
            tok = jnp.argmax(lg[:, 0], -1)[:, None]
            t = np.asarray(tok[:, 0])
            self.lane_steps += self.n_slots
            self.useful_steps += int(alive.sum())
            for i, r in enumerate(wave):
                if alive[i]:
                    gen[i, step] = t[i]
                    if (self.eos_id is not None and t[i] == self.eos_id) or step + 1 >= r.max_new:
                        alive[i] = False
        return [(r.req_id, gen[i, : r.max_new].copy()) for i, r in enumerate(wave)]

    def run_until_drained(self, max_waves: int = 10_000):
        out = []
        for _ in range(max_waves):
            if not self.queue:
                break
            out.extend(self.run_wave())
        return out

    @property
    def lane_utilization(self) -> float:
        return self.useful_steps / max(self.lane_steps, 1)


def serve_stream(
    engine: LMEngine,
    log: StreamLog,
    input_topic: str,
    output_topic: str,
    prompt_len: int,
    *,
    max_new: int = 16,
) -> int:
    """Drain an input topic of fixed-length prompts through the engine.

    Input records: int32[prompt_len] tokens. Output records:
    ``req_id int32 || generated int32[max_new]`` (padded with -1).
    """
    log.ensure_topic(output_topic)
    offset, rid = 0, 0
    end = log.end_offset(input_topic, 0)
    while offset < end:
        batch = log.read(input_topic, 0, offset, 64)
        mat = batch.to_matrix()
        toks = np.ascontiguousarray(mat).view(np.int32).reshape(len(batch), -1)
        for row in toks:
            engine.submit(Request(rid, row[:prompt_len], max_new))
            rid += 1
        offset = batch.next_offset
    served = 0
    for req_id, gen in engine.run_until_drained():
        out = np.full(max_new + 1, -1, np.int32)
        out[0] = req_id
        out[1 : 1 + len(gen)] = gen[:max_new]
        log.produce(output_topic, out.tobytes())
        served += 1
    return served
