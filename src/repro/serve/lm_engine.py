"""LM serving engines on the stream pipeline: wave and continuous batching.

Extends the paper's Algorithm 2 from stateless per-batch prediction to
stateful LM generation. Two engines share the model's prefill/decode
steps:

- :class:`LMEngine` — **wave** batching: up to ``n_slots`` equal-length
  prompts are prefilled as one batch, then decoded together step by
  step; sequences that hit ``eos``/``max_new`` early stop contributing
  (their lanes idle until the wave ends). Fixed shapes, one fused
  prefill + one fused decode per iteration, no per-slot bookkeeping —
  but it cannot mix prompt lengths in a wave and lane idle time grows
  with the spread of ``max_new``.

- :class:`ContinuousLMEngine` — **continuous (per-slot)** batching
  (DESIGN.md §13): requests are admitted into the in-flight decode
  batch the moment a slot frees up. Each slot decodes at its own cache
  position (the model's per-row ``decode_step``), finished slots are
  recycled immediately, and K/V lives in a blocked/paged pool
  (:meth:`~repro.models.model.StreamModel.init_paged_cache`) so slots
  with different prompt lengths share the cache without fragmentation.
  Greedy outputs are token-identical to the wave engine — the
  batch/stream-identical framing the DataFlow line of work argues for,
  applied to serving.

Transport is the paper's: requests on an input topic (consumer groups
load-balance across serving workers, keys partition by tenant),
completions on a response topic. :class:`LMServingWorker` wires an
engine into the group-consumer/transactional-publish machinery shared
with :class:`~repro.serve.engine.InferenceReplica`, so a worker crash
mid-serve neither loses nor duplicates completions.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.witness import make_lock
from repro.core.consumer import ConsumerGroup, RebalanceError
from repro.core.log import StreamLog
from repro.models.model import StreamModel
from repro.serve.engine import TxnOutputPublisher

__all__ = [
    "ContinuousLMEngine",
    "KVBlockTable",
    "LMEngine",
    "LMServingGroup",
    "LMServingWorker",
    "Request",
    "decode_completion",
    "decode_request",
    "encode_completion",
    "encode_request",
    "serve_stream",
]


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new: int
    tenant: int = 0  # partitioning key on the request/response topics


# ------------------------------------------------------- topic record codec
# Request records: int32 header [req_id, tenant, max_new, plen] || prompt
# tokens. Completion records: int32 [req_id, tenant, n] || n generated
# tokens. Variable length — decoded per record, not via to_matrix.

def encode_request(req: Request) -> bytes:
    hdr = np.array([req.req_id, req.tenant, req.max_new, len(req.prompt)], np.int32)
    return hdr.tobytes() + np.asarray(req.prompt, np.int32).tobytes()


def decode_request(buf) -> Request:
    a = np.frombuffer(buf, np.int32)
    rid, tenant, max_new, plen = (int(x) for x in a[:4])
    return Request(rid, a[4 : 4 + plen].copy(), max_new, tenant=tenant)


def encode_completion(req_id: int, tenant: int, tokens: np.ndarray) -> bytes:
    hdr = np.array([req_id, tenant, len(tokens)], np.int32)
    return hdr.tobytes() + np.asarray(tokens, np.int32).tobytes()


def decode_completion(buf) -> tuple[int, int, np.ndarray]:
    a = np.frombuffer(buf, np.int32)
    return int(a[0]), int(a[1]), a[3 : 3 + int(a[2])].copy()


def tenant_key(tenant: int) -> bytes:
    """The record key a tenant's requests/completions partition by."""
    return np.int32(tenant).tobytes()


# ------------------------------------------------------------- wave engine
class LMEngine:
    """Fixed-slot wave batching around prefill + decode_step."""

    def __init__(
        self,
        model: StreamModel,
        params,
        *,
        n_slots: int = 4,
        s_cache: int = 128,
        eos_id: int | None = None,
    ):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.s_cache = s_cache
        self.eos_id = eos_id
        # submit() races with the decode loop (a polling worker feeds the
        # queue from another thread): deque + engine-ranked lock, popleft
        # is O(1) where the old list.pop(0) was O(n)
        self.queue: deque[Request] = deque()
        self._lock = make_lock("engine", name="lm-wave")
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, s_cache, cache_dtype=jnp.float32)
        )
        self._decode = jax.jit(model.decode_step)
        self.waves = 0
        self.lane_steps = 0
        self.useful_steps = 0
        self.first_token_s: dict[int, float] = {}  # req_id -> TTFT timestamp

    def submit(self, req: Request) -> None:
        with self._lock:
            self.queue.append(req)

    def qsize(self) -> int:
        with self._lock:
            return len(self.queue)

    def _next_wave(self) -> list[Request]:
        wave: list[Request] = []
        with self._lock:
            while self.queue and len(wave) < self.n_slots:
                nxt = self.queue[0]
                if wave and len(nxt.prompt) != len(wave[0].prompt):
                    break  # waves are equal-length: leave it for the next wave
                wave.append(self.queue.popleft())
        return wave

    def run_wave(self) -> list[tuple[int, np.ndarray]]:
        wave = self._next_wave()
        if not wave:
            return []
        self.waves += 1
        plen = len(wave[0].prompt)
        assert all(len(r.prompt) == plen for r in wave), "wave = equal-length prompts"
        # pad the batch up to n_slots with a copy of row 0 (fixed shapes)
        rows = [r.prompt for r in wave] + [wave[0].prompt] * (self.n_slots - len(wave))
        prompts = jnp.asarray(np.stack(rows).astype(np.int32))
        logits, cache = self._prefill(self.params, {"tokens": prompts})
        tok = jnp.argmax(logits, -1)[:, None]
        now = time.perf_counter()
        for r in wave:
            self.first_token_s[r.req_id] = now
        max_new = max(r.max_new for r in wave)
        gen = np.full((self.n_slots, max_new), -1, np.int32)
        gen[:, 0] = np.asarray(tok[:, 0])
        alive = np.array([r.max_new > 1 for r in wave] + [False] * (self.n_slots - len(wave)))
        if self.eos_id is not None:
            alive &= gen[:, 0] != self.eos_id
        for step in range(1, max_new):
            if not alive.any():
                break
            lg, cache = self._decode(self.params, cache, tok, jnp.int32(plen + step - 1))
            tok = jnp.argmax(lg[:, 0], -1)[:, None]
            t = np.asarray(tok[:, 0])
            self.lane_steps += self.n_slots
            self.useful_steps += int(alive.sum())
            for i, r in enumerate(wave):
                if alive[i]:
                    gen[i, step] = t[i]
                    if (self.eos_id is not None and t[i] == self.eos_id) or step + 1 >= r.max_new:
                        alive[i] = False
        return [(r.req_id, gen[i, : r.max_new].copy()) for i, r in enumerate(wave)]

    def run_until_drained(self, max_waves: int = 10_000):
        out = []
        for _ in range(max_waves):
            if not self.qsize():
                break
            out.extend(self.run_wave())
        return out

    @property
    def lane_utilization(self) -> float:
        return self.useful_steps / max(self.lane_steps, 1)


# --------------------------------------------------------- paged KV blocks
class KVBlockTable:
    """Host-side free-list over the physical KV block pool.

    Block 0 is the reserved scratch target idle rows' (discarded) decode
    writes land in — it is never handed out, so a recycled slot's
    zeroed block table can never alias a live row's blocks.
    """

    def __init__(self, n_blocks: int):
        if n_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is reserved scratch)")
        self.n_blocks = n_blocks
        self._free = list(range(n_blocks - 1, 0, -1))  # pop() yields 1, 2, ...

    def reserve(self, n: int) -> list[int] | None:
        """n physical block ids, or None if the pool can't cover them
        (all-or-nothing, so admission never deadlocks holding a rump)."""
        if len(self._free) < n:
            return None
        return [self._free.pop() for _ in range(n)]

    def release(self, ids: list[int]) -> None:
        self._free.extend(ids)

    @property
    def free_blocks(self) -> int:
        return len(self._free)


@dataclasses.dataclass
class _Slot:
    req: Request
    blocks: list[int]  # physical block ids owned by this row
    pos: int  # tokens in cache (mirrors the device-side per-row pos)
    generated: list[int]


# -------------------------------------------------------- continuous engine
class ContinuousLMEngine:
    """Continuous (per-slot) batching over a paged KV cache.

    Each :meth:`step` first admits queued requests into free slots —
    a per-request prefill scattered into reserved blocks via
    ``paged_insert`` — then runs ONE fused ``decode_step`` across all
    slots with a per-row position vector. Slots that hit ``eos`` /
    ``max_new`` are recycled immediately (blocks released, block table
    zeroed), so a long request never holds idle lanes hostage the way a
    wave does. Greedy outputs are token-identical to :class:`LMEngine`.
    """

    def __init__(
        self,
        model: StreamModel,
        params,
        *,
        n_slots: int = 4,
        n_blocks: int = 64,
        block_size: int = 16,
        max_blocks: int = 16,
        eos_id: int | None = None,
    ):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.block_size = block_size
        self.max_blocks = max_blocks
        self.eos_id = eos_id
        self.queue: deque[Request] = deque()
        self._lock = make_lock("engine", name="lm-continuous")
        self.blocks = KVBlockTable(n_blocks)
        self.caches = model.init_paged_cache(
            n_slots, n_blocks, block_size, max_blocks, dtype=jnp.float32
        )
        self.slots: list[_Slot | None] = [None] * n_slots
        self._tok = np.zeros((n_slots, 1), np.int32)  # each row's last token
        self._decode = jax.jit(model.decode_step)
        self._clear = jax.jit(model.paged_clear)

        def _admit(params, caches, tokens, row, block_ids, bt_row, plen):
            # pad the prefill cache to whole blocks; block_ids' (static)
            # length fixes s_pad, so jit specializes per length bucket
            s_pad = block_ids.shape[0] * block_size
            logits, small = model.prefill(
                params, {"tokens": tokens}, s_pad, cache_dtype=jnp.float32
            )
            caches = model.paged_insert(caches, small, row, block_ids, bt_row, plen)
            return logits[0], caches

        self._admit = jax.jit(_admit)
        self.lane_steps = 0
        self.useful_steps = 0
        self.admissions = 0
        self.first_token_s: dict[int, float] = {}  # req_id -> TTFT timestamp

    def _blocks_needed(self, req: Request) -> int:
        # final decode step writes K/V at position plen + max_new - 2;
        # the cache must hold plen + max_new - 1 tokens
        return -(-(len(req.prompt) + max(req.max_new, 1) - 1) // self.block_size)

    def submit(self, req: Request) -> None:
        if self._blocks_needed(req) > self.max_blocks:
            raise ValueError(
                f"request {req.req_id}: {len(req.prompt)}+{req.max_new} tokens "
                f"exceeds max_blocks={self.max_blocks} * block={self.block_size}"
            )
        with self._lock:
            self.queue.append(req)

    def qsize(self) -> int:
        with self._lock:
            return len(self.queue)

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def _finish(self, row: int, out: list[tuple[int, np.ndarray]]) -> None:
        slot = self.slots[row]
        gen = np.asarray(slot.generated[: slot.req.max_new], np.int32)
        out.append((slot.req.req_id, gen))
        self.blocks.release(slot.blocks)
        # zero the row's position + block table so its idle writes land
        # in the scratch block — a stale table would corrupt whichever
        # row the freed blocks go to next
        self.caches = self._clear(self.caches, jnp.int32(row))
        self.slots[row] = None

    def _admit_pending(self, out: list[tuple[int, np.ndarray]]) -> None:
        for row in range(self.n_slots):
            if self.slots[row] is not None:
                continue
            with self._lock:
                req = self.queue.popleft() if self.queue else None
            if req is None:
                return
            need = self._blocks_needed(req)
            blocks = self.blocks.reserve(need)
            if blocks is None:
                with self._lock:
                    self.queue.appendleft(req)  # pool exhausted: retry later
                return
            plen = len(req.prompt)
            nb_prefill = -(-plen // self.block_size)
            bt_row = np.zeros(self.max_blocks, np.int32)
            bt_row[:need] = blocks
            tokens = jnp.asarray(req.prompt, jnp.int32)[None]
            logits, self.caches = self._admit(
                self.params,
                self.caches,
                tokens,
                jnp.int32(row),
                jnp.asarray(blocks[:nb_prefill], jnp.int32),
                jnp.asarray(bt_row),
                jnp.int32(plen),
            )
            tok0 = int(jnp.argmax(logits))
            self.first_token_s[req.req_id] = time.perf_counter()
            self.admissions += 1
            self.slots[row] = _Slot(req, blocks, plen, [tok0])
            self._tok[row, 0] = tok0
            if req.max_new <= 1 or (self.eos_id is not None and tok0 == self.eos_id):
                self._finish(row, out)

    def step(self) -> list[tuple[int, np.ndarray]]:
        """One engine tick: admit from the queue, then one fused decode
        step across every active slot. Returns completions finished this
        tick as ``(req_id, generated)`` pairs."""
        out: list[tuple[int, np.ndarray]] = []
        self._admit_pending(out)
        rows = [r for r in range(self.n_slots) if self.slots[r] is not None]
        if not rows:
            return out
        pos_vec = np.zeros(self.n_slots, np.int32)
        for r in rows:
            pos_vec[r] = self.slots[r].pos
        lg, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(self._tok), jnp.asarray(pos_vec)
        )
        tok = jnp.argmax(lg[:, 0], -1)
        t = np.asarray(tok)
        self.lane_steps += self.n_slots
        self.useful_steps += len(rows)
        for r in rows:
            slot = self.slots[r]
            slot.generated.append(int(t[r]))
            slot.pos += 1
            self._tok[r, 0] = t[r]
            if (
                self.eos_id is not None and t[r] == self.eos_id
            ) or len(slot.generated) >= slot.req.max_new:
                self._finish(r, out)
        return out

    def run_until_drained(self, max_steps: int = 100_000):
        out: list[tuple[int, np.ndarray]] = []
        for _ in range(max_steps):
            if not self.qsize() and self.active == 0:
                break
            out.extend(self.step())
        return out

    @property
    def lane_utilization(self) -> float:
        return self.useful_steps / max(self.lane_steps, 1)


# -------------------------------------------------------- cluster serving
class LMServingWorker:
    """One serving worker: group consumer -> engine -> response topic.

    The Algorithm 2 loop with LM state: poll requests from the group's
    assigned partitions, submit to the engine, drain, publish keyed
    completions. ``transactional=True`` (clusters only) publishes
    completions atomically with the consumed request offsets
    (:class:`~repro.serve.engine.TxnOutputPublisher`): a worker crash
    mid-serve can neither lose nor duplicate a completion — the
    re-delivered requests re-serve deterministically (greedy decode) and
    the aborted first attempt stays invisible to read_committed readers.
    A full engine queue pauses the consumer (backpressure) instead of
    buffering unboundedly.
    """

    def __init__(
        self,
        worker_id: str,
        log,
        group: ConsumerGroup,
        engine,
        response_topic: str,
        *,
        transactional: bool = False,
        max_queue: int = 64,
    ):
        self.worker_id = worker_id
        self.log = log
        self.engine = engine
        self.response_topic = response_topic
        self.max_queue = max_queue
        txn = transactional and hasattr(log, "init_producer")
        self.consumer = group.join(
            worker_id, isolation_level="read_committed" if txn else None
        )
        self.publisher = (
            TxnOutputPublisher(
                log, self.consumer, worker_id,
                transactional_id=f"{group.group_id}-{worker_id}",
            )
            if txn
            else None
        )
        self._tenants: dict[int, int] = {}  # req_id -> tenant for keyed publish
        self.served = 0
        self.alive = True

    def poll_serve(self, max_records: int = 64) -> int:
        """One tick: poll -> submit -> drain -> publish+commit. Returns
        completions published (0 also covers rejoin/recovery ticks)."""
        if not self.alive:
            return 0
        if self.worker_id not in self.consumer.group.members:
            # evicted while alive (heartbeats lapsed under load): re-enter
            # and resume from committed offsets next tick
            self.consumer.rejoin()
            return 0
        if self.engine.qsize() >= self.max_queue:
            self.consumer.pause()
        else:
            self.consumer.resume()
        try:
            polled = self.consumer.poll(max_records)
        except RebalanceError:
            self.consumer.rejoin()
            return 0
        for batch in polled:
            for buf in batch.values:
                req = decode_request(buf)
                self._tenants[req.req_id] = req.tenant
                self.engine.submit(req)
        completions = self.engine.run_until_drained()
        if not polled and not completions:
            return 0
        recs, keys = [], []
        for rid, gen in completions:
            tenant = self._tenants.pop(rid, 0)
            recs.append(encode_completion(rid, tenant, gen))
            keys.append(tenant_key(tenant))
        if self.publisher is not None:
            done = self.publisher.publish(self.response_topic, [recs], keys=[keys])
            self.served += done
            return done
        self.log.ensure_topic(self.response_topic)
        for rec, key in zip(recs, keys):
            self.log.produce(self.response_topic, rec, key=key)
        self.consumer.commit()
        self.served += len(recs)
        return len(recs)

    def kill(self) -> None:
        """Simulated crash: stops heartbeating (the group expires it)."""
        self.alive = False


class LMServingGroup:
    """N serving workers on one consumer group over the request topic —
    the LM analogue of :class:`~repro.serve.engine.InferenceDeployment`.
    Per-tenant keys partition the request topic, the group's range
    assignment load-balances partitions across workers, and a worker
    that stops heartbeating loses its partitions to the survivors."""

    def __init__(
        self,
        log,
        engines: list,
        *,
        input_topic: str,
        response_topic: str,
        group_id: str = "lm-serve",
        transactional: bool = False,
        session_timeout_s: float = 5.0,
        max_queue: int = 64,
        clock=None,
    ):
        self.log = log
        self.group = ConsumerGroup(
            log,
            group_id=group_id,
            topics=[input_topic],
            session_timeout_s=session_timeout_s,
            clock=clock,
        )
        self.workers = [
            LMServingWorker(
                f"worker-{i}", log, self.group, eng, response_topic,
                transactional=transactional, max_queue=max_queue,
            )
            for i, eng in enumerate(engines)
        ]

    def poll_all(self) -> int:
        for w in self.workers:  # live workers heartbeat, dead ones don't
            if w.alive and w.worker_id in self.group.members:
                self.group.heartbeat(w.worker_id)
        self.group.expire_dead_members()
        return sum(w.poll_serve() for w in self.workers)

    def kill_worker(self, idx: int) -> None:
        self.workers[idx].kill()

    def drain(self, max_iters: int = 100) -> int:
        total = 0
        for _ in range(max_iters):
            got = self.poll_all()
            total += got
            if got == 0:
                break
        return total


def serve_stream(
    engine: LMEngine,
    log: StreamLog,
    input_topic: str,
    output_topic: str,
    prompt_len: int,
    *,
    max_new: int = 16,
) -> int:
    """Drain an input topic of fixed-length prompts through the engine.

    Input records: int32[prompt_len] tokens. Output records:
    ``req_id int32 || generated int32[max_new]`` (padded with -1).
    """
    log.ensure_topic(output_topic)
    offset, rid = 0, 0
    end = log.end_offset(input_topic, 0)
    while offset < end:
        batch = log.read(input_topic, 0, offset, 64)
        mat = batch.to_matrix()
        toks = np.ascontiguousarray(mat).view(np.int32).reshape(len(batch), -1)
        for row in toks:
            engine.submit(Request(rid, row[:prompt_len], max_new))
            rid += 1
        offset = batch.next_offset
    served = 0
    for req_id, gen in engine.run_until_drained():
        out = np.full(max_new + 1, -1, np.int32)
        out[0] = req_id
        out[1 : 1 + len(gen)] = gen[:max_new]
        log.produce(output_topic, out.tobytes())
        served += 1
    return served
