"""Pallas TPU kernels for the model zoo's compute hot spots.

flash_attention / ssd_scan / rglru_scan, each with a pure-jnp oracle in
ref.py and a model-facing jit wrapper in ops.py. The paper itself (Kafka-ML)
has no kernel-level contribution — these serve the assigned architectures'
hot paths (DESIGN.md §2).
"""
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan_kernel
from repro.kernels.ssd_scan import ssd_scan
