"""Mamba-2 SSD chunk-scan Pallas TPU kernel.

Grid ``(B, H, n_chunks)`` with the chunk dimension innermost and
*sequential*: the (N, P) per-head state lives in VMEM scratch and is
carried across chunk steps — the inter-chunk recurrence costs no HBM
round-trip (the pure-XLA path in repro.models.ssm re-loads the carried
state from HBM every scan step).

Per chunk the kernel runs three MXU matmuls:
    scores  = (C B^T) . L          (Q x Q)
    y_intra = scores @ (dt*x)      (Q x P)
    y_inter = (C e^{cumA}) @ state (Q x P)
    state'  = e^{totA} state + B^T @ (dt*x*decay)   (N x P)

VMEM per step with (Q, N, P) = (256, 128, 64):
    x/B/C/y tiles ~256x128x2B x4 + state 128x64x4B + (Q,Q) fp32 scores
    ≈ 0.6 MB — comfortably resident; Q and N are MXU-tile multiples.

The wrapper pre-folds dt into x (elementwise, fused by XLA) and
pre-repeats grouped B/C to per-head layout. Validated against ``ref.ssd``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    xdt_ref,  # (Q, P)  x * dt
    dA_ref,  # (Q, 1)   dt * A  (log decay)
    b_ref,  # (Q, N)
    c_ref,  # (Q, N)
    st0_ref,  # (N, P)   initial state for this (b, h)
    y_ref,  # (Q, P)   out
    stout_ref,  # (N, P) out final state
    state_ref,  # VMEM scratch (N, P) f32
    *,
    n_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = st0_ref[...].astype(jnp.float32)

    xdt = xdt_ref[...].astype(jnp.float32)  # (Q, P)
    dA = dA_ref[...].astype(jnp.float32)  # (Q, 1)
    bm = b_ref[...].astype(jnp.float32)  # (Q, N)
    cm = c_ref[...].astype(jnp.float32)

    ca = jnp.cumsum(dA, axis=0)  # (Q, 1) inclusive
    total = ca[-1:, :]  # (1, 1)

    # intra-chunk: masked decayed quadratic form
    q = xdt.shape[0]
    lmat = ca - ca.reshape(1, q)  # [i, j] = sum_{j<u<=i} dA_u
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
        <= jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    )
    lmat = jnp.where(tri, jnp.exp(lmat), 0.0)
    cb = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q)
    y_intra = jax.lax.dot_general(
        cb * lmat, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # inter-chunk: contribution of the carried state
    state = state_ref[...]
    y_inter = jax.lax.dot_general(
        cm * jnp.exp(ca), state, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    y_ref[...] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update
    decay_to_end = jnp.exp(total - ca)  # (Q, 1)
    upd = jax.lax.dot_general(
        bm, xdt * decay_to_end, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (N, P)
    state_ref[...] = jnp.exp(total) * state + upd

    @pl.when(ic == n_chunks - 1)
    def _fin():
        stout_ref[...] = state_ref[...]


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret")
)
def ssd_scan(
    x: jax.Array,  # (B, H, S, P)
    dt: jax.Array,  # (B, H, S) fp32 post-softplus
    A: jax.Array,  # (H,) fp32 negative
    Bm: jax.Array,  # (B, H, S, N) per-head (groups pre-repeated)
    Cm: jax.Array,  # (B, H, S, N)
    init_state: jax.Array | None = None,  # (B, H, N, P) f32
    *,
    chunk: int = 256,
    interpret: bool = True,
):
    b, h, s, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    if init_state is None:
        init_state = jnp.zeros((b, h, n, p), jnp.float32)

    xdt = (x.astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    dA = (dt * A[None, :, None])[..., None]  # (B, H, S, 1) f32

    kernel = functools.partial(_ssd_kernel, n_chunks=nc)
    y, st = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((None, None, chunk, p), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((None, None, chunk, 1), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((None, None, chunk, n), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((None, None, chunk, n), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((None, None, n, p), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, chunk, p), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((None, None, n, p), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
        name="ssd_scan",
    )(xdt, dA, Bm, Cm, init_state)
    return y, st
