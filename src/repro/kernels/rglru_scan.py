"""RG-LRU linear-recurrence Pallas TPU kernel.

Grid ``(B, n_blocks)`` with the sequence-block dimension sequential: the
(1, C) hidden state is carried in VMEM scratch across blocks. Within a
block of T timesteps the first-order recurrence

    h_t = a_t h_{t-1} + b_t,   b_t = sqrt(1 - a_t^2) x_t

is computed with a log-depth *doubling scan* (Hillis-Steele on the (A, B)
affine composition), unrolled in Python over ceil(log2 T) steps — each
step is two shifted elementwise multiplies on the (T, C) tile, all VPU
work, no HBM traffic. The carried state enters as h = B_scan + A_scan*h0.

Block T=256, C up to 4096: tile is 256x4096x4B = 4 MB fp32 — resident in
VMEM; larger C is split by the wrapper (channels are independent).
Validated against ``ref.rglru``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(
    x_ref,  # (T, C) gated input
    loga_ref,  # (T, C) log decay
    h0_ref,  # (1, C) initial state
    h_ref,  # (T, C) out
    hlast_ref,  # (1, C) out
    carry_ref,  # VMEM scratch (1, C) f32
    *,
    t_block: int,
    n_blocks: int,
):
    ib = pl.program_id(1)

    @pl.when(ib == 0)
    def _init():
        carry_ref[...] = h0_ref[...].astype(jnp.float32)

    log_a = loga_ref[...].astype(jnp.float32)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 0.0)) * x_ref[...].astype(
        jnp.float32
    )

    # Hillis-Steele doubling scan over the affine maps (A, B):
    # identity fill is A=1 (multiplicative), B=0 (additive).
    A, B = a, b
    shift = 1
    for _ in range(int(math.ceil(math.log2(max(t_block, 2))))):
        A_prev = _shift_down(A, shift, 1.0)
        B_prev = _shift_down(B, shift, 0.0)
        B = A * B_prev + B
        A = A * A_prev
        shift *= 2
    # fold in the carried state: h_t = B_t + A_t * h_carry
    h = B + A * carry_ref[...]
    h_ref[...] = h.astype(h_ref.dtype)
    carry_ref[...] = h[-1:, :]

    @pl.when(ib == n_blocks - 1)
    def _fin():
        hlast_ref[...] = carry_ref[...]


def _shift_down(x: jax.Array, k: int, fill: float) -> jax.Array:
    """Shift rows down by k, filling the scan identity (1 for A, 0 for B)."""
    t = x.shape[0]
    if k >= t:
        return jnp.full_like(x, fill)
    pad = jnp.full((k, x.shape[1]), fill, x.dtype)
    return jnp.concatenate([pad, x[: t - k]], axis=0)


@functools.partial(jax.jit, static_argnames=("t_block", "interpret"))
def rglru_scan_kernel(
    x: jax.Array,  # (B, S, C) fp32 gated input
    log_a: jax.Array,  # (B, S, C) fp32
    h0: jax.Array | None = None,  # (B, C) f32
    *,
    t_block: int = 256,
    interpret: bool = True,
):
    b, s, c = x.shape
    t_block = min(t_block, s)
    assert s % t_block == 0, (s, t_block)
    nb = s // t_block
    if h0 is None:
        h0 = jnp.zeros((b, c), jnp.float32)

    kernel = functools.partial(_rglru_kernel, t_block=t_block, n_blocks=nb)
    h, h_last = pl.pallas_call(
        kernel,
        grid=(b, nb),
        in_specs=[
            pl.BlockSpec((None, t_block, c), lambda ib, it: (ib, it, 0)),
            pl.BlockSpec((None, t_block, c), lambda ib, it: (ib, it, 0)),
            pl.BlockSpec((1, c), lambda ib, it: (ib, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, t_block, c), lambda ib, it: (ib, it, 0)),
            pl.BlockSpec((1, c), lambda ib, it: (ib, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, c), x.dtype),
            jax.ShapeDtypeStruct((b, c), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, c), jnp.float32)],
        interpret=interpret,
        name="rglru_scan",
    )(x, log_a, h0)
    return h, h_last
