"""Flash attention Pallas TPU kernel.

Grid ``(B, H, n_q, n_k)`` with the KV dimension innermost and sequential:
each (q-block, kv-block) step keeps the classic flash running statistics
(row max ``m``, denominator ``l``, weighted accumulator ``acc``) in VMEM
scratch that persists across the sequential kv steps. Causal and
sliding-window blocks that are fully masked are *skipped* with ``pl.when``
(no MXU work issued) — the FLOP-halving XLA cannot express (DESIGN.md §4,
EXPERIMENTS.md §Perf).

Block shapes are BlockSpec-tiled to VMEM: q/o tiles are
``(block_q, head_dim)``, kv tiles ``(block_k, head_dim)`` — with the
defaults (512, 128) the working set is ~
  q 512x128x2B + k/v 2x512x128x2B + acc 512x128x4B + m/l 2x512x128x4B
  ≈ 1.2 MB of VMEM, well inside the ~16 MB/core budget, and all matmul
dims are multiples of the 128x128 MXU tile.

Supports: causal masking, sliding window, gemma-style logit softcap.
Validated against ``ref.mha`` in interpret mode (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(
    q_ref,  # (block_q, D)
    k_ref,  # (block_k, D)
    v_ref,  # (block_k, D)
    o_ref,  # (block_q, D)
    m_ref,  # VMEM scratch (block_q, 128) f32
    l_ref,  # VMEM scratch (block_q, 128) f32
    acc_ref,  # VMEM scratch (block_q, D) f32
    *,
    causal: bool,
    window: int | None,
    softcap: float | None,
    scale: float,
    block_q: int,
    block_k: int,
    n_k: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # static-shape block skip predicate (computed on scalars)
    needed = jnp.bool_(True)
    if causal:
        needed &= k_start <= q_start + block_q - 1  # some key <= some query
    if window is not None:
        needed &= k_start + block_k - 1 > q_start - window  # inside window

    @pl.when(needed)
    def _compute():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        ok = jnp.bool_(True)
        if causal:
            ok &= k_pos <= q_pos
        if window is not None:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[:, :1]  # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (bq, bk)
        corr = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_new = corr * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = l_ref[:, :1]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "block_q", "block_k", "interpret",
    ),
)
def flash_attention(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, H, S, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, h, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    n_q, n_k = s // block_q, s // block_k
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _attn_kernel,
        causal=causal,
        window=window,
        softcap=softcap,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        n_k=n_k,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((None, None, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((None, None, block_k, d), lambda ib, ih, iq, ik: (ib, ih, ik, 0)),
            pl.BlockSpec((None, None, block_k, d), lambda ib, ih, iq, ik: (ib, ih, ik, 0)),
        ],
        out_specs=pl.BlockSpec(
            (None, None, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            _vmem((block_q, 128), jnp.float32),
            _vmem((block_q, 128), jnp.float32),
            _vmem((block_q, d), jnp.float32),
        ],
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
