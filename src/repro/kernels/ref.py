"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose vs these)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def mha(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
) -> jax.Array:
    """Naive full-materialization attention."""
    b, h, s, d = q.shape
    s_ = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s_ = s_ / math.sqrt(d)
    if softcap is not None:
        s_ = softcap * jnp.tanh(s_ / softcap)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    ok = jnp.ones((s, s), bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    s_ = jnp.where(ok[None, None], s_, -1e30)
    w = jax.nn.softmax(s_, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32)).astype(q.dtype)


def ssd(
    x: jax.Array,  # (B, H, S, P)
    dt: jax.Array,  # (B, H, S) fp32 post-softplus
    A: jax.Array,  # (H,) fp32 negative
    Bm: jax.Array,  # (B, H, S, N) pre-repeated per head
    Cm: jax.Array,  # (B, H, S, N)
    init_state: jax.Array | None = None,  # (B, H, N, P)
):
    """Sequential SSD recurrence (the definitional oracle).

    S_t = exp(dt_t A) S_{t-1} + B_t (dt_t x_t)^T ;  y_t = C_t . S_t
    Returns (y (B,H,S,P), final_state (B,H,N,P)).
    """
    b, h, s, p = x.shape
    n = Bm.shape[-1]
    state = (
        jnp.zeros((b, h, n, p), jnp.float32) if init_state is None else init_state
    )

    def step(state, t):
        dA = jnp.exp(dt[:, :, t] * A[None, :])  # (B, H)
        upd = jnp.einsum(
            "bhn,bhp->bhnp",
            Bm[:, :, t].astype(jnp.float32),
            (x[:, :, t] * dt[:, :, t, None].astype(x.dtype)).astype(jnp.float32),
        )
        state = state * dA[:, :, None, None] + upd
        y = jnp.einsum("bhn,bhnp->bhp", Cm[:, :, t].astype(jnp.float32), state)
        return state, y

    final, ys = jax.lax.scan(step, state, jnp.arange(s))
    return jnp.moveaxis(ys, 0, 2).astype(x.dtype), final


def rglru(
    x: jax.Array,  # (B, S, D) fp32 gated input
    log_a: jax.Array,  # (B, S, D) fp32 log decay
    h0: jax.Array | None = None,  # (B, D)
):
    """Sequential linear recurrence h_t = a_t h_{t-1} + sqrt(1-a_t^2) x_t."""
    b, s, d = x.shape
    h = jnp.zeros((b, d), jnp.float32) if h0 is None else h0

    def step(h, t):
        a = jnp.exp(log_a[:, t])
        h = a * h + jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * x[:, t]
        return h, h

    h_last, hs = jax.lax.scan(step, h, jnp.arange(s))
    return jnp.moveaxis(hs, 0, 1), h_last
