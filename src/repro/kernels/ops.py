"""jit'd wrappers bridging model-layer calling conventions to the kernels.

The model layers use (B, S, H, D) activation layout and grouped (GQA /
SSD-group) KV; the kernels want (B, H, S, D) with per-head tensors. These
wrappers do the (XLA-fused) transposes/repeats, pick interpret mode
automatically (interpret on CPU, compiled on TPU), and are the only
entry points the rest of the codebase calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan_kernel
from repro.kernels.ssd_scan import ssd_scan

__all__ = ["attention_op", "rglru_op", "ssd_op", "default_interpret"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "block_q", "block_k")
)
def attention_op(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, Kv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
) -> jax.Array:
    b, s, h, d = q.shape
    kv = k.shape[2]
    rep = h // kv
    qt = jnp.moveaxis(q, 1, 2)  # (B, H, S, D)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    if rep != 1:
        kt = jnp.repeat(kt, rep, axis=1)
        vt = jnp.repeat(vt, rep, axis=1)
    out = flash_attention(
        qt,
        kt,
        vt,
        causal=causal,
        window=window,
        softcap=softcap,
        block_q=min(block_q, s),
        block_k=min(block_k, s),
        interpret=default_interpret(),
    )
    return jnp.moveaxis(out, 1, 2)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_op(
    x: jax.Array,  # (B, S, H, P) — model layout
    dt: jax.Array,  # (B, S, H)
    A: jax.Array,  # (H,)
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
    init_state: jax.Array | None = None,  # (B, H, N, P)
    *,
    chunk: int = 256,
):
    b, s, h, p = x.shape
    g = Bm.shape[2]
    rep = h // g
    xt = jnp.moveaxis(x, 1, 2)  # (B, H, S, P)
    dtt = jnp.moveaxis(dt, 1, 2)  # (B, H, S)
    Bt = jnp.moveaxis(Bm, 1, 2)  # (B, G, S, N)
    Ct = jnp.moveaxis(Cm, 1, 2)
    if rep != 1:
        Bt = jnp.repeat(Bt, rep, axis=1)
        Ct = jnp.repeat(Ct, rep, axis=1)
    y, st = ssd_scan(
        xt, dtt.astype(jnp.float32), A, Bt, Ct, init_state,
        chunk=chunk, interpret=default_interpret(),
    )
    return jnp.moveaxis(y, 1, 2), st


@functools.partial(jax.jit, static_argnames=("t_block",))
def rglru_op(
    x: jax.Array,  # (B, S, C) gated input (fp32)
    log_a: jax.Array,  # (B, S, C) fp32
    h0: jax.Array | None = None,
    *,
    t_block: int = 256,
):
    return rglru_scan_kernel(
        x.astype(jnp.float32),
        log_a.astype(jnp.float32),
        h0,
        t_block=min(t_block, x.shape[1]),
        interpret=default_interpret(),
    )
