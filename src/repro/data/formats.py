"""Stream data formats — RAW and AVRO (paper §III-D).

Kafka-ML ships producer/consumer libraries for two encodings and the
control message carries ``input_format`` + ``input_config`` so the training
job can decode without out-of-band coordination:

* **RAW** — "suitable for single-input data streams that may request a
  reshape, like images": each message is ``data_bytes || label_bytes`` with
  fixed dtypes/shapes given in the config.
* **AVRO** — "suitable for complex and multi-input datasets where a scheme
  specifies how the data stream is decoded": each message is a schema'd
  record of named fields. (True Avro wire-encoding is unavailable offline;
  we implement the same *contract* — a self-describing scheme in the
  control message, multi-input named fields, schema-checked decode — as a
  packed little-endian binary. DESIGN.md §2 records this substitution.)

Both codecs expose *vectorized* batch encode/decode: a RecordBatch of n
fixed-size messages decodes with one (n, record_bytes) uint8 view + per
field ``.view(dtype).reshape`` — no per-record Python loop on the hot path.

**Zero-copy framed decode** (DESIGN.md §10): the log's contiguous read
path hands out one payload memoryview per segment span
(:attr:`RecordBatch.spans`), and :meth:`_PackedCodec.decode_frames` turns
a span directly into per-field **strided ndarray views** over the segment
buffer — no per-record Python, no copy until the device transfer. The
fast path requires the aligned-stride layout (field offset and record
stride both multiples of the dtype's itemsize, measured from the span's
actual base address); an unaligned field falls back to one vectorized
column copy (the *measured* fallback — ``benchmarks/datapath.py`` records
both paths). Decoded views are read-only: the log's buffers are the
single source of truth and a consumer must not be able to rewrite
history through a borrowed view.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence

import numpy as np

from repro.core.log import RecordBatch

__all__ = [
    "AvroCodec",
    "FieldSpec",
    "RawCodec",
    "codec_from_control",
    "decode_span_fields",
]


def _dtype_size(dtype: str) -> int:
    return np.dtype(dtype).itemsize


def _shape_elems(shape: Sequence[int]) -> int:
    return int(math.prod(shape)) if shape else 1


def decode_span_fields(
    view,
    n: int,
    fields: Sequence[FieldSpec],
    offsets: Sequence[int],
    record_bytes: int,
) -> tuple[dict[str, np.ndarray], bool]:
    """Decode ``n`` fixed-layout records packed back to back in ``view``.

    The zero-copy primitive behind :meth:`_PackedCodec.decode_frames`:
    each field becomes an ``np.ndarray`` **view** over the span's buffer
    with record stride ``record_bytes`` — provided the layout is aligned
    (the field's absolute base address and the record stride are both
    multiples of the dtype's itemsize). An unaligned field takes the
    fallback: one vectorized column copy, never a per-record loop.

    Returns ``(arrays, zero_copy)`` where ``zero_copy`` is True iff every
    field took the view path. View arrays are marked read-only (they
    alias live log segment buffers).
    """
    base = np.frombuffer(view, dtype=np.uint8)
    if base.nbytes != n * record_bytes:
        raise ValueError(
            f"span holds {base.nbytes} bytes, expected {n} x {record_bytes}"
        )
    if n == 0:
        return (
            {f.name: np.zeros((0,) + f.shape, f.dtype) for f in fields},
            True,
        )
    ptr = base.__array_interface__["data"][0]
    out: dict[str, np.ndarray] = {}
    zero_copy = True
    mat = None
    for f, off in zip(fields, offsets):
        item = _dtype_size(f.dtype)
        if (ptr + off) % item == 0 and record_bytes % item == 0:
            # aligned-stride fast path: a strided view, no bytes move.
            # Within one record the field's elements are contiguous, so
            # the inner strides are plain C strides; the outer (record)
            # stride is the full record width.
            strides = (record_bytes,) + tuple(
                item * _shape_elems(f.shape[i + 1 :])
                for i in range(len(f.shape))
            )
            arr = np.ndarray(
                shape=(n,) + f.shape,
                dtype=f.dtype,
                buffer=base,
                offset=off,
                strides=strides,
            )
            if arr.flags.writeable:
                arr.flags.writeable = False
            out[f.name] = arr
        else:
            if mat is None:
                mat = base.reshape(n, record_bytes)
            chunk = np.ascontiguousarray(mat[:, off : off + f.nbytes])
            out[f.name] = chunk.view(np.dtype(f.dtype)).reshape((n,) + f.shape)
            zero_copy = False
    return out, zero_copy


@dataclass(frozen=True)
class FieldSpec:
    """One named field of a scheme: dtype + per-record shape."""

    name: str
    dtype: str
    shape: tuple[int, ...] = ()

    @property
    def nbytes(self) -> int:
        return _shape_elems(self.shape) * _dtype_size(self.dtype)

    def to_config(self) -> dict[str, Any]:
        return {"name": self.name, "dtype": self.dtype, "shape": list(self.shape)}

    @classmethod
    def from_config(cls, d: Mapping[str, Any]) -> "FieldSpec":
        return cls(d["name"], d["dtype"], tuple(d.get("shape", ())))


class _PackedCodec:
    """Shared machinery: fixed-layout packed fields, vectorized both ways."""

    def __init__(self, fields: Sequence[FieldSpec]):
        if not fields:
            raise ValueError("codec needs at least one field")
        names = [f.name for f in fields]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate field names in {names}")
        self.fields = tuple(fields)
        self._offsets: list[int] = []
        pos = 0
        for f in self.fields:
            self._offsets.append(pos)
            pos += f.nbytes
        self.record_bytes = pos

    # ---------------------------------------------------------------- encode
    def encode_batch(self, arrays: Mapping[str, np.ndarray]) -> list[bytes]:
        """Encode n records; every array is (n, *field.shape)."""
        n = None
        cols: list[np.ndarray] = []
        for f in self.fields:
            if f.name not in arrays:
                raise KeyError(f"missing field {f.name!r}")
            a = np.asarray(arrays[f.name], dtype=f.dtype)
            want = (a.shape[0],) + f.shape
            if a.shape != want:
                a = a.reshape(want)  # raises if incompatible
            if n is None:
                n = a.shape[0]
            elif a.shape[0] != n:
                raise ValueError("field batch sizes differ")
            cols.append(
                np.ascontiguousarray(a).reshape(n, -1).view(np.uint8).reshape(n, f.nbytes)
            )
        packed = np.concatenate(cols, axis=1) if len(cols) > 1 else cols[0]
        return [row.tobytes() for row in packed]

    def encode(self, record: Mapping[str, np.ndarray]) -> bytes:
        return self.encode_batch(
            {k: np.asarray(v)[None, ...] for k, v in record.items()}
        )[0]

    # ---------------------------------------------------------------- decode
    def decode_matrix(self, mat: np.ndarray) -> dict[str, np.ndarray]:
        """Decode an (n, record_bytes) uint8 matrix into named arrays."""
        if mat.ndim != 2 or mat.shape[1] != self.record_bytes:
            raise ValueError(
                f"expected (n, {self.record_bytes}) uint8 matrix, got {mat.shape}"
            )
        n = mat.shape[0]
        out: dict[str, np.ndarray] = {}
        for f, off in zip(self.fields, self._offsets):
            chunk = np.ascontiguousarray(mat[:, off : off + f.nbytes])
            out[f.name] = chunk.view(np.dtype(f.dtype)).reshape((n,) + f.shape)
        return out

    def decode_batch(self, batch: RecordBatch) -> dict[str, np.ndarray]:
        return self.decode_matrix(batch.to_matrix())

    def decode_span(
        self, view, n: int
    ) -> tuple[dict[str, np.ndarray], bool]:
        """Zero-copy decode of ``n`` records packed in one contiguous
        span; see :func:`decode_span_fields`."""
        return decode_span_fields(
            view, n, self.fields, self._offsets, self.record_bytes
        )

    def decode_frames(self, batch: RecordBatch) -> dict[str, np.ndarray]:
        """Decode a fetched batch through the zero-copy framed path.

        A single-span batch (the overwhelmingly common case: one fetch
        inside one segment) decodes into per-field strided views over the
        segment buffer — no copy at all on the aligned layout. A batch
        whose records cross a segment boundary decodes each span
        zero-copy and pays one C-level concatenate per field. A batch
        with no framing (filtered read, ragged records) falls back to the
        copying matrix path. Either way there is never per-record Python
        work.
        """
        if not batch.values:
            return {
                f.name: np.zeros((0,) + f.shape, f.dtype)
                for f in self.fields
            }
        spans = batch.framed(self.record_bytes)
        if spans is None:
            return self.decode_matrix(batch.to_matrix())
        if len(spans) == 1:
            return self.decode_span(spans[0][0], spans[0][1])[0]
        parts = [self.decode_span(mv, cnt)[0] for mv, cnt in spans]
        return {
            f.name: np.concatenate([p[f.name] for p in parts], axis=0)
            for f in self.fields
        }

    def decode(self, value: bytes | memoryview) -> dict[str, np.ndarray]:
        mat = np.frombuffer(bytes(value), dtype=np.uint8)[None, :]
        return {k: v[0] for k, v in self.decode_matrix(mat).items()}


class RawCodec(_PackedCodec):
    """RAW format: one ``data`` tensor + one ``label`` tensor per message."""

    FORMAT = "RAW"

    def __init__(
        self,
        data_dtype: str,
        data_shape: Sequence[int],
        label_dtype: str,
        label_shape: Sequence[int] = (),
    ):
        super().__init__(
            [
                FieldSpec("data", data_dtype, tuple(data_shape)),
                FieldSpec("label", label_dtype, tuple(label_shape)),
            ]
        )

    def input_config(self) -> dict[str, Any]:
        d, l = self.fields
        return {
            "data_type": d.dtype,
            "data_reshape": list(d.shape),
            "label_type": l.dtype,
            "label_reshape": list(l.shape),
        }

    @classmethod
    def from_config(cls, cfg: Mapping[str, Any]) -> "RawCodec":
        return cls(
            cfg["data_type"],
            tuple(cfg.get("data_reshape", ())),
            cfg["label_type"],
            tuple(cfg.get("label_reshape", ())),
        )


class AvroCodec(_PackedCodec):
    """AVRO format: named multi-input ``data_scheme`` + ``label_scheme``.

    Mirrors the paper's HCOPD validation example where age / smoking status
    / gender etc. are separate schema fields.
    """

    FORMAT = "AVRO"

    def __init__(self, data_scheme: Sequence[FieldSpec], label_scheme: Sequence[FieldSpec]):
        self.data_fields = tuple(data_scheme)
        self.label_fields = tuple(label_scheme)
        super().__init__(list(data_scheme) + list(label_scheme))

    def input_config(self) -> dict[str, Any]:
        return {
            "data_scheme": [f.to_config() for f in self.data_fields],
            "label_scheme": [f.to_config() for f in self.label_fields],
        }

    @classmethod
    def from_config(cls, cfg: Mapping[str, Any]) -> "AvroCodec":
        return cls(
            [FieldSpec.from_config(f) for f in cfg["data_scheme"]],
            [FieldSpec.from_config(f) for f in cfg["label_scheme"]],
        )

    def split(self, decoded: Mapping[str, np.ndarray]) -> tuple[dict, dict]:
        data = {f.name: decoded[f.name] for f in self.data_fields}
        label = {f.name: decoded[f.name] for f in self.label_fields}
        return data, label


def codec_from_control(input_format: str, input_config: Mapping[str, Any]):
    """Instantiate the codec a control message describes (paper §IV-E:
    inference auto-configures its decoder from the training control
    message)."""
    if input_format == "RAW":
        return RawCodec.from_config(input_config)
    if input_format == "AVRO":
        return AvroCodec.from_config(input_config)
    raise ValueError(f"unsupported input_format {input_format!r}")
