from repro.data.formats import AvroCodec, FieldSpec, RawCodec, codec_from_control
from repro.data.pipeline import (
    BatchIterator,
    ShardedFeeder,
    StreamDataset,
    TransactionalProcessor,
    ingest,
)
