"""Log → device data pipeline.

The glue between the distributed log and pjit'd compute:

* :func:`ingest` — the producer-side library the paper ships (§III-D): it
  encodes a dataset with a codec, appends it to data topic(s) as message
  sets, then emits the control message with the exact
  ``[topic:partition:offset:length]`` ranges.
* :class:`StreamDataset` — the consumer side of Algorithm 1: given a
  control message, read the ranges back from the log, vector-decode them,
  and split train/eval by ``validation_rate`` (the paper's take/split).
* :class:`BatchIterator` — shuffled epoch batching (host-side, numpy).
* :class:`ShardedFeeder` — places host batches on the mesh with a named
  sharding (batch axis over ``('pod','data')``) and prefetches one batch
  ahead on a background thread so host decode overlaps device compute.

The pipeline is backend-agnostic: ``log`` may be a single-broker
:class:`StreamLog` or a replicated
:class:`~repro.core.cluster.BrokerCluster`. On a cluster, ``ingest``
appends route to partition leaders (retrying transparently through leader
elections), and at ``acks='all'`` every record named by the emitted control
message is on the full ISR before the producer moves on — so the stream a
control message announces survives the loss of any single broker.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Iterator, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.control import ControlMessage, StreamRange, send_control
from repro.core.log import StreamBackend
from repro.data.formats import AvroCodec, RawCodec, codec_from_control

__all__ = ["BatchIterator", "ShardedFeeder", "StreamDataset", "ingest"]


# --------------------------------------------------------------------- ingest
def ingest(
    log: StreamBackend,
    topic: str,
    codec: RawCodec | AvroCodec,
    arrays: Mapping[str, np.ndarray],
    deployment_id: str,
    *,
    validation_rate: float = 0.0,
    partition: int | None = None,
    message_set_size: int = 1024,
    send_control_message: bool = True,
) -> ControlMessage:
    """Producer library: encode + stream a dataset, then announce it.

    Returns the control message (already sent to the control topic unless
    ``send_control_message=False``). The data lives only in the log —
    no file system (paper contribution #2).
    """
    log.ensure_topic(topic)
    encoded = codec.encode_batch(arrays)
    total = len(encoded)
    ranges: list[StreamRange] = []
    i = 0
    cur: tuple[int, int, int] | None = None  # (partition, first, last)
    while i < total:
        chunk = encoded[i : i + message_set_size]
        p, first, last = log.produce_batch(topic, chunk, partition=partition)
        if cur is not None and cur[0] == p and first == cur[2] + 1:
            cur = (p, cur[1], last)
        else:
            if cur is not None:
                ranges.append(StreamRange(topic, cur[0], cur[1], cur[2] - cur[1] + 1))
            cur = (p, first, last)
        # stick to the chosen partition for the rest of the stream so the
        # range list stays compact (Kafka sticky partitioner)
        partition = p
        i += message_set_size
    if cur is not None:
        ranges.append(StreamRange(topic, cur[0], cur[1], cur[2] - cur[1] + 1))

    msg = ControlMessage(
        deployment_id=deployment_id,
        topic=topic,
        input_format=codec.FORMAT,
        input_config=codec.input_config(),
        validation_rate=validation_rate,
        total_msg=total,
        ranges=ranges,
    )
    if send_control_message:
        send_control(log, msg)
    return msg


# -------------------------------------------------------------- StreamDataset
class StreamDataset:
    """Materialize the stream a control message points at (Algorithm 1).

    ``read()`` decodes every range; ``split()`` applies ``validation_rate``
    — the paper trains on the leading ``1 - rate`` fraction and evaluates on
    the tail.
    """

    def __init__(self, log: StreamBackend, msg: ControlMessage):
        self.log = log
        self.msg = msg
        self.codec = codec_from_control(msg.input_format, msg.input_config)

    def read(self) -> dict[str, np.ndarray]:
        mats = []
        for r in self.msg.ranges:
            for batch in self.log.iter_range(r.topic, r.partition, r.offset, r.length):
                mats.append(batch.to_matrix())
        if not mats:
            return {f.name: np.zeros((0,) + f.shape, f.dtype) for f in self.codec.fields}
        mat = np.concatenate(mats, axis=0)
        return self.codec.decode_matrix(mat)

    def split(self) -> tuple[dict[str, np.ndarray], dict[str, np.ndarray]]:
        full = self.read()
        n = self.msg.total_msg
        n_train = n - int(round(n * self.msg.validation_rate))
        train = {k: v[:n_train] for k, v in full.items()}
        evald = {k: v[n_train:] for k, v in full.items()}
        return train, evald


# -------------------------------------------------------------- BatchIterator
class BatchIterator:
    """Shuffled, epoch'd minibatches over host arrays (drop-remainder)."""

    def __init__(
        self,
        arrays: Mapping[str, np.ndarray],
        batch_size: int,
        *,
        shuffle: bool = True,
        seed: int = 0,
        epochs: int | None = None,
    ):
        sizes = {v.shape[0] for v in arrays.values()}
        if len(sizes) != 1:
            raise ValueError(f"ragged field sizes {sizes}")
        self.n = sizes.pop()
        if self.n < batch_size:
            raise ValueError(f"dataset of {self.n} records < batch_size {batch_size}")
        self.arrays = dict(arrays)
        self.batch_size = batch_size
        self.shuffle = shuffle
        self.rng = np.random.default_rng(seed)
        self.epochs = epochs

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        epoch = 0
        while self.epochs is None or epoch < self.epochs:
            idx = (
                self.rng.permutation(self.n) if self.shuffle else np.arange(self.n)
            )
            for s in range(0, self.n - self.batch_size + 1, self.batch_size):
                sel = idx[s : s + self.batch_size]
                yield {k: v[sel] for k, v in self.arrays.items()}
            epoch += 1

    def steps_per_epoch(self) -> int:
        return self.n // self.batch_size


# -------------------------------------------------------------- ShardedFeeder
class ShardedFeeder:
    """Device placement + 1-deep prefetch.

    The batch axis is sharded over the mesh's data-parallel axes so each
    device receives only its slice; host decode of batch ``i+1`` overlaps
    device compute of batch ``i``.
    """

    def __init__(
        self,
        mesh: Mesh,
        batch_axes: Sequence[str] = ("data",),
        *,
        prefetch: int = 1,
    ):
        self.mesh = mesh
        axes = [a for a in batch_axes if a in mesh.axis_names]
        self.sharding = NamedSharding(mesh, P(tuple(axes)))
        self.prefetch = prefetch

    def place(self, batch: Mapping[str, np.ndarray]) -> dict[str, jax.Array]:
        return {k: jax.device_put(v, self.sharding) for k, v in batch.items()}

    def __call__(
        self, it: Iterator[Mapping[str, np.ndarray]]
    ) -> Iterator[dict[str, jax.Array]]:
        if self.prefetch <= 0:
            for b in it:
                yield self.place(b)
            return
        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        _DONE = object()

        def _worker() -> None:
            try:
                for b in it:
                    q.put(self.place(b))
            finally:
                q.put(_DONE)

        t = threading.Thread(target=_worker, daemon=True)
        t.start()
        while True:
            item = q.get()
            if item is _DONE:
                break
            yield item
