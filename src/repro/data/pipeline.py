"""Log → device data pipeline.

The glue between the distributed log and pjit'd compute:

* :func:`ingest` — the producer-side library the paper ships (§III-D): it
  encodes a dataset with a codec, appends it to data topic(s) as message
  sets, then emits the control message with the exact
  ``[topic:partition:offset:length]`` ranges.
* :class:`StreamDataset` — the consumer side of Algorithm 1: given a
  control message, read the ranges back from the log, vector-decode them,
  and split train/eval by ``validation_rate`` (the paper's take/split).
* :class:`StreamingBatchIterator` — the paper's *train directly from the
  stream* claim made literal (DESIGN.md §10): polls the log/cluster
  consumer incrementally (``fetch_records`` per poll), zero-copy decodes
  each fetched batch via :meth:`~repro.data.formats._PackedCodec.
  decode_frames`, and yields fixed-size minibatches with bounded host
  memory — never a full-stream ``np.concatenate``. The batch sequence is
  byte-identical to ``BatchIterator(shuffle=False)`` over the
  materialized ``StreamDataset`` arrays, so checkpoint/resume
  fast-forwarding (``fast_forward``, pure offset arithmetic — no reads)
  works unchanged.
* :class:`BatchIterator` — shuffled epoch batching (host-side, numpy),
  with an optional bounded prefetch queue (``prefetch=k``) so batch
  assembly for step ``i+1..i+k`` overlaps the device step for batch ``i``.
  Also accepts a :class:`StreamingBatchIterator` and delegates, so
  callers built against the materialized API can switch to streaming
  without restructuring.
* :func:`device_feed` — double-buffered ``jax.device_put``: host poll +
  decode + H2D dispatch for batch ``i+1`` runs on a background thread
  while the caller's device step consumes batch ``i``.
* :class:`ShardedFeeder` — places host batches on the mesh with a named
  sharding (batch axis over ``('pod','data')``) and prefetches ``prefetch``
  batches ahead on a background thread so host decode overlaps device
  compute.
* :func:`prefetch_iter` — the bounded background prefetch primitive both
  of the above share (worker-thread + depth-bounded queue, exception
  propagation, clean ``close()``).

The pipeline is backend-agnostic: ``log`` may be a single-broker
:class:`StreamLog` or a replicated
:class:`~repro.core.cluster.BrokerCluster`. On a cluster, ``ingest``
appends route to partition leaders (retrying transparently through leader
elections), and at ``acks='all'`` every record named by the emitted control
message is on the full ISR before the producer moves on — so the stream a
control message announces survives the loss of any single broker.
``ingest(num_threads=k)`` streams dataset shards from ``k`` producer
threads to distinct partitions in parallel — the cluster's per-partition
locking means the appends don't contend. ``ingest(idempotent=True)``
rides per-thread idempotent producers (and an exactly-once control-message
send), so a retry after a lost ack can never duplicate a training record
(DESIGN.md §7). ``ingest(transactional=True)`` publishes the stream and
its control-message announce as ONE transaction — a read_committed
training job sees the whole stream or nothing — and
:class:`TransactionalProcessor` is the exactly-once read-process-write
stage (consume → transform → produce with input offsets committed
atomically with the output records, DESIGN.md §8).
"""

from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Iterator, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.cluster import (
    BrokerCluster,
    ClusterConsumer,
    ClusterError,
    ClusterProducer,
    InvalidTxnState,
)
from repro.core.control import ControlMessage, StreamRange, send_control
from repro.core.log import LogConfig, StreamBackend, TopicPartition
from repro.core.metrics import default_registry
from repro.data.formats import AvroCodec, RawCodec, codec_from_control

__all__ = [
    "BatchIterator",
    "PrefetchIterator",
    "ShardedFeeder",
    "ShortStreamError",
    "StreamDataset",
    "StreamingBatchIterator",
    "TransactionalProcessor",
    "device_feed",
    "ingest",
    "prefetch_iter",
]


class ShortStreamError(ValueError):
    """The stream (or split) holds fewer records than one batch.

    Raised by :class:`BatchIterator` / :class:`StreamingBatchIterator`
    when ``n < batch_size`` — with drop-remainder batching such a source
    would silently yield *zero* batches, so it fails loudly instead.
    Actionable fixes: lower ``batch_size``, ingest more records, or (for
    the eval split) lower ``validation_rate``. Subclasses ``ValueError``
    for backward compatibility with callers that caught the old untyped
    error.
    """

    def __init__(self, n: int, batch_size: int, *, split: str | None = None):
        what = f"{split} split" if split else "dataset"
        super().__init__(
            f"{what} of {n} records < batch_size {batch_size}: "
            f"drop-remainder batching would yield no batches "
            f"(lower batch_size, ingest more records"
            + (", or lower validation_rate)" if split == "eval" else ")")
        )
        self.n = n
        self.batch_size = batch_size


# ------------------------------------------------------------------ prefetch
class PrefetchIterator:
    """Bounded background prefetch over any iterator.

    A worker thread drains ``it`` into a ``depth``-bounded queue; consuming
    this iterator pops from the queue, so producing item ``i+1`` overlaps
    consuming item ``i`` (log reads / host decode overlap device steps).
    Worker exceptions re-raise at the consumer's ``next()`` — a failed
    source never silently truncates the stream. ``close()`` stops the
    worker even if it is blocked on a full queue (e.g. the consumer
    abandoned an infinite stream mid-epoch); abandoning the iterator
    without close() also stops it, via the garbage collector — the pump
    is a staticmethod sharing only the queue/event/error box, never
    ``self``, so a running worker does not pin the iterator alive.
    """

    _DONE = object()

    def __init__(self, it: Iterator[Any], depth: int = 2,
                 name: str = "prefetch"):
        self._queue: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self._stop = threading.Event()
        self._errbox: list[BaseException] = []
        self._finished = False
        self._closed = False
        # source failures re-raise at the consumer, but are also counted
        # (daemon_errors{daemon=...}) so chaos runs can assert zero
        # unexpected background errors without re-driving every stream
        errors = default_registry().counter("daemon_errors_total", daemon=name)
        self._thread = threading.Thread(
            target=self._pump,
            args=(iter(it), self._queue, self._stop, self._errbox, self._DONE,
                  errors),
            name=f"prefetch:{name}",
            daemon=True,
        )
        self._thread.start()

    @staticmethod
    def _pump(
        it: Iterator[Any],
        q: "queue.Queue",
        stop: threading.Event,
        errbox: list[BaseException],
        done: Any,
        errors: Any,
    ) -> None:
        def put(item: Any) -> bool:
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        try:
            for item in it:
                if not put(item):
                    return
        except BaseException as e:  # propagated to the consumer
            errors.inc()
            errbox.append(e)
        put(done)

    def __iter__(self) -> "PrefetchIterator":
        return self

    def __next__(self) -> Any:
        # terminal states (source exhausted, error already delivered, or
        # close()d) keep raising StopIteration instead of blocking on a
        # queue no live worker will ever feed again
        while not self._finished:
            try:
                item = self._queue.get(timeout=0.05)
            except queue.Empty:
                if self._stop.is_set():
                    self._finished = True
                elif not self._thread.is_alive() and self._queue.empty():
                    # a dead worker can't put again, so empty() is stable:
                    # anything it produced before exiting (including the
                    # _DONE sentinel carrying an error) was already drained
                    self._finished = True
            else:
                if item is not self._DONE:
                    return item
                self._finished = True
                if self._errbox:
                    raise self._errbox.pop()
        raise StopIteration

    def close(self, timeout: float = 5.0) -> None:
        """Stop the worker deterministically (idempotent): signal stop,
        unblock a worker stuck on a full queue, and join with a timeout
        — after close() returns no pump thread of this iterator is
        running (or it is reported leaked by the witness teardown)."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        self._finished = True
        while True:  # unblock a worker stuck on put()
            try:
                self._queue.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout)

    def __del__(self):  # abandoned without close(): full deterministic stop
        try:
            self.close(timeout=1.0)
        except Exception:
            pass


def prefetch_iter(it: Iterator[Any], depth: int,
                  name: str = "prefetch") -> Iterator[Any]:
    """Wrap ``it`` with a bounded background prefetch; ``depth <= 0`` is
    a no-op passthrough (fully synchronous iteration)."""
    if depth <= 0:
        return iter(it)
    return PrefetchIterator(it, depth, name=name)


# --------------------------------------------------------------------- ingest
def ingest(
    log: StreamBackend,
    topic: str,
    codec: RawCodec | AvroCodec,
    arrays: Mapping[str, np.ndarray],
    deployment_id: str,
    *,
    validation_rate: float = 0.0,
    partition: int | None = None,
    message_set_size: int = 1024,
    num_threads: int = 1,
    idempotent: bool = False,
    transactional: bool = False,
    send_control_message: bool = True,
) -> ControlMessage:
    """Producer library: encode + stream a dataset, then announce it.

    Returns the control message (already sent to the control topic unless
    ``send_control_message=False``). The data lives only in the log —
    no file system (paper contribution #2).

    ``num_threads > 1`` splits the encoded dataset into contiguous shards
    and streams them from producer threads in parallel, each to its own
    partition (``shard i -> partition i``) — on a cluster the appends
    land on distinct partition locks and don't contend. Shard ranges are
    emitted in shard order, so reading the control message back
    reconstructs the original record order (the ``validation_rate`` tail
    split is unchanged). The thread count is capped at the partition
    count, and a pinned ``partition=`` forces single-threaded streaming:
    threads sharing one partition would serialize on its lock anyway
    while interleaving their chunks, fragmenting the range list the
    control message carries.

    ``idempotent=True`` (clusters only; a bare in-process ``StreamLog``
    has no retry loop to dedup) streams through per-thread idempotent
    :class:`~repro.core.cluster.ClusterProducer` instances and sends the
    control message through one of them, so a retried append — a leader
    died after committing but before acking — cannot re-enter the
    training stream as a duplicate record, and the emitted ranges always
    name each record's single, original offset (paper §V: every retry
    duplicate is a *training-data* duplicate).

    ``transactional=True`` (clusters only) goes one further: the whole
    stream — every data record AND its control-message announce — is one
    transaction. A ``read_committed`` training job therefore observes
    either the complete stream or nothing: a crash mid-ingest aborts,
    leaving no partial stream and no dangling announce to train on.
    Transactions are single-producer, so the stream runs on one thread
    (``num_threads`` is ignored) under ``transactional.id``
    ``ingest-<deployment_id>``; re-running the ingest fences — and
    aborts — a crashed predecessor's unfinished transaction.
    """
    if transactional and not hasattr(log, "init_producer"):
        # never degrade silently: the caller asked for an all-or-nothing
        # publish a bare StreamLog cannot provide
        raise ValueError(
            "ingest(transactional=True) requires a BrokerCluster backend "
            "(transactions live in the cluster coordinator)"
        )
    log.ensure_topic(topic)
    encoded = codec.encode_batch(arrays)
    total = len(encoded)
    use_txn = transactional
    use_idem = (idempotent or use_txn) and hasattr(log, "init_producer")

    # ingest throughput metrics (no-op on backends without a registry)
    _m = getattr(log, "metrics", None)
    _instrument = _m is not None and _m.enabled
    _t0 = time.perf_counter() if _instrument else 0.0

    def _done(msg: ControlMessage) -> ControlMessage:
        if _instrument:
            dt = time.perf_counter() - _t0
            _m.counter("ingest_records_total", topic=topic).inc(total)
            _m.histogram("ingest_seconds").record(dt)
            if dt > 0:
                _m.gauge("ingest_records_per_s", topic=topic).set(total / dt)
        return msg

    def produce_span(
        span: Sequence[bytes],
        part: int | None,
        producer: "ClusterProducer | None" = None,
    ) -> tuple[list[StreamRange], "ClusterProducer | None"]:
        if producer is None and use_idem:
            producer = ClusterProducer(log, idempotent=True)
        append = producer.send_batch if producer is not None else (
            lambda t, chunk, partition: log.produce_batch(
                t, chunk, partition=partition
            )
        )
        out: list[StreamRange] = []
        cur: tuple[int, int, int] | None = None  # (partition, first, last)
        i = 0
        while i < len(span):
            chunk = span[i : i + message_set_size]
            p, first, last = append(topic, chunk, partition=part)
            if cur is not None and cur[0] == p and first == cur[2] + 1:
                cur = (p, cur[1], last)
            else:
                if cur is not None:
                    out.append(
                        StreamRange(topic, cur[0], cur[1], cur[2] - cur[1] + 1)
                    )
                cur = (p, first, last)
            # stick to the chosen partition for the rest of the span so the
            # range list stays compact (Kafka sticky partitioner)
            part = p
            i += message_set_size
        if cur is not None:
            out.append(StreamRange(topic, cur[0], cur[1], cur[2] - cur[1] + 1))
        return out, producer

    if use_txn:
        # one transaction = one producer: the data records and the
        # control-message announce commit (or abort) together
        producer = ClusterProducer(
            log, transactional_id=f"ingest-{deployment_id}"
        )
        producer.begin_txn()
        try:
            ranges, _ = produce_span(encoded, partition, producer)
            msg = ControlMessage(
                deployment_id=deployment_id,
                topic=topic,
                input_format=codec.FORMAT,
                input_config=codec.input_config(),
                validation_rate=validation_rate,
                total_msg=total,
                ranges=ranges,
            )
            if send_control_message:
                send_control(log, msg, producer=producer)
            producer.commit_txn()
        except BaseException:
            try:
                producer.abort_txn()
            except Exception:
                pass  # outcome resolves via coordinator recovery
            raise
        return _done(msg)

    num_threads = max(1, min(num_threads, total or 1))
    if partition is not None:
        num_threads = 1  # one partition serializes appends anyway
    else:
        num_threads = min(num_threads, log.num_partitions(topic))
    if num_threads == 1:
        ranges, control_producer = produce_span(encoded, partition)
    else:
        per = -(-total // num_threads)  # ceil: contiguous, balanced shards
        spans = [encoded[i : i + per] for i in range(0, total, per)]
        with ThreadPoolExecutor(
            max_workers=len(spans), thread_name_prefix="ingest"
        ) as pool:
            futs = [
                pool.submit(produce_span, span, i)
                for i, span in enumerate(spans)
            ]
            results = [f.result() for f in futs]
        # shard order == original record order (shards are contiguous)
        ranges = [r for rs, _ in results for r in rs]
        control_producer = results[0][1]

    msg = ControlMessage(
        deployment_id=deployment_id,
        topic=topic,
        input_format=codec.FORMAT,
        input_config=codec.input_config(),
        validation_rate=validation_rate,
        total_msg=total,
        ranges=ranges,
    )
    if send_control_message:
        # the announce rides the same exactly-once path as the data: a
        # duplicated control message would re-trigger training
        send_control(log, msg, producer=control_producer)
    return _done(msg)


# ------------------------------------------------- transactional transform
class TransactionalProcessor:
    """Exactly-once read-process-write: consume a topic, transform each
    record with ``fn``, produce the results — input offsets and output
    records committed in ONE transaction (Kafka Streams' exactly-once
    processing mode, DESIGN.md §8).

    Each cycle is atomic: either the transformed records land on the
    output topic AND the input offsets advance, or neither happens. A
    crash anywhere inside a cycle — including between "produce output"
    and "commit offsets", the window where a plain at-least-once
    processor duplicates (produce-first) or drops (commit-first) a step —
    aborts or completes via coordinator recovery, and the re-run resumes
    from the committed offsets with the aborted outputs invisible to
    ``read_committed`` consumers downstream.

    The input is read ``read_committed`` too, so chained processors
    compose into an end-to-end exactly-once pipeline. Zombie fencing
    comes from the transactional id: a re-created processor with the same
    id bumps the producer epoch, and the predecessor's unfinished
    transaction is aborted, its late appends fenced.
    """

    def __init__(
        self,
        cluster: BrokerCluster,
        transactional_id: str,
        input_topic: str,
        output_topic: str,
        fn,
        *,
        group_id: str | None = None,
        max_records: int = 256,
    ):
        self.cluster = cluster
        self.input_topic = input_topic
        self.output_topic = output_topic
        self.fn = fn
        self.group_id = group_id or f"txn-{transactional_id}"
        self.max_records = max_records
        # output mirrors the input's partitioning (partition p in → p out,
        # so per-partition record order is preserved through the stage)
        cluster.ensure_topic(output_topic, LogConfig(
            num_partitions=cluster.num_partitions(input_topic)
        ))
        self.producer = ClusterProducer(
            cluster, transactional_id=transactional_id
        )
        self.consumer = ClusterConsumer(
            cluster, group_id=self.group_id, isolation_level="read_committed"
        )

    def _position(self, tp: TopicPartition) -> int:
        off = self.consumer.committed(tp)
        if off is None:
            off = self.cluster.start_offset(tp.topic, tp.partition)
        return off

    def process_once(self) -> int:
        """One atomic cycle over every input partition; returns the
        number of input offsets consumed (0 = caught up — includes
        filtered control markers and aborted records, so progress never
        reads as zero while the input still advances)."""
        if self.producer.in_txn:
            # a previous cycle died with its abort unresolved (quorum
            # outage): retry the abort now; InvalidTxnState means the
            # outcome is already decided and is resolved just below
            try:
                self.producer.abort_txn()
            except InvalidTxnState:
                pass
        st = self.cluster.txn_state(self.producer.producer_id)
        if st in ("prepare_commit", "prepare_abort"):
            # a predecessor's outcome is durably decided but its offsets
            # may not be applied yet — finish it BEFORE reading committed
            # positions, or this cycle would re-fetch (and re-produce)
            # the very batch a prepared commit covers. resolve_txn runs
            # at the transaction's own recorded epoch, so this also
            # covers a RESTARTED processor whose producer epoch already
            # moved past the transaction it inherited.
            self.cluster.resolve_txn(self.producer.producer_id)
        in_txn = False
        done = 0
        offsets: dict[TopicPartition, int] = {}
        try:
            for p in range(self.cluster.num_partitions(self.input_topic)):
                tp = TopicPartition(self.input_topic, p)
                pos = self._position(tp)
                batch = self.consumer.fetch(
                    self.input_topic, p, pos, self.max_records
                )
                if len(batch) == 0 and (batch.scanned or 0) == 0:
                    continue
                if not in_txn:
                    self.producer.begin_txn()
                    in_txn = True
                if len(batch):
                    outs = [self.fn(bytes(v)) for v in batch.values]
                    self.producer.send_batch(
                        self.output_topic, outs, partition=p
                    )
                # progress is measured in *consumed* input offsets, not
                # delivered records: a window holding only an aborted
                # transaction's records (filtered out) still advances,
                # so run_to_end keeps draining past it
                done += batch.next_offset - pos
                offsets[tp] = batch.next_offset
            if in_txn:
                # one AddOffsetsToTxn for the whole cycle (one quorum
                # round-trip), not one per partition
                self.producer.send_offsets_to_txn(self.group_id, offsets)
                self.producer.commit_txn()
        except BaseException:
            if in_txn:
                try:
                    self.producer.abort_txn()
                except Exception:
                    # a prepared commit cannot be aborted (its outcome is
                    # durably decided: InvalidTxnState) and a quorum
                    # outage resolves via coordinator recovery — either
                    # way the re-run resumes from the recovered offsets
                    pass
            raise
        return done

    def run_to_end(self, max_cycles: int = 1000) -> int:
        """Drain the input: cycles until one processes nothing."""
        total = 0
        for _ in range(max_cycles):
            got = self.process_once()
            if got == 0:
                return total
            total += got
        return total


# -------------------------------------------------------------- StreamDataset
class StreamDataset:
    """Materialize the stream a control message points at (Algorithm 1).

    ``read()`` decodes every range; ``split()`` applies ``validation_rate``
    — the paper trains on the leading ``1 - rate`` fraction and evaluates on
    the tail.
    """

    def __init__(self, log: StreamBackend, msg: ControlMessage):
        self.log = log
        self.msg = msg
        self.codec = codec_from_control(msg.input_format, msg.input_config)

    def read(self) -> dict[str, np.ndarray]:
        mats = []
        for r in self.msg.ranges:
            for batch in self.log.iter_range(r.topic, r.partition, r.offset, r.length):
                mats.append(batch.to_matrix())
        if not mats:
            return {f.name: np.zeros((0,) + f.shape, f.dtype) for f in self.codec.fields}
        mat = np.concatenate(mats, axis=0)
        return self.codec.decode_matrix(mat)

    def split(self) -> tuple[dict[str, np.ndarray], dict[str, np.ndarray]]:
        full = self.read()
        n = self.msg.total_msg
        n_train = n - int(round(n * self.msg.validation_rate))
        train = {k: v[:n_train] for k, v in full.items()}
        evald = {k: v[n_train:] for k, v in full.items()}
        return train, evald

    def stream(
        self,
        batch_size: int,
        *,
        split: str = "train",
        epochs: int | None = 1,
        fetch_records: int = 4096,
        prefetch: int = 0,
    ) -> "StreamingBatchIterator":
        """Streaming (bounded-memory) counterpart of ``split()`` +
        :class:`BatchIterator`; see :class:`StreamingBatchIterator`."""
        return StreamingBatchIterator(
            self.log,
            self.msg,
            batch_size,
            split=split,
            epochs=epochs,
            fetch_records=fetch_records,
            prefetch=prefetch,
        )


def _window_ranges(
    ranges: Sequence[StreamRange], start: int, count: int
) -> list[StreamRange]:
    """Sub-ranges covering records ``[start, start + count)`` of the
    concatenated range list — the record-index → log-offset arithmetic
    behind splits and fast-forward (ranges emitted by ``ingest`` name
    data records only, so record index maps 1:1 onto raw offsets)."""
    out: list[StreamRange] = []
    pos = 0
    end = start + count
    for r in ranges:
        lo = max(start, pos)
        hi = min(end, pos + r.length)
        if lo < hi:
            out.append(
                StreamRange(r.topic, r.partition, r.offset + (lo - pos), hi - lo)
            )
        pos += r.length
    return out


# ----------------------------------------------------- StreamingBatchIterator
class StreamingBatchIterator:
    """Minibatches straight off the stream, with bounded host memory.

    The materialized path (``StreamDataset.read()`` → ``BatchIterator``)
    concatenates the *entire* stream on the host before the first record
    reaches a device. This iterator instead polls the consumer
    incrementally — ``fetch_records`` records per poll via
    ``log.iter_range`` (on a cluster that is the leader-routed,
    failover-retrying fetch path) — zero-copy decodes each fetched batch
    (:meth:`~repro.data.formats._PackedCodec.decode_frames`), and
    assembles drop-remainder batches of ``batch_size``. Peak host
    footprint is O(``fetch_records`` + ``batch_size``) records, not
    O(stream).

    **Determinism** (the checkpoint/resume contract): batches are emitted
    in range order — exactly the record order ``StreamDataset.read()``
    materializes — so the sequence is byte-identical to
    ``BatchIterator(shuffle=False)`` over the same split, epoch after
    epoch. ``fast_forward(k)`` therefore needs no reads at all: it is
    pure offset arithmetic, and resume after ``k`` steps re-polls only
    from the k-th batch's position onward.

    **Batch assembly is copy-light**: a batch that falls inside one
    fetched chunk is a pure row-slice view of the decoded (itself
    zero-copy) chunk; only a batch straddling a chunk boundary pays one
    per-field concatenate of ``batch_size`` rows. There is never a
    stream-sized concatenate.

    ``split`` selects the paper's take/split window: ``"train"`` = the
    leading ``1 - validation_rate`` fraction, ``"eval"`` = the tail,
    ``"all"`` = everything (serving replay). ``epochs=None`` streams
    forever (re-polling the log each epoch — stream reuse, paper §V).
    """

    def __init__(
        self,
        log: StreamBackend,
        msg: ControlMessage,
        batch_size: int,
        *,
        split: str = "train",
        epochs: int | None = 1,
        fetch_records: int = 4096,
        prefetch: int = 0,
    ):
        if batch_size <= 0:
            raise ValueError(f"batch_size must be positive, got {batch_size}")
        if fetch_records <= 0:
            raise ValueError(f"fetch_records must be positive, got {fetch_records}")
        total = msg.total_msg
        # same rounding as StreamDataset.split(): train = leading n_train
        n_eval = int(round(total * msg.validation_rate))
        n_train = total - n_eval
        windows = {"train": (0, n_train), "eval": (n_train, n_eval), "all": (0, total)}
        if split not in windows:
            raise ValueError(f"split must be one of {sorted(windows)}, got {split!r}")
        start, count = windows[split]
        if count < batch_size:
            raise ShortStreamError(count, batch_size, split=split)
        self.log = log
        self.msg = msg
        self.codec = codec_from_control(msg.input_format, msg.input_config)
        self.batch_size = batch_size
        self.split_name = split
        self.n = count
        self.epochs = epochs
        self.fetch_records = fetch_records
        self.prefetch = prefetch
        self._ranges = _window_ranges(msg.ranges, start, count)
        self._skip = 0
        self._prefetchers: list[PrefetchIterator] = []

    def steps_per_epoch(self) -> int:
        return self.n // self.batch_size

    def fast_forward(self, n_batches: int) -> None:
        """Skip the first ``n_batches`` of the sequence without reading
        them — pure arithmetic (checkpoint resume at step k re-polls the
        log only from batch k's record position onward). Cumulative
        across calls; applies to the next ``iter()``."""
        if n_batches < 0:
            raise ValueError(f"n_batches must be >= 0, got {n_batches}")
        self._skip += n_batches

    # ------------------------------------------------------------- internals
    def _chunks(
        self, skip_records: int, count: int
    ) -> Iterator[dict[str, np.ndarray]]:
        """Poll + decode records ``[skip_records, skip_records + count)``
        of this split's window, one bounded fetch at a time."""
        for r in _window_ranges(self._ranges, skip_records, count):
            for batch in self.log.iter_range(
                r.topic, r.partition, r.offset, r.length, chunk=self.fetch_records
            ):
                yield self.codec.decode_frames(batch)

    def _epoch(self, start_batch: int) -> Iterator[dict[str, np.ndarray]]:
        bs = self.batch_size
        usable = self.steps_per_epoch() * bs  # drop-remainder tail never read
        skip = start_batch * bs
        parts: list[dict[str, np.ndarray]] = []  # decoded, not-yet-emitted
        head = 0  # rows of parts[0] already emitted
        avail = 0  # unemitted rows buffered across parts
        for chunk in self._chunks(skip, usable - skip):
            rows = next(iter(chunk.values())).shape[0]
            if rows == 0:
                continue
            parts.append(chunk)
            avail += rows
            while avail >= bs:
                first = parts[0]
                first_rows = next(iter(first.values())).shape[0]
                if first_rows - head >= bs:
                    # common case: the batch is a pure view into one chunk
                    batch = {k: v[head : head + bs] for k, v in first.items()}
                    head += bs
                else:
                    # chunk-boundary batch: one batch_size-row concat
                    need, pieces = bs, []
                    while need:
                        cur = parts[0]
                        cur_rows = next(iter(cur.values())).shape[0]
                        take = min(need, cur_rows - head)
                        pieces.append(
                            {k: v[head : head + take] for k, v in cur.items()}
                        )
                        head += take
                        need -= take
                        if head == cur_rows:
                            parts.pop(0)
                            head = 0
                    batch = {
                        k: np.concatenate([p[k] for p in pieces], axis=0)
                        for k in pieces[0]
                    }
                if parts and head == next(iter(parts[0].values())).shape[0]:
                    parts.pop(0)
                    head = 0
                avail -= bs
                yield batch

    def _batches(self) -> Iterator[dict[str, np.ndarray]]:
        skip = self._skip
        spe = self.steps_per_epoch()
        epoch = 0
        while self.epochs is None or epoch < self.epochs:
            if skip >= spe:
                skip -= spe  # whole epoch fast-forwarded: zero reads
            else:
                yield from self._epoch(skip)
                skip = 0
            epoch += 1

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        it = prefetch_iter(self._batches(), self.prefetch, name="stream-batch")
        if isinstance(it, PrefetchIterator):
            # deterministic shutdown: close() (or GC of this iterator)
            # joins every pump thread this object spawned, so witness
            # teardown never sees leaked prefetch workers
            self._prefetchers = [p for p in self._prefetchers
                                 if p._thread.is_alive()]
            self._prefetchers.append(it)
        return it

    def close(self, timeout: float = 5.0) -> None:
        """Stop any background prefetch workers spawned by iteration."""
        prefetchers, self._prefetchers = self._prefetchers, []
        for p in prefetchers:
            p.close(timeout)

    def __del__(self):
        try:
            self.close(timeout=1.0)
        except Exception:
            pass


# -------------------------------------------------------------- BatchIterator
class BatchIterator:
    """Shuffled, epoch'd minibatches over host arrays (drop-remainder).

    ``prefetch=k`` assembles up to ``k`` batches ahead on a background
    thread (bounded queue), overlapping the gather/copy work with the
    consumer's device steps. The batch *sequence* is identical either way
    — prefetch changes when batches are built, not which or in what order
    — so checkpoint/resume fast-forwarding stays deterministic.

    A source shorter than one batch raises :class:`ShortStreamError`
    (drop-remainder batching would otherwise silently yield nothing).

    ``arrays`` may also be a :class:`StreamingBatchIterator`: iteration
    then delegates to the streaming source (which must be constructed
    with the same ``batch_size``; ``shuffle`` must be False — a stream
    is strictly sequential, and global shuffle would require exactly the
    full materialization streaming exists to avoid). The stream's own
    ``epochs``/``prefetch`` configuration governs delegated iteration.
    """

    def __init__(
        self,
        arrays: "Mapping[str, np.ndarray] | StreamingBatchIterator",
        batch_size: int,
        *,
        shuffle: bool = True,
        seed: int = 0,
        epochs: int | None = None,
        prefetch: int = 0,
    ):
        self._stream: StreamingBatchIterator | None = None
        self._prefetchers: list[PrefetchIterator] = []
        if isinstance(arrays, StreamingBatchIterator):
            if shuffle:
                raise ValueError(
                    "a streaming source is strictly sequential: pass "
                    "shuffle=False (global shuffle requires materializing "
                    "the stream — use StreamDataset.read())"
                )
            if batch_size != arrays.batch_size:
                raise ValueError(
                    f"batch_size {batch_size} != streaming source's "
                    f"{arrays.batch_size}"
                )
            self._stream = arrays
            self.n = arrays.n
            self.arrays = {}
            self.batch_size = batch_size
            self.shuffle = False
            self.rng = np.random.default_rng(seed)
            self.epochs = arrays.epochs
            self.prefetch = 0  # the stream applies its own prefetch
            return
        sizes = {v.shape[0] for v in arrays.values()}
        if len(sizes) != 1:
            raise ValueError(f"ragged field sizes {sizes}")
        self.n = sizes.pop()
        if self.n < batch_size:
            raise ShortStreamError(self.n, batch_size)
        self.arrays = dict(arrays)
        self.batch_size = batch_size
        self.shuffle = shuffle
        self.rng = np.random.default_rng(seed)
        self.epochs = epochs
        self.prefetch = prefetch

    def _epochs(self) -> Iterator[dict[str, np.ndarray]]:
        epoch = 0
        while self.epochs is None or epoch < self.epochs:
            idx = (
                self.rng.permutation(self.n) if self.shuffle else np.arange(self.n)
            )
            for s in range(0, self.n - self.batch_size + 1, self.batch_size):
                sel = idx[s : s + self.batch_size]
                yield {k: v[sel] for k, v in self.arrays.items()}
            epoch += 1

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        if self._stream is not None:
            return iter(self._stream)
        it = prefetch_iter(self._epochs(), self.prefetch, name="batch")
        if isinstance(it, PrefetchIterator):
            self._prefetchers = [p for p in self._prefetchers
                                 if p._thread.is_alive()]
            self._prefetchers.append(it)
        return it

    def close(self, timeout: float = 5.0) -> None:
        """Stop background prefetch workers (and a delegated stream's)."""
        prefetchers, self._prefetchers = self._prefetchers, []
        for p in prefetchers:
            p.close(timeout)
        if self._stream is not None:
            self._stream.close(timeout)

    def __del__(self):
        try:
            self.close(timeout=1.0)
        except Exception:
            pass

    def steps_per_epoch(self) -> int:
        return self.n // self.batch_size


# -------------------------------------------------------------- ShardedFeeder
class ShardedFeeder:
    """Device placement + bounded prefetch.

    The batch axis is sharded over the mesh's data-parallel axes so each
    device receives only its slice; host decode + device_put of batches
    ``i+1..i+prefetch`` overlap device compute of batch ``i`` (via
    :func:`prefetch_iter`, so a failing source raises at the consumer
    instead of silently ending the stream).
    """

    def __init__(
        self,
        mesh: Mesh,
        batch_axes: Sequence[str] = ("data",),
        *,
        prefetch: int = 1,
    ):
        self.mesh = mesh
        axes = [a for a in batch_axes if a in mesh.axis_names]
        self.sharding = NamedSharding(mesh, P(tuple(axes)))
        self.prefetch = prefetch

    def place(self, batch: Mapping[str, np.ndarray]) -> dict[str, jax.Array]:
        return {k: jax.device_put(v, self.sharding) for k, v in batch.items()}

    def __call__(
        self, it: Iterator[Mapping[str, np.ndarray]]
    ) -> Iterator[dict[str, jax.Array]]:
        placed = (self.place(b) for b in it)
        stream = prefetch_iter(placed, self.prefetch, name="sharded-feeder")
        try:
            yield from stream
        finally:
            close = getattr(stream, "close", None)
            if close is not None:
                close()


# ----------------------------------------------------------------- device_feed
def device_feed(
    it: Iterator[Mapping[str, np.ndarray]],
    *,
    sharding: NamedSharding | None = None,
    depth: int = 2,
) -> Iterator[dict[str, jax.Array]]:
    """Double-buffered device placement (DESIGN.md §10).

    Wraps a host-batch iterator so ``jax.device_put`` for batch ``i+1``
    (and, transitively, the consumer poll + zero-copy decode feeding it)
    is dispatched on a background thread while the caller's device step
    consumes batch ``i`` — host poll, H2D transfer, and device compute
    *pipeline* instead of serializing. ``depth=2`` is classic double
    buffering; ``depth <= 0`` degrades to the fully synchronous serial
    path (the baseline ``benchmarks/datapath.py`` measures overlap
    against). With ``sharding=None`` batches land on the default device;
    pass a :class:`~jax.sharding.NamedSharding` to split the batch axis
    across a mesh (what :class:`ShardedFeeder` does).

    The returned iterator is a :class:`PrefetchIterator` when
    ``depth > 0`` — ``close()`` it when abandoning an infinite stream
    mid-epoch.
    """

    def place(b: Mapping[str, np.ndarray]) -> dict[str, jax.Array]:
        if sharding is None:
            return {k: jax.device_put(v) for k, v in b.items()}
        return {k: jax.device_put(v, sharding) for k, v in b.items()}

    return prefetch_iter((place(b) for b in it), depth, name="device_feed")
