"""Log → device data pipeline.

The glue between the distributed log and pjit'd compute:

* :func:`ingest` — the producer-side library the paper ships (§III-D): it
  encodes a dataset with a codec, appends it to data topic(s) as message
  sets, then emits the control message with the exact
  ``[topic:partition:offset:length]`` ranges.
* :class:`StreamDataset` — the consumer side of Algorithm 1: given a
  control message, read the ranges back from the log, vector-decode them,
  and split train/eval by ``validation_rate`` (the paper's take/split).
* :class:`BatchIterator` — shuffled epoch batching (host-side, numpy),
  with an optional bounded prefetch queue (``prefetch=k``) so batch
  assembly for step ``i+1..i+k`` overlaps the device step for batch ``i``.
* :class:`ShardedFeeder` — places host batches on the mesh with a named
  sharding (batch axis over ``('pod','data')``) and prefetches ``prefetch``
  batches ahead on a background thread so host decode overlaps device
  compute.
* :func:`prefetch_iter` — the bounded background prefetch primitive both
  of the above share (worker-thread + depth-bounded queue, exception
  propagation, clean ``close()``).

The pipeline is backend-agnostic: ``log`` may be a single-broker
:class:`StreamLog` or a replicated
:class:`~repro.core.cluster.BrokerCluster`. On a cluster, ``ingest``
appends route to partition leaders (retrying transparently through leader
elections), and at ``acks='all'`` every record named by the emitted control
message is on the full ISR before the producer moves on — so the stream a
control message announces survives the loss of any single broker.
``ingest(num_threads=k)`` streams dataset shards from ``k`` producer
threads to distinct partitions in parallel — the cluster's per-partition
locking means the appends don't contend. ``ingest(idempotent=True)``
rides per-thread idempotent producers (and an exactly-once control-message
send), so a retry after a lost ack can never duplicate a training record
(DESIGN.md §7). ``ingest(transactional=True)`` publishes the stream and
its control-message announce as ONE transaction — a read_committed
training job sees the whole stream or nothing — and
:class:`TransactionalProcessor` is the exactly-once read-process-write
stage (consume → transform → produce with input offsets committed
atomically with the output records, DESIGN.md §8).
"""

from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Iterator, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.cluster import (
    BrokerCluster,
    ClusterConsumer,
    ClusterError,
    ClusterProducer,
    InvalidTxnState,
)
from repro.core.control import ControlMessage, StreamRange, send_control
from repro.core.log import LogConfig, StreamBackend, TopicPartition
from repro.data.formats import AvroCodec, RawCodec, codec_from_control

__all__ = [
    "BatchIterator",
    "PrefetchIterator",
    "ShardedFeeder",
    "StreamDataset",
    "TransactionalProcessor",
    "ingest",
    "prefetch_iter",
]


# ------------------------------------------------------------------ prefetch
class PrefetchIterator:
    """Bounded background prefetch over any iterator.

    A worker thread drains ``it`` into a ``depth``-bounded queue; consuming
    this iterator pops from the queue, so producing item ``i+1`` overlaps
    consuming item ``i`` (log reads / host decode overlap device steps).
    Worker exceptions re-raise at the consumer's ``next()`` — a failed
    source never silently truncates the stream. ``close()`` stops the
    worker even if it is blocked on a full queue (e.g. the consumer
    abandoned an infinite stream mid-epoch); abandoning the iterator
    without close() also stops it, via the garbage collector — the pump
    is a staticmethod sharing only the queue/event/error box, never
    ``self``, so a running worker does not pin the iterator alive.
    """

    _DONE = object()

    def __init__(self, it: Iterator[Any], depth: int = 2):
        self._queue: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self._stop = threading.Event()
        self._errbox: list[BaseException] = []
        self._finished = False
        self._thread = threading.Thread(
            target=self._pump,
            args=(iter(it), self._queue, self._stop, self._errbox, self._DONE),
            daemon=True,
        )
        self._thread.start()

    @staticmethod
    def _pump(
        it: Iterator[Any],
        q: "queue.Queue",
        stop: threading.Event,
        errbox: list[BaseException],
        done: Any,
    ) -> None:
        def put(item: Any) -> bool:
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        try:
            for item in it:
                if not put(item):
                    return
        except BaseException as e:  # propagated to the consumer
            errbox.append(e)
        put(done)

    def __iter__(self) -> "PrefetchIterator":
        return self

    def __next__(self) -> Any:
        # terminal states (source exhausted, error already delivered, or
        # close()d) keep raising StopIteration instead of blocking on a
        # queue no live worker will ever feed again
        while not self._finished:
            try:
                item = self._queue.get(timeout=0.05)
            except queue.Empty:
                if self._stop.is_set():
                    self._finished = True
                elif not self._thread.is_alive() and self._queue.empty():
                    # a dead worker can't put again, so empty() is stable:
                    # anything it produced before exiting (including the
                    # _DONE sentinel carrying an error) was already drained
                    self._finished = True
            else:
                if item is not self._DONE:
                    return item
                self._finished = True
                if self._errbox:
                    raise self._errbox.pop()
        raise StopIteration

    def close(self) -> None:
        """Stop the worker and release the queue (idempotent)."""
        self._stop.set()
        self._finished = True
        while True:  # unblock a worker stuck on put()
            try:
                self._queue.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5.0)

    def __del__(self):  # abandoned without close(): stop the pump
        try:
            self._stop.set()
        except Exception:
            pass


def prefetch_iter(it: Iterator[Any], depth: int) -> Iterator[Any]:
    """Wrap ``it`` with a bounded background prefetch; ``depth <= 0`` is
    a no-op passthrough (fully synchronous iteration)."""
    if depth <= 0:
        return iter(it)
    return PrefetchIterator(it, depth)


# --------------------------------------------------------------------- ingest
def ingest(
    log: StreamBackend,
    topic: str,
    codec: RawCodec | AvroCodec,
    arrays: Mapping[str, np.ndarray],
    deployment_id: str,
    *,
    validation_rate: float = 0.0,
    partition: int | None = None,
    message_set_size: int = 1024,
    num_threads: int = 1,
    idempotent: bool = False,
    transactional: bool = False,
    send_control_message: bool = True,
) -> ControlMessage:
    """Producer library: encode + stream a dataset, then announce it.

    Returns the control message (already sent to the control topic unless
    ``send_control_message=False``). The data lives only in the log —
    no file system (paper contribution #2).

    ``num_threads > 1`` splits the encoded dataset into contiguous shards
    and streams them from producer threads in parallel, each to its own
    partition (``shard i -> partition i``) — on a cluster the appends
    land on distinct partition locks and don't contend. Shard ranges are
    emitted in shard order, so reading the control message back
    reconstructs the original record order (the ``validation_rate`` tail
    split is unchanged). The thread count is capped at the partition
    count, and a pinned ``partition=`` forces single-threaded streaming:
    threads sharing one partition would serialize on its lock anyway
    while interleaving their chunks, fragmenting the range list the
    control message carries.

    ``idempotent=True`` (clusters only; a bare in-process ``StreamLog``
    has no retry loop to dedup) streams through per-thread idempotent
    :class:`~repro.core.cluster.ClusterProducer` instances and sends the
    control message through one of them, so a retried append — a leader
    died after committing but before acking — cannot re-enter the
    training stream as a duplicate record, and the emitted ranges always
    name each record's single, original offset (paper §V: every retry
    duplicate is a *training-data* duplicate).

    ``transactional=True`` (clusters only) goes one further: the whole
    stream — every data record AND its control-message announce — is one
    transaction. A ``read_committed`` training job therefore observes
    either the complete stream or nothing: a crash mid-ingest aborts,
    leaving no partial stream and no dangling announce to train on.
    Transactions are single-producer, so the stream runs on one thread
    (``num_threads`` is ignored) under ``transactional.id``
    ``ingest-<deployment_id>``; re-running the ingest fences — and
    aborts — a crashed predecessor's unfinished transaction.
    """
    if transactional and not hasattr(log, "init_producer"):
        # never degrade silently: the caller asked for an all-or-nothing
        # publish a bare StreamLog cannot provide
        raise ValueError(
            "ingest(transactional=True) requires a BrokerCluster backend "
            "(transactions live in the cluster coordinator)"
        )
    log.ensure_topic(topic)
    encoded = codec.encode_batch(arrays)
    total = len(encoded)
    use_txn = transactional
    use_idem = (idempotent or use_txn) and hasattr(log, "init_producer")

    # ingest throughput metrics (no-op on backends without a registry)
    _m = getattr(log, "metrics", None)
    _instrument = _m is not None and _m.enabled
    _t0 = time.perf_counter() if _instrument else 0.0

    def _done(msg: ControlMessage) -> ControlMessage:
        if _instrument:
            dt = time.perf_counter() - _t0
            _m.counter("ingest_records_total", topic=topic).inc(total)
            _m.histogram("ingest_seconds").record(dt)
            if dt > 0:
                _m.gauge("ingest_records_per_s", topic=topic).set(total / dt)
        return msg

    def produce_span(
        span: Sequence[bytes],
        part: int | None,
        producer: "ClusterProducer | None" = None,
    ) -> tuple[list[StreamRange], "ClusterProducer | None"]:
        if producer is None and use_idem:
            producer = ClusterProducer(log, idempotent=True)
        append = producer.send_batch if producer is not None else (
            lambda t, chunk, partition: log.produce_batch(
                t, chunk, partition=partition
            )
        )
        out: list[StreamRange] = []
        cur: tuple[int, int, int] | None = None  # (partition, first, last)
        i = 0
        while i < len(span):
            chunk = span[i : i + message_set_size]
            p, first, last = append(topic, chunk, partition=part)
            if cur is not None and cur[0] == p and first == cur[2] + 1:
                cur = (p, cur[1], last)
            else:
                if cur is not None:
                    out.append(
                        StreamRange(topic, cur[0], cur[1], cur[2] - cur[1] + 1)
                    )
                cur = (p, first, last)
            # stick to the chosen partition for the rest of the span so the
            # range list stays compact (Kafka sticky partitioner)
            part = p
            i += message_set_size
        if cur is not None:
            out.append(StreamRange(topic, cur[0], cur[1], cur[2] - cur[1] + 1))
        return out, producer

    if use_txn:
        # one transaction = one producer: the data records and the
        # control-message announce commit (or abort) together
        producer = ClusterProducer(
            log, transactional_id=f"ingest-{deployment_id}"
        )
        producer.begin_txn()
        try:
            ranges, _ = produce_span(encoded, partition, producer)
            msg = ControlMessage(
                deployment_id=deployment_id,
                topic=topic,
                input_format=codec.FORMAT,
                input_config=codec.input_config(),
                validation_rate=validation_rate,
                total_msg=total,
                ranges=ranges,
            )
            if send_control_message:
                send_control(log, msg, producer=producer)
            producer.commit_txn()
        except BaseException:
            try:
                producer.abort_txn()
            except Exception:
                pass  # outcome resolves via coordinator recovery
            raise
        return _done(msg)

    num_threads = max(1, min(num_threads, total or 1))
    if partition is not None:
        num_threads = 1  # one partition serializes appends anyway
    else:
        num_threads = min(num_threads, log.num_partitions(topic))
    if num_threads == 1:
        ranges, control_producer = produce_span(encoded, partition)
    else:
        per = -(-total // num_threads)  # ceil: contiguous, balanced shards
        spans = [encoded[i : i + per] for i in range(0, total, per)]
        with ThreadPoolExecutor(
            max_workers=len(spans), thread_name_prefix="ingest"
        ) as pool:
            futs = [
                pool.submit(produce_span, span, i)
                for i, span in enumerate(spans)
            ]
            results = [f.result() for f in futs]
        # shard order == original record order (shards are contiguous)
        ranges = [r for rs, _ in results for r in rs]
        control_producer = results[0][1]

    msg = ControlMessage(
        deployment_id=deployment_id,
        topic=topic,
        input_format=codec.FORMAT,
        input_config=codec.input_config(),
        validation_rate=validation_rate,
        total_msg=total,
        ranges=ranges,
    )
    if send_control_message:
        # the announce rides the same exactly-once path as the data: a
        # duplicated control message would re-trigger training
        send_control(log, msg, producer=control_producer)
    return _done(msg)


# ------------------------------------------------- transactional transform
class TransactionalProcessor:
    """Exactly-once read-process-write: consume a topic, transform each
    record with ``fn``, produce the results — input offsets and output
    records committed in ONE transaction (Kafka Streams' exactly-once
    processing mode, DESIGN.md §8).

    Each cycle is atomic: either the transformed records land on the
    output topic AND the input offsets advance, or neither happens. A
    crash anywhere inside a cycle — including between "produce output"
    and "commit offsets", the window where a plain at-least-once
    processor duplicates (produce-first) or drops (commit-first) a step —
    aborts or completes via coordinator recovery, and the re-run resumes
    from the committed offsets with the aborted outputs invisible to
    ``read_committed`` consumers downstream.

    The input is read ``read_committed`` too, so chained processors
    compose into an end-to-end exactly-once pipeline. Zombie fencing
    comes from the transactional id: a re-created processor with the same
    id bumps the producer epoch, and the predecessor's unfinished
    transaction is aborted, its late appends fenced.
    """

    def __init__(
        self,
        cluster: BrokerCluster,
        transactional_id: str,
        input_topic: str,
        output_topic: str,
        fn,
        *,
        group_id: str | None = None,
        max_records: int = 256,
    ):
        self.cluster = cluster
        self.input_topic = input_topic
        self.output_topic = output_topic
        self.fn = fn
        self.group_id = group_id or f"txn-{transactional_id}"
        self.max_records = max_records
        # output mirrors the input's partitioning (partition p in → p out,
        # so per-partition record order is preserved through the stage)
        cluster.ensure_topic(output_topic, LogConfig(
            num_partitions=cluster.num_partitions(input_topic)
        ))
        self.producer = ClusterProducer(
            cluster, transactional_id=transactional_id
        )
        self.consumer = ClusterConsumer(
            cluster, group_id=self.group_id, isolation_level="read_committed"
        )

    def _position(self, tp: TopicPartition) -> int:
        off = self.consumer.committed(tp)
        if off is None:
            off = self.cluster.start_offset(tp.topic, tp.partition)
        return off

    def process_once(self) -> int:
        """One atomic cycle over every input partition; returns the
        number of input offsets consumed (0 = caught up — includes
        filtered control markers and aborted records, so progress never
        reads as zero while the input still advances)."""
        if self.producer.in_txn:
            # a previous cycle died with its abort unresolved (quorum
            # outage): retry the abort now; InvalidTxnState means the
            # outcome is already decided and is resolved just below
            try:
                self.producer.abort_txn()
            except InvalidTxnState:
                pass
        st = self.cluster.txn_state(self.producer.producer_id)
        if st in ("prepare_commit", "prepare_abort"):
            # a predecessor's outcome is durably decided but its offsets
            # may not be applied yet — finish it BEFORE reading committed
            # positions, or this cycle would re-fetch (and re-produce)
            # the very batch a prepared commit covers. resolve_txn runs
            # at the transaction's own recorded epoch, so this also
            # covers a RESTARTED processor whose producer epoch already
            # moved past the transaction it inherited.
            self.cluster.resolve_txn(self.producer.producer_id)
        in_txn = False
        done = 0
        offsets: dict[TopicPartition, int] = {}
        try:
            for p in range(self.cluster.num_partitions(self.input_topic)):
                tp = TopicPartition(self.input_topic, p)
                pos = self._position(tp)
                batch = self.consumer.fetch(
                    self.input_topic, p, pos, self.max_records
                )
                if len(batch) == 0 and (batch.scanned or 0) == 0:
                    continue
                if not in_txn:
                    self.producer.begin_txn()
                    in_txn = True
                if len(batch):
                    outs = [self.fn(bytes(v)) for v in batch.values]
                    self.producer.send_batch(
                        self.output_topic, outs, partition=p
                    )
                # progress is measured in *consumed* input offsets, not
                # delivered records: a window holding only an aborted
                # transaction's records (filtered out) still advances,
                # so run_to_end keeps draining past it
                done += batch.next_offset - pos
                offsets[tp] = batch.next_offset
            if in_txn:
                # one AddOffsetsToTxn for the whole cycle (one quorum
                # round-trip), not one per partition
                self.producer.send_offsets_to_txn(self.group_id, offsets)
                self.producer.commit_txn()
        except BaseException:
            if in_txn:
                try:
                    self.producer.abort_txn()
                except Exception:
                    # a prepared commit cannot be aborted (its outcome is
                    # durably decided: InvalidTxnState) and a quorum
                    # outage resolves via coordinator recovery — either
                    # way the re-run resumes from the recovered offsets
                    pass
            raise
        return done

    def run_to_end(self, max_cycles: int = 1000) -> int:
        """Drain the input: cycles until one processes nothing."""
        total = 0
        for _ in range(max_cycles):
            got = self.process_once()
            if got == 0:
                return total
            total += got
        return total


# -------------------------------------------------------------- StreamDataset
class StreamDataset:
    """Materialize the stream a control message points at (Algorithm 1).

    ``read()`` decodes every range; ``split()`` applies ``validation_rate``
    — the paper trains on the leading ``1 - rate`` fraction and evaluates on
    the tail.
    """

    def __init__(self, log: StreamBackend, msg: ControlMessage):
        self.log = log
        self.msg = msg
        self.codec = codec_from_control(msg.input_format, msg.input_config)

    def read(self) -> dict[str, np.ndarray]:
        mats = []
        for r in self.msg.ranges:
            for batch in self.log.iter_range(r.topic, r.partition, r.offset, r.length):
                mats.append(batch.to_matrix())
        if not mats:
            return {f.name: np.zeros((0,) + f.shape, f.dtype) for f in self.codec.fields}
        mat = np.concatenate(mats, axis=0)
        return self.codec.decode_matrix(mat)

    def split(self) -> tuple[dict[str, np.ndarray], dict[str, np.ndarray]]:
        full = self.read()
        n = self.msg.total_msg
        n_train = n - int(round(n * self.msg.validation_rate))
        train = {k: v[:n_train] for k, v in full.items()}
        evald = {k: v[n_train:] for k, v in full.items()}
        return train, evald


# -------------------------------------------------------------- BatchIterator
class BatchIterator:
    """Shuffled, epoch'd minibatches over host arrays (drop-remainder).

    ``prefetch=k`` assembles up to ``k`` batches ahead on a background
    thread (bounded queue), overlapping the gather/copy work with the
    consumer's device steps. The batch *sequence* is identical either way
    — prefetch changes when batches are built, not which or in what order
    — so checkpoint/resume fast-forwarding stays deterministic.
    """

    def __init__(
        self,
        arrays: Mapping[str, np.ndarray],
        batch_size: int,
        *,
        shuffle: bool = True,
        seed: int = 0,
        epochs: int | None = None,
        prefetch: int = 0,
    ):
        sizes = {v.shape[0] for v in arrays.values()}
        if len(sizes) != 1:
            raise ValueError(f"ragged field sizes {sizes}")
        self.n = sizes.pop()
        if self.n < batch_size:
            raise ValueError(f"dataset of {self.n} records < batch_size {batch_size}")
        self.arrays = dict(arrays)
        self.batch_size = batch_size
        self.shuffle = shuffle
        self.rng = np.random.default_rng(seed)
        self.epochs = epochs
        self.prefetch = prefetch

    def _epochs(self) -> Iterator[dict[str, np.ndarray]]:
        epoch = 0
        while self.epochs is None or epoch < self.epochs:
            idx = (
                self.rng.permutation(self.n) if self.shuffle else np.arange(self.n)
            )
            for s in range(0, self.n - self.batch_size + 1, self.batch_size):
                sel = idx[s : s + self.batch_size]
                yield {k: v[sel] for k, v in self.arrays.items()}
            epoch += 1

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return prefetch_iter(self._epochs(), self.prefetch)

    def steps_per_epoch(self) -> int:
        return self.n // self.batch_size


# -------------------------------------------------------------- ShardedFeeder
class ShardedFeeder:
    """Device placement + bounded prefetch.

    The batch axis is sharded over the mesh's data-parallel axes so each
    device receives only its slice; host decode + device_put of batches
    ``i+1..i+prefetch`` overlap device compute of batch ``i`` (via
    :func:`prefetch_iter`, so a failing source raises at the consumer
    instead of silently ending the stream).
    """

    def __init__(
        self,
        mesh: Mesh,
        batch_axes: Sequence[str] = ("data",),
        *,
        prefetch: int = 1,
    ):
        self.mesh = mesh
        axes = [a for a in batch_axes if a in mesh.axis_names]
        self.sharding = NamedSharding(mesh, P(tuple(axes)))
        self.prefetch = prefetch

    def place(self, batch: Mapping[str, np.ndarray]) -> dict[str, jax.Array]:
        return {k: jax.device_put(v, self.sharding) for k, v in batch.items()}

    def __call__(
        self, it: Iterator[Mapping[str, np.ndarray]]
    ) -> Iterator[dict[str, jax.Array]]:
        placed = (self.place(b) for b in it)
        stream = prefetch_iter(placed, self.prefetch)
        try:
            yield from stream
        finally:
            close = getattr(stream, "close", None)
            if close is not None:
                close()
