"""Optimizers: AdamW (fp32 state) and AdamW8bit (blockwise-quantized state).

AdamW8bit stores the first/second moments as int8 codes with one fp32
scale per 256-element block of the trailing dim (dynamic blockwise
quantization, bnb-style). For arctic-480b this turns 3.84 TB of fp32
moments into ~0.97 TB — the difference between fitting and not fitting a
(16,16) v5e pod (DESIGN.md §4, 15 GB vs ~7.6 GB per device).

Interface is optax-like but pytree-explicit so optimizer-state
PartitionSpecs can mirror the param specs exactly:

    opt = adamw(lr=...) | adamw8bit(lr=...)
    state = opt.init(params)
    new_params, new_state = opt.update(grads, state, params)
    state_specs = opt.state_pspecs(param_pspecs)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["Optimizer", "adamw", "adamw8bit", "clip_by_global_norm", "cosine_schedule"]

_QBLOCK = 256


# --------------------------------------------------------------- lr schedules
def cosine_schedule(
    peak_lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1
) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)

    return lr


def clip_by_global_norm(grads, max_norm: float):
    g2 = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(g2)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


# ------------------------------------------------------------ 8-bit moments
def _pad_to_block(n: int) -> int:
    return -(-n // _QBLOCK) * _QBLOCK


def _pad_last(x: jax.Array, npad: int) -> jax.Array:
    n = x.shape[-1]
    if npad == n:
        return x
    cfg = [(0, 0)] * (x.ndim - 1) + [(0, npad - n)]
    return jnp.pad(x, cfg)


def _quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (fp32, any shape) -> (int8 codes same shape, fp32 scales blocked
    over a padded trailing dim).

    Blocks split ONLY the trailing dim — leading dims keep their identity
    so SPMD sharding propagates through (a flatten-to-2D here forces XLA
    to replicate the whole moment tensor: +5.5 TB/dev measured on
    arctic-480b, EXPERIMENTS.md §Perf it-5).
    """
    shape = x.shape
    n = shape[-1] if shape else 1
    npad = _pad_to_block(n)
    blocks = _pad_last(x, npad).reshape(shape[:-1] + (npad // _QBLOCK, _QBLOCK))
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    safe = jnp.where(scale == 0, 1.0, scale)
    codes = jnp.clip(jnp.round(blocks / safe), -127, 127).astype(jnp.int8)
    codes = codes.reshape(shape[:-1] + (npad,))[..., :n]
    return codes, scale[..., 0]


def _dequantize(codes: jax.Array, scales: jax.Array) -> jax.Array:
    shape = codes.shape
    n = shape[-1] if shape else 1
    npad = _pad_to_block(n)
    blocks = _pad_last(codes.astype(jnp.float32), npad).reshape(
        shape[:-1] + (npad // _QBLOCK, _QBLOCK)
    )
    out = blocks * scales[..., None]
    return out.reshape(shape[:-1] + (npad,))[..., :n]


_V_FLOOR = 1e-16  # offset so v=0 is representable in log space


def _quantize_log(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Non-negative x -> int8 codes on a per-block log2 grid.

    Linear absmax quantization destroys Adam's second moment (entries far
    below the block max collapse to 0 and the update explodes through
    1/sqrt(v)); a log grid keeps *relative* error uniform across ~38 orders
    of magnitude. Scales carry (log_min, log_step) per block.
    """
    shape = x.shape
    n = shape[-1] if shape else 1
    npad = _pad_to_block(n)
    blocks = jnp.log2(
        _pad_last(x, npad).reshape(shape[:-1] + (npad // _QBLOCK, _QBLOCK)) + _V_FLOOR
    )
    lo = jnp.min(blocks, axis=-1, keepdims=True)
    hi = jnp.max(blocks, axis=-1, keepdims=True)
    step = jnp.maximum((hi - lo) / 254.0, 1e-8)
    codes = jnp.clip(jnp.round((blocks - lo) / step) - 127, -127, 127).astype(jnp.int8)
    codes = codes.reshape(shape[:-1] + (npad,))[..., :n]
    scales = jnp.concatenate([lo, step], axis=-1)  # (..., nblk, 2)
    return codes, scales


def _dequantize_log(codes: jax.Array, scales: jax.Array) -> jax.Array:
    shape = codes.shape
    n = shape[-1] if shape else 1
    npad = _pad_to_block(n)
    blocks = _pad_last(codes.astype(jnp.float32), npad).reshape(
        shape[:-1] + (npad // _QBLOCK, _QBLOCK)
    )
    lo, step = scales[..., :1], scales[..., 1:]
    out = jnp.exp2(lo + (blocks + 127.0) * step) - _V_FLOOR
    out = jnp.maximum(out, 0.0)
    return out.reshape(shape[:-1] + (npad,))[..., :n]


def _scale_spec(spec: P) -> P:
    """Scales: same spec with the trailing dim unsharded (tiny arrays)."""
    if len(spec) == 0:
        return P()
    return P(*spec[:-1], None)


# ----------------------------------------------------------------- optimizer
@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]
    state_pspecs: Callable[[Any], Any]


def adamw(
    lr: float | Callable = 1e-3,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    max_grad_norm: float | None = 1.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.float32(lr))

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "step": jnp.int32(0),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(grads, state, params):
        if max_grad_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        step = state["step"] + 1
        lr_t = lr_fn(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"step": step, "m": new_m, "v": new_v}

    def state_pspecs(param_pspecs):
        return {
            "step": P(),
            "m": param_pspecs,
            "v": param_pspecs,
        }

    return Optimizer(init, update, state_pspecs)


def adamw8bit(
    lr: float | Callable = 1e-3,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    max_grad_norm: float | None = 1.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.float32(lr))

    def _zero_m(p):
        c, s = _quantize(jnp.zeros(p.shape, jnp.float32))
        return {"codes": c, "scales": s}

    def _zero_v(p):
        c, s = _quantize_log(jnp.zeros(p.shape, jnp.float32))
        return {"codes": c, "scales": s}

    def init(params):
        return {
            "step": jnp.int32(0),
            "m": jax.tree.map(_zero_m, params),
            "v": jax.tree.map(_zero_v, params),
        }

    def update(grads, state, params):
        if max_grad_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        step = state["step"] + 1
        lr_t = lr_fn(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        is_q = lambda x: isinstance(x, dict) and "codes" in x

        def upd(p, g, mq, vq):
            g = g.astype(jnp.float32)
            m = _dequantize(mq["codes"], mq["scales"])
            v = _dequantize_log(vq["codes"], vq["scales"])
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps) + weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)
            mc, ms = _quantize(m)
            vc, vs = _quantize_log(v)
            return newp, {"codes": mc, "scales": ms}, {"codes": vc, "scales": vs}

        out = _tree_map4(upd, params, grads, state["m"], state["v"], is_q)
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"step": step, "m": new_m, "v": new_v}

    def state_pspecs(param_pspecs):
        def mspec(spec):  # scales: (..., nblk)
            return {"codes": spec, "scales": _scale_spec(spec)}

        def vspec(spec):  # scales: (..., nblk, 2)
            base = _scale_spec(spec)
            return {"codes": spec, "scales": P(*base, None)}

        is_p = lambda x: isinstance(x, P)
        return {
            "step": P(),
            "m": jax.tree.map(mspec, param_pspecs, is_leaf=is_p),
            "v": jax.tree.map(vspec, param_pspecs, is_leaf=is_p),
        }

    return Optimizer(init, update, state_pspecs)


def _tree_map4(f, params, grads, ms, vs, is_q):
    """tree.map over params treedef, with m/v leaves being {codes, scales}."""
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(ms)
    flat_v = treedef.flatten_up_to(vs)
    return treedef.unflatten(
        [f(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    )
