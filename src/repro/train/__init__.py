from repro.train import checkpoint
from repro.train.compression import compressed_psum_mean, int8_decode, int8_encode
from repro.train.optimizer import Optimizer, adamw, adamw8bit, cosine_schedule
from repro.train.trainer import TrainingJob, build_train_step, dp_train_step, make_state
