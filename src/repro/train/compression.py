"""Gradient compression for the data-parallel all-reduce.

Scheme (DESIGN.md §4): two-phase compressed all-reduce under shard_map —

  1. ``psum_scatter`` the bf16 gradients over the DP axis (bandwidth:
     1x size in bf16 — already half of an fp32 ring all-reduce's reduce
     phase);
  2. blockwise int8-quantize the reduced shard and ``all_gather`` codes +
     fp32 block scales (bandwidth: ~0.25x fp32).

Net wire bytes vs fp32 all-reduce: (2 + 1.06)/8 ≈ 0.38x. Lossy only in
phase 2 (each replica sees identically quantized values, so replicas stay
bit-identical — no divergence). Used by the manual-DP trainer
(``repro.train.trainer.dp_train_step``); the pjit/SPMD path keeps XLA's
fused bf16 all-reduce (EXPERIMENTS.md discusses the trade).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compressed_psum_mean", "int8_encode", "int8_decode"]

_BLOCK = 256


def int8_encode(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Flatten -> pad -> per-block absmax int8. Returns (codes, scales)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    npad = -(-n // _BLOCK) * _BLOCK
    if npad != n:
        flat = jnp.pad(flat, (0, npad - n))
    blocks = flat.reshape(-1, _BLOCK).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    safe = jnp.where(scale == 0, 1.0, scale)
    codes = jnp.clip(jnp.round(blocks / safe), -127, 127).astype(jnp.int8)
    return codes, scale[:, 0]


def int8_decode(codes: jax.Array, scales: jax.Array, shape, dtype) -> jax.Array:
    out = codes.astype(jnp.float32) * scales[:, None]
    n = 1
    for d in shape:
        n *= d
    return out.reshape(-1)[:n].reshape(shape).astype(dtype)


def compressed_psum_mean(grads, axis: str):
    """Mean-all-reduce a gradient pytree over ``axis`` (inside shard_map).

    reduce-scatter in bf16, int8-quantize the owned shard, all-gather codes.
    Leaves too small to scatter evenly fall back to plain psum.
    """
    n = jax.lax.axis_size(axis)

    def one(g):
        flat = g.reshape(-1).astype(jnp.bfloat16)
        if flat.shape[0] % (n * _BLOCK) != 0:
            return (jax.lax.psum(g.astype(jnp.float32), axis) / n).astype(g.dtype)
        shard = jax.lax.psum_scatter(flat, axis, scatter_dimension=0, tiled=True)
        shard = (shard.astype(jnp.float32) / n).astype(jnp.float32)
        codes, scales = int8_encode(shard)
        codes_g = jax.lax.all_gather(codes, axis, axis=0, tiled=True)
        scales_g = jax.lax.all_gather(scales, axis, axis=0, tiled=True)
        return int8_decode(codes_g, scales_g, g.shape, g.dtype)

    return jax.tree.map(one, grads)
