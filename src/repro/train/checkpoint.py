"""Checkpoint/restart — fault tolerance for training jobs.

The Kafka-ML angle (paper §II, §V): the *data* needs no checkpointing — it
lives in the distributed log and is re-readable by offset. What must be
checkpointed is (a) the model/optimizer state and (b) the **stream
offsets** consumed so far. A restarted job restores the latest checkpoint
and resumes reading the log at the saved offsets: exactly-once training
semantics on top of the log's at-least-once delivery.

Properties:
* atomic: write to a tmp dir, fsync, rename — a crash mid-save never
  corrupts the latest checkpoint;
* async: the host copy + write happens on a background thread so the
  device stays busy (device->host transfer is the only sync part);
* retention: keep the newest ``keep`` checkpoints;
* **elastic**: arrays are stored mesh-independent (dense host numpy) and
  re-sharded at load onto whatever mesh/policy the restarted job uses —
  restart on 256 chips from a 512-chip checkpoint re-shards transparently.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Mapping

import jax
import numpy as np

__all__ = ["CheckpointManager", "latest_step", "restore", "save"]

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_key_str(k) for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name in ("bfloat16", "float8_e4m3fn", "float8_e5m2"):
            # np.savez cannot round-trip ml_dtypes; store as fp32 (lossless
            # for bf16/fp8) — restore() casts back to the template dtype
            arr = np.asarray(jax.numpy.asarray(leaf).astype(jax.numpy.float32))
        out[key] = arr
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"[{k.idx}]"
    return str(k)


def save(
    ckpt_dir: str,
    step: int,
    state: Any,
    *,
    offsets: Mapping[str, int] | None = None,
    meta: Mapping[str, Any] | None = None,
) -> str:
    """Synchronous atomic save. Returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "offsets": dict(offsets or {}),
        "meta": dict(meta or {}),
        "treedef": None,  # restored against a template tree
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for d in os.listdir(ckpt_dir)
        if (m := _STEP_RE.match(d)) and os.path.isdir(os.path.join(ckpt_dir, d))
    ]
    return max(steps) if steps else None


def restore(
    ckpt_dir: str,
    template: Any,
    step: int | None = None,
    *,
    shardings: Any = None,
) -> tuple[Any, dict[str, int], dict[str, Any]]:
    """Restore (state, offsets, meta).

    ``template`` provides the pytree structure (e.g. from eval_shape);
    ``shardings`` (same treedef, optional) re-shards each leaf onto the
    *current* mesh — the elastic-restart path.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    z = np.load(os.path.join(path, "arrays.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    shard_flat = (
        jax.tree.leaves(shardings, is_leaf=lambda x: hasattr(x, "spec"))
        if shardings is not None
        else [None] * len(flat)
    )
    for (pathk, leaf), sh in zip(flat, shard_flat):
        key = "/".join(_key_str(k) for k in pathk)
        arr = z[key]
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        if arr.dtype != want_dtype:
            # cast via jnp: numpy lacks direct casts to ml_dtypes (bf16, fp8)
            arr = np.asarray(jax.numpy.asarray(arr).astype(want_dtype))
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr))
    state = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves
    )
    return state, dict(manifest.get("offsets", {})), dict(manifest.get("meta", {}))


class CheckpointManager:
    """Async checkpointing with retention.

    ``save_async`` snapshots device arrays to host (sync) then writes on a
    daemon thread; ``wait`` joins the in-flight write (used before exit and
    in tests).
    """

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def save_async(self, step: int, state: Any, *, offsets=None, meta=None) -> None:
        self.wait()
        host_state = jax.tree.map(np.asarray, state)  # device->host now

        def _write():
            save(self.ckpt_dir, step, host_state, offsets=offsets, meta=meta)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1))
            for d in os.listdir(self.ckpt_dir)
            if (m := _STEP_RE.match(d))
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s}"), ignore_errors=True)

    def latest(self) -> int | None:
        return latest_step(self.ckpt_dir)
