"""Training jobs — the paper's Algorithm 1 on a JAX mesh.

Two layers:

* :func:`build_train_step` — the pjit'd SPMD step for the model zoo:
  in/out shardings derived from model + optimizer pspecs, optional
  microbatch gradient accumulation, donated state.
* :class:`TrainingJob` — the Kafka-ML training Job (paper §IV-C): fetch
  model spec from the registry, block on the control topic for its
  deployment_id, read the stream (train/eval split per validation_rate),
  train, upload trained artifact + metrics back to the registry.
  Checkpoints embed the stream offsets; ``resume=True`` restarts exactly
  where a killed job died (fault tolerance, paper §II/§V).

Plus :func:`dp_train_step` — a manual-DP (shard_map) step with int8
compressed gradient all-reduce for the pure data-parallel regime.

Jobs accept any :class:`~repro.core.log.StreamBackend`: against a
replicated :class:`~repro.core.cluster.BrokerCluster` the control topic and
the stream ranges a job reads both survive broker loss, so a stream
ingested at ``acks='all'`` remains trainable — and replayable to new
deployments (§V) — after any single broker dies. With
``ingest(idempotent=True)`` the stream a job trains on is additionally
**exactly-once**: client retries during ingest can neither duplicate a
training record nor re-announce the stream (a duplicated control message
would re-trigger training), and ``wait_for_control`` rides out
mid-election windows instead of dying on them — see DESIGN.md §7.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Iterator, Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.control import ControlMessage, poll_control
from repro.core.controller import ClusterError
from repro.core.log import StreamBackend
from repro.core.registry import Registry
from repro.data.pipeline import (
    BatchIterator,
    ShardedFeeder,
    StreamDataset,
    StreamingBatchIterator,
    device_feed,
)
from repro.models.model import StreamModel
from repro.models.policy import Policy
from repro.train import checkpoint as ckpt_lib
from repro.train.compression import compressed_psum_mean
from repro.train.optimizer import Optimizer, adamw

__all__ = ["TrainingJob", "build_train_step", "dp_train_step", "make_state"]


# ------------------------------------------------------------- SPMD pjit step
def make_state(model: StreamModel, opt: Optimizer, rng) -> dict:
    params = model.init(rng)
    return {"params": params, "opt": opt.init(params)}


def state_pspecs(model: StreamModel, opt: Optimizer) -> dict:
    pspecs = model.param_pspecs()
    return {"params": pspecs, "opt": opt.state_pspecs(pspecs)}


def _to_microbatches(x: jax.Array, k: int, dp: int) -> jax.Array:
    """(B, ...) -> (k, B/k, ...) such that every microbatch spans every
    data shard.

    A plain reshape would turn the (contiguously) batch-sharded dim into a
    sharded *microbatch* dim — each accumulation step would then live on
    1/dp of the devices. Instead split per-shard rows across microbatches:
    shard d's rows [d*B/dp, ...) are dealt round-robin to the k steps, so
    each (B/k)-row microbatch keeps the full P(batch_axes) sharding.
    (This permutes which rows share a microbatch; rows are i.i.d. samples.)
    """
    b = x.shape[0]
    bl = b // (dp * k)
    y = x.reshape((dp, k, bl) + x.shape[1:])
    y = jnp.moveaxis(y, 1, 0)  # (k, dp, bl, ...)
    return y.reshape((k, dp * bl) + x.shape[1:])


def build_train_step(
    model: StreamModel,
    opt: Optimizer,
    *,
    microbatches: int = 1,
    donate: bool = True,
    mesh: Mesh | None = None,
):
    """Returns (step_fn, state_shardings). step_fn(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def step(state, batch):
        b0 = jax.tree.leaves(batch)[0].shape[0]
        dp = model.policy.dp_degree
        k = min(microbatches, max(b0 // max(dp, 1), 1))  # each microbatch must cover DP
        if k > 1:

            def micro(acc, mb):
                (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state["params"], mb
                )
                acc_g, acc_loss = acc
                acc_g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / k, acc_g, g
                )
                return (acc_g, acc_loss + loss / k), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"]
            )
            mbs = jax.tree.map(lambda x: _to_microbatches(x, k, dp), batch)
            (grads, loss), _ = jax.lax.scan(
                micro, (zeros, jnp.float32(0.0)), mbs,
                unroll=True if model.policy.unroll else 1,
            )
            metrics = {"loss": loss}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"], batch
            )
        new_params, new_opt = opt.update(grads, state["opt"], state["params"])
        return {"params": new_params, "opt": new_opt}, {
            **metrics,
            "loss": metrics["loss"],
        }

    if mesh is None:
        return jax.jit(step, donate_argnums=(0,) if donate else ()), None

    specs = state_pspecs(model, opt)
    shardings = jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), specs, is_leaf=lambda x: isinstance(x, P)
    )
    batch_sharding = NamedSharding(mesh, P(model.policy.batch_axes))
    fn = jax.jit(
        step,
        in_shardings=(shardings, batch_sharding),
        out_shardings=(shardings, None),
        donate_argnums=(0,) if donate else (),
    )
    return fn, shardings


# --------------------------------------------------------- manual-DP variant
def dp_train_step(
    loss_fn: Callable,
    opt: Optimizer,
    mesh: Mesh,
    axis: str = "data",
    compress: bool = True,
):
    """Pure data parallelism with explicit (optionally int8-compressed)
    gradient all-reduce — params replicated, batch sharded over ``axis``."""
    from jax.experimental.shard_map import shard_map

    def local_step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p, b: loss_fn(p, b), has_aux=True
        )(state["params"], batch)
        if compress:
            grads = compressed_psum_mean(grads, axis)
        else:
            n = jax.lax.axis_size(axis)
            grads = jax.tree.map(
                lambda g: (jax.lax.psum(g.astype(jnp.float32), axis) / n).astype(g.dtype),
                grads,
            )
        loss = jax.lax.pmean(loss, axis)
        new_params, new_opt = opt.update(grads, state["opt"], state["params"])
        return {"params": new_params, "opt": new_opt}, {"loss": loss}

    rep = P()
    state_specs = None  # replicated everywhere

    def wrapped(state, batch):
        return shard_map(
            local_step,
            mesh=mesh,
            in_specs=(jax.tree.map(lambda _: rep, state), jax.tree.map(lambda _: P(axis), batch)),
            out_specs=(jax.tree.map(lambda _: rep, state), {"loss": rep}),
            check_rep=False,
        )(state, batch)

    return jax.jit(wrapped, donate_argnums=(0,))


# ------------------------------------------------------------- Training Job
@dataclasses.dataclass
class TrainResult:
    metrics: dict[str, float]
    eval_metrics: dict[str, float]
    steps: int
    control: ControlMessage


class TrainingJob:
    """Paper §IV-C Algorithm 1, with checkpoint/restart fault tolerance.

    One Job trains one model of a deployed configuration. ``run`` blocks
    on the control topic until a control message targets this deployment,
    then trains over the referenced stream ranges.
    """

    def __init__(
        self,
        log: StreamBackend,
        registry: Registry,
        deployment_id: str,
        model_id: str,
        *,
        loss_fn: Callable,  # (params, batch) -> (loss, metrics)
        init_fn: Callable,  # rng -> params
        opt: Optimizer | None = None,
        ckpt_dir: str | None = None,
        ckpt_every: int = 50,
        seed: int = 0,
        isolation_level: str | None = None,
    ):
        self.log = log
        self.registry = registry
        self.deployment_id = deployment_id
        self.model_id = model_id
        self.loss_fn = loss_fn
        self.init_fn = init_fn
        self.opt = opt or adamw(1e-3)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.seed = seed
        # "read_committed" pairs with ingest(transactional=True): the job
        # only ever acts on a control message whose whole stream is
        # durably committed — a crashed (aborted) ingest announces nothing
        self.isolation_level = isolation_level
        self.manager = (
            ckpt_lib.CheckpointManager(ckpt_dir) if ckpt_dir is not None else None
        )

    # ---------------------------------------------------------------- control
    def wait_for_control(self, poll_interval: float = 0.0, max_polls: int = 1000):
        """Algorithm 1's readControlStreams loop.

        On a cluster, the control topic can be momentarily unreadable
        mid-election (leaderless partition, no controller quorum); that
        counts as an empty poll and the loop retries — the same
        skip-and-retry contract the consumer-group read path uses — so a
        waiting training job survives a broker or controller failover
        instead of dying before its stream is even announced.
        """
        offset = 0
        for _ in range(max_polls):
            try:
                msg, offset = poll_control(
                    self.log, self.deployment_id, offset,
                    isolation=self.isolation_level,
                )
            except ClusterError:
                msg = None  # control topic unavailable mid-election
            if msg is not None:
                return msg
            if poll_interval:
                time.sleep(poll_interval)
        raise TimeoutError(
            f"no control message for deployment {self.deployment_id!r}"
        )

    # ------------------------------------------------------------------- run
    def run(
        self,
        *,
        batch_size: int,
        epochs: int = 1,
        resume: bool = False,
        max_steps: int | None = None,
        prefetch: int = 2,  # batches assembled ahead of the device step
        streaming: bool = False,
        fetch_records: int = 4096,
        crash_after: int | None = None,  # fault-injection hook for tests
    ) -> TrainResult:
        """Train over the announced stream.

        ``streaming=False`` (default) materializes the stream on the host
        (``StreamDataset.split()``) and trains with a seeded global
        shuffle. ``streaming=True`` is the broker→device path of
        DESIGN.md §10: a :class:`StreamingBatchIterator` polls the
        consumer ``fetch_records`` records at a time, zero-copy decodes,
        and :func:`device_feed` double-buffers ``jax.device_put`` so the
        next poll+decode+transfer overlaps the running device step —
        peak host memory is O(fetch_records), not O(stream), and resume
        fast-forwards by offset arithmetic instead of replaying batches.
        Streaming trains in stream order (no global shuffle — that would
        require exactly the materialization streaming avoids); both modes
        yield a deterministic batch sequence, so checkpoints resume
        exactly either way.
        """
        msg = self.wait_for_control()

        params = self.init_fn(jax.random.PRNGKey(self.seed))
        state = {"params": params, "opt": self.opt.init(params)}
        start_step = 0
        if resume and self.manager is not None and self.manager.latest() is not None:
            state, offsets, meta = ckpt_lib.restore(self.ckpt_dir, state)
            start_step = int(meta.get("next_step", 0))

        @jax.jit
        def step_fn(state, batch):
            (loss, metrics), grads = jax.value_and_grad(self.loss_fn, has_aux=True)(
                state["params"], batch
            )
            new_params, new_opt = self.opt.update(grads, state["opt"], state["params"])
            return {"params": new_params, "opt": new_opt}, metrics

        eval_arrays: dict[str, np.ndarray] | None = None
        if streaming:
            it = StreamingBatchIterator(
                self.log, msg, batch_size, split="train", epochs=None,
                fetch_records=fetch_records,
            )
            # resume = offset arithmetic: no records are fetched, decoded,
            # or transferred for the fast-forwarded prefix
            it.fast_forward(start_step)
        else:
            ds = StreamDataset(self.log, msg)
            train_arrays, eval_arrays = ds.split()
            it = BatchIterator(
                train_arrays, batch_size, seed=self.seed, epochs=None,
                shuffle=True, prefetch=prefetch,
            )
        steps_per_epoch = it.steps_per_epoch()
        total = max_steps if max_steps is not None else epochs * steps_per_epoch

        metrics = {}
        # training throughput metrics (no-op on backends with no registry)
        reg = getattr(self.log, "metrics", None)
        instrument = reg is not None and reg.enabled
        # batch assembly overlaps the device step (prefetch is a bounded
        # background queue over the same deterministic batch sequence);
        # streaming additionally overlaps the device_put dispatch
        if streaming:
            stream = device_feed(iter(it), depth=prefetch)
        else:
            stream = iter(it)
        try:
            if not streaming:
                # deterministic resume: fast-forward the shuffled stream
                for _ in range(start_step):
                    next(stream)
            for step_i in range(start_step, total):
                t0 = time.perf_counter() if instrument else 0.0
                nxt = next(stream)
                batch = (
                    nxt if streaming
                    else {k: jnp.asarray(v) for k, v in nxt.items()}
                )
                state, m = step_fn(state, batch)
                metrics = {k: float(v) for k, v in m.items()}
                if instrument:
                    dt = time.perf_counter() - t0
                    reg.histogram(
                        "train_step_seconds", deployment=self.deployment_id
                    ).record(dt)
                    reg.counter(
                        "train_records_total", deployment=self.deployment_id
                    ).inc(batch_size)
                    if dt > 0:
                        reg.gauge(
                            "train_records_per_s",
                            deployment=self.deployment_id,
                        ).set(batch_size / dt)
                done = step_i + 1
                if self.manager is not None and done % self.ckpt_every == 0:
                    self.manager.save_async(
                        done,
                        state,
                        offsets={str(r): r.end for r in msg.ranges},
                        meta={"next_step": done, "deployment_id": self.deployment_id},
                    )
                if crash_after is not None and done >= crash_after:
                    self.manager and self.manager.wait()
                    raise RuntimeError(f"injected crash after step {done}")
        finally:
            # the epochs=None stream is infinite: stop its prefetch worker
            close = getattr(stream, "close", None)
            if close is not None:
                close()
        if self.manager is not None:
            self.manager.save_async(
                total, state, offsets={str(r): r.end for r in msg.ranges},
                meta={"next_step": total, "deployment_id": self.deployment_id},
            )
            self.manager.wait()

        eval_metrics = {}
        n_eval = int(round(msg.total_msg * msg.validation_rate))
        if streaming:
            if msg.validation_rate > 0 and n_eval > 0:
                # bounded-memory eval: stream the tail split in batches and
                # average the metric means (equal-size batches, so the
                # average of means is exact up to the dropped remainder)
                acc: dict[str, float] = {}
                seen = 0
                ev = StreamingBatchIterator(
                    self.log, msg, min(batch_size, n_eval), split="eval",
                    epochs=1, fetch_records=fetch_records,
                )
                for eb in device_feed(iter(ev), depth=prefetch):
                    _, em = self.loss_fn(state["params"], eb)
                    for k, v in em.items():
                        acc[k] = acc.get(k, 0.0) + float(v)
                    seen += 1
                eval_metrics = {k: v / seen for k, v in acc.items()}
        elif msg.validation_rate > 0 and next(iter(eval_arrays.values())).shape[0] > 0:
            eb = {k: jnp.asarray(v) for k, v in eval_arrays.items()}
            _, em = self.loss_fn(state["params"], eb)
            eval_metrics = {k: float(v) for k, v in em.items()}

        artifact = None
        if self.ckpt_dir is not None:
            artifact = self.ckpt_dir
        self.registry.upload_result(
            self.deployment_id,
            self.model_id,
            metrics,
            eval_metrics,
            input_format=msg.input_format,
            input_config=msg.input_config,
            artifact_path=artifact,
        )
        self._final_state = state
        return TrainResult(metrics, eval_metrics, total, msg)
