"""Static lock-hierarchy analyzer (DESIGN.md §12, layer 1).

An AST pass over a source tree (normally ``src/repro``) that:

* discovers lock objects — ``threading.Lock/RLock/Condition`` attribute
  assignments and :func:`repro.analysis.witness.make_lock/make_rlock`
  calls — and resolves each to a lock *class* in the declared rank table
  (:mod:`repro.analysis.ranks`); a lock that resolves to nothing is
  itself a finding, so the table cannot silently rot;
* builds the may-acquire-while-holding graph from ``with``-block
  nesting plus intra-module call edges (``self.method()`` and local
  function calls, closed transitively) and checks every edge against
  the rank table: acquiring a strictly lower rank while holding a
  higher one, or acquiring anything while holding a leaf, is a finding;
* flags raw ``.acquire()`` calls with no same-receiver ``.release()``
  in a ``finally`` block;
* flags blocking calls (``time.sleep``, ``Thread.join``, ``Event.wait``,
  ``controller.submit``, network-ish I/O) made while statically holding
  a metadata or partition lock;
* flags silent broad ``except: pass`` handlers inside daemon loops.

Findings carry stable ids (``kind:path:qualname:detail`` — no line
numbers, so the allowlist survives unrelated edits). Intentional
findings live in :mod:`repro.analysis.lockcheck_allowlist`, every entry
with a one-line justification; entries that match nothing in a scanned
tree they apply to are *stale* and fail the gate, so the allowlist can
only shrink unless a justified entry is added alongside new code.

CI gate::

    python -m repro.analysis.lockcheck src/repro

exits 0 on a clean (or fully justified) tree, 1 on findings, 2 on a
malformed allowlist.
"""

from __future__ import annotations

import argparse
import ast
import fnmatch
import json
import os
import sys
from dataclasses import dataclass, field

from repro.analysis.ranks import LEAF, RANKS, ALLOWED_EDGES, classify_attr

# lock classes whose statically-held sections must not make blocking calls
_NO_BLOCK_UNDER = frozenset({"metadata", "partition"})

_LOCK_CTORS = frozenset({"Lock", "RLock", "Condition"})
_WITNESS_CTORS = frozenset({"make_lock", "make_rlock"})


@dataclass
class Finding:
    kind: str
    path: str  # posix relpath from the scan root
    qualname: str
    detail: str
    lineno: int
    message: str

    @property
    def id(self) -> str:
        return f"{self.kind}:{self.path}:{self.qualname}:{self.detail}"


@dataclass
class _FuncInfo:
    qualname: str
    class_name: str | None
    node: ast.AST
    acquires: set[str] = field(default_factory=set)  # direct lock classes
    blocking: set[str] = field(default_factory=set)  # direct blocking descs
    calls: set[str] = field(default_factory=set)  # local callee qualnames
    # transitive closures (filled by _close)
    may_acquire: set[str] = field(default_factory=set)
    may_block: set[str] = field(default_factory=set)


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted repr of an expression (for receivers)."""
    try:
        return ast.unparse(node)
    except Exception:  # pragma: no cover - unparse is total on exprs
        return "<expr>"


def _recv_attr(expr: ast.AST) -> tuple[str | None, str | None]:
    """(receiver repr, attribute) of an Attribute/Subscript-ish expr."""
    if isinstance(expr, ast.Subscript):
        expr = expr.value
    if isinstance(expr, ast.Attribute):
        return _dotted(expr.value), expr.attr
    return None, None


class _ModuleScan:
    """One module's lock surface: functions, lock sites, findings."""

    def __init__(self, relpath: str, tree: ast.Module):
        self.relpath = relpath
        self.basename = os.path.basename(relpath)
        self.tree = tree
        self.funcs: dict[str, _FuncInfo] = {}
        self.findings: list[Finding] = []
        self.parents: dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                self.parents[child] = parent

    # ---------------------------------------------------------- resolution
    def _classify(self, cls: str | None, expr: ast.AST,
                  aliases: dict[str, str]) -> str | None:
        """Lock class of a with/acquire receiver expression, or None."""
        if isinstance(expr, ast.Name):
            return aliases.get(expr.id)
        recv, attr = _recv_attr(expr)
        if attr is None:
            # with self._txn_locks.setdefault(...) / dict.get(...) forms
            if isinstance(expr, ast.Call):
                recv, attr = _recv_attr(expr.func)
                if attr in ("setdefault", "get") and recv is not None:
                    _, lock_attr = _recv_attr(expr.func.value)
                    if lock_attr is not None:
                        return classify_attr(
                            self.basename, cls if recv and
                            recv.startswith("self.") else None, lock_attr)
            return None
        use_cls = cls if recv == "self" else None
        return classify_attr(self.basename, use_cls, attr)

    def _lock_aliases(self, fn: ast.AST, cls: str | None) -> dict[str, str]:
        """name -> lock class, for locals assigned from known lock attrs
        (``lock = self._txn_locks.setdefault(pid, ...)``)."""
        out: dict[str, str] = {}
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                continue
            got = self._classify(cls, node.value, {})
            if got is None and isinstance(node.value, ast.Attribute):
                got = self._classify(cls, node.value, {})
            if got is not None:
                out[node.targets[0].id] = got
        return out

    # ---------------------------------------------------------- discovery
    def collect(self) -> None:
        self._collect_funcs(self.tree, prefix="", class_name=None)
        self._collect_lock_ctors()

    def _collect_funcs(self, node: ast.AST, prefix: str,
                       class_name: str | None) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                self._collect_funcs(child, f"{prefix}{child.name}.",
                                    class_name=child.name)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                self.funcs[q] = _FuncInfo(q, class_name, child)
                # nested defs are separate analysis units (callbacks run
                # with an empty held stack), resolvable by local name
                self._collect_funcs(child, f"{q}.", class_name=class_name)

    def _collect_lock_ctors(self) -> None:
        """Every lock construction must resolve to a ranked class."""
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            is_threading = (isinstance(f, ast.Attribute)
                            and isinstance(f.value, ast.Name)
                            and f.value.id == "threading"
                            and f.attr in _LOCK_CTORS)
            is_witness = ((isinstance(f, ast.Name) and f.id in _WITNESS_CTORS)
                          or (isinstance(f, ast.Attribute)
                              and f.attr in _WITNESS_CTORS))
            if is_witness:
                q = self._enclosing_qualname(node)
                if node.args and isinstance(node.args[0], ast.Constant) \
                        and isinstance(node.args[0].value, str):
                    if node.args[0].value not in RANKS:
                        self._add("unknown-lock", q,
                                  f"class({node.args[0].value})", node.lineno,
                                  f"lock class {node.args[0].value!r} is not "
                                  f"in the rank table (repro.analysis.ranks)")
                else:
                    self._add("unknown-lock", q, "class(dynamic)", node.lineno,
                              "make_lock/make_rlock must take a literal "
                              "lock-class string")
                continue
            if not is_threading:
                continue
            attr = self._ctor_target_attr(node)
            q = self._enclosing_qualname(node)
            cls = self._enclosing_class(node)
            if attr is None or classify_attr(self.basename, cls, attr) is None:
                self._add(
                    "unknown-lock", q, f"attr({attr or '?'})", node.lineno,
                    f"threading.{f.attr}() at {self.relpath}:{node.lineno} "
                    f"does not resolve to a class in the rank table — add a "
                    f"SITE_TABLE entry or construct it via witness.make_lock")

    def _ctor_target_attr(self, call: ast.Call) -> str | None:
        node: ast.AST = call
        while node in self.parents:
            parent = self.parents[node]
            if isinstance(parent, ast.Assign):
                for t in parent.targets:
                    _, attr = _recv_attr(t)
                    if attr is not None:
                        return attr
                    if isinstance(t, ast.Name):
                        return t.id
                return None
            if isinstance(parent, ast.Call):
                recv, attr = _recv_attr(parent.func)
                if attr in ("setdefault", "get") and recv is not None:
                    _, lock_attr = _recv_attr(parent.func.value)
                    return lock_attr
            if isinstance(parent, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.ClassDef, ast.Module)):
                return None
            node = parent
        return None

    def _enclosing_qualname(self, node: ast.AST) -> str:
        names: list[str] = []
        while node in self.parents:
            node = self.parents[node]
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                names.append(node.name)
        return ".".join(reversed(names)) or "<module>"

    def _enclosing_class(self, node: ast.AST) -> str | None:
        while node in self.parents:
            node = self.parents[node]
            if isinstance(node, ast.ClassDef):
                return node.name
        return None

    # ----------------------------------------------------------- summaries
    def summarize(self) -> None:
        for info in self.funcs.values():
            aliases = self._lock_aliases(info.node, info.class_name)
            for node in self._own_nodes(info.node):
                if isinstance(node, (ast.With, ast.AsyncWith)):
                    for item in node.items:
                        got = self._classify(info.class_name,
                                             item.context_expr, aliases)
                        if got is not None:
                            info.acquires.add(got)
                elif isinstance(node, ast.Call):
                    desc = self._blocking_desc(node)
                    if desc is not None:
                        info.blocking.add(desc)
                    callee = self._resolve_call(node, info)
                    if callee is not None:
                        info.calls.add(callee)

    def _own_nodes(self, fn: ast.AST):
        """Walk a function body without descending into nested defs."""
        stack = list(ast.iter_child_nodes(fn))
        while stack:
            node = stack.pop()
            yield node
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                continue
            stack.extend(ast.iter_child_nodes(node))

    def _resolve_call(self, call: ast.Call, info: _FuncInfo) -> str | None:
        f = call.func
        if isinstance(f, ast.Name):
            # nested def in this function, else a module-level function
            nested = f"{info.qualname}.{f.id}"
            if nested in self.funcs:
                return nested
            if f.id in self.funcs:
                return f.id
            return None
        if (isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name)
                and f.value.id == "self" and info.class_name is not None):
            q = f"{info.class_name}.{f.attr}"
            return q if q in self.funcs else None
        return None

    def _blocking_desc(self, call: ast.Call) -> str | None:
        f = call.func
        if not isinstance(f, ast.Attribute):
            return None
        recv = _dotted(f.value)
        low = recv.lower()
        if f.attr == "sleep" and recv == "time":
            return "time.sleep"
        if f.attr == "submit" and "controller" in low:
            return "controller.submit"
        if f.attr == "join" and "thread" in low:
            return "thread.join"
        if f.attr == "wait" and ("stop" in low or "event" in low):
            return "event.wait"
        if f.attr in ("result", "shutdown") and ("pool" in low or "fut" in low
                                                or "executor" in low):
            return f"executor.{f.attr}"
        root = recv.split(".", 1)[0].split("(", 1)[0]
        if root in ("socket", "requests", "urllib", "http", "subprocess"):
            return f"{root}.{f.attr}"
        return None

    # ------------------------------------------------------------- closure
    def _close(self) -> None:
        for info in self.funcs.values():
            info.may_acquire = set(info.acquires)
            info.may_block = set(info.blocking)
        changed = True
        while changed:
            changed = False
            for info in self.funcs.values():
                for callee in info.calls:
                    c = self.funcs[callee]
                    if not c.may_acquire <= info.may_acquire:
                        info.may_acquire |= c.may_acquire
                        changed = True
                    if not c.may_block <= info.may_block:
                        info.may_block |= c.may_block
                        changed = True

    # -------------------------------------------------------------- checks
    def check(self) -> None:
        self.summarize()
        self._close()
        for info in self.funcs.values():
            aliases = self._lock_aliases(info.node, info.class_name)
            self._walk_held(info, list(ast.iter_child_nodes(info.node)),
                            held=[], aliases=aliases)
            self._check_acquire_release(info)
            self._check_silent_except(info)

    def _walk_held(self, info: _FuncInfo, nodes: list[ast.AST],
                   held: list[str], aliases: dict[str, str]) -> None:
        for node in nodes:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                continue
            if isinstance(node, (ast.With, ast.AsyncWith)):
                got: list[str] = []
                for item in node.items:
                    c = self._classify(info.class_name, item.context_expr,
                                       aliases)
                    if c is None:
                        _, attr = _recv_attr(item.context_expr)
                        if attr is not None and "lock" in attr.lower():
                            self._add("unknown-lock", info.qualname,
                                      f"with({attr})", node.lineno,
                                      f"`with {_dotted(item.context_expr)}:` "
                                      f"does not resolve to a ranked lock")
                        continue
                    self._check_order(info, held, c, node.lineno, via=None)
                    got.append(c)
                self._walk_held(info, list(ast.iter_child_nodes(node)),
                                held + got, aliases)
                continue
            if isinstance(node, ast.Call):
                desc = self._blocking_desc(node)
                if desc is not None:
                    self._check_blocking(info, held, desc, node.lineno,
                                         via=None)
                callee = self._resolve_call(node, info)
                if callee is not None and held:
                    c = self.funcs[callee]
                    for cls in sorted(c.may_acquire):
                        self._check_order(info, held, cls, node.lineno,
                                          via=callee)
                    for desc in sorted(c.may_block):
                        self._check_blocking(info, held, desc, node.lineno,
                                             via=callee)
            self._walk_held(info, list(ast.iter_child_nodes(node)), held,
                            aliases)

    def _check_order(self, info: _FuncInfo, held: list[str], cls: str,
                     lineno: int, via: str | None) -> None:
        for h in held:
            if (h, cls) in ALLOWED_EDGES:
                continue
            suffix = f" (via {via})" if via else ""
            if h in LEAF:
                self._add("lock-order", info.qualname,
                          f"leaf({h})->{cls}", lineno,
                          f"acquires {cls!r} while holding leaf lock "
                          f"{h!r}{suffix}")
            elif h != cls and RANKS[cls] < RANKS[h]:
                self._add("lock-order", info.qualname, f"{h}->{cls}", lineno,
                          f"acquires {cls!r} (rank {RANKS[cls]}) while "
                          f"holding {h!r} (rank {RANKS[h]}){suffix} — "
                          f"inverts the declared hierarchy")

    def _check_blocking(self, info: _FuncInfo, held: list[str], desc: str,
                        lineno: int, via: str | None) -> None:
        bad = [h for h in held if h in _NO_BLOCK_UNDER]
        if not bad:
            return
        suffix = f" (via {via})" if via else ""
        self._add("blocking-under-lock", info.qualname,
                  f"{bad[-1]}->{desc}", lineno,
                  f"blocking call {desc} while holding {bad[-1]!r} "
                  f"lock{suffix}")

    def _check_acquire_release(self, info: _FuncInfo) -> None:
        acquires: dict[str, int] = {}
        released_in_finally: set[str] = set()
        for node in self._own_nodes(info.node):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)):
                continue
            recv = _dotted(node.func.value)
            locky = "lock" in recv.lower() or "lock" in node.func.attr.lower()
            if node.func.attr == "acquire" and locky:
                acquires[recv] = node.lineno
            elif node.func.attr == "release" and locky:
                if self._in_finally(node):
                    released_in_finally.add(recv)
        for recv, lineno in acquires.items():
            if recv not in released_in_finally:
                self._add(
                    "unbalanced-acquire", info.qualname,
                    f"acquire({recv})", lineno,
                    f"raw {recv}.acquire() with no {recv}.release() in a "
                    f"finally block — an exception leaks the lock (use "
                    f"`with`)")

    def _in_finally(self, node: ast.AST) -> bool:
        child = node
        while child in self.parents:
            parent = self.parents[child]
            if isinstance(parent, (ast.Try, getattr(ast, "TryStar", ast.Try))):
                if any(child is n or self._contains(n, child)
                       for n in parent.finalbody):
                    return True
            if isinstance(parent, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return False
            child = parent
        return False

    @staticmethod
    def _contains(tree: ast.AST, node: ast.AST) -> bool:
        return any(n is node for n in ast.walk(tree))

    def _check_silent_except(self, info: _FuncInfo) -> None:
        for node in self._own_nodes(info.node):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if not (len(node.body) == 1 and isinstance(node.body[0], ast.Pass)):
                continue
            if not self._is_broad(node.type):
                continue
            if not self._in_while(node, info.node):
                continue
            name = _dotted(node.type) if node.type is not None else "bare"
            self._add("silent-except", info.qualname,
                      f"except({name})", node.lineno,
                      f"silent `except {name}: pass` inside a daemon loop — "
                      f"count it (daemon_errors metric) or narrow it")

    @staticmethod
    def _is_broad(t: ast.AST | None) -> bool:
        if t is None:
            return True
        names = []
        for n in ([t.elts] if isinstance(t, ast.Tuple) else [[t]])[0]:
            if isinstance(n, ast.Name):
                names.append(n.id)
        return any(n in ("Exception", "BaseException") for n in names)

    def _in_while(self, node: ast.AST, fn: ast.AST) -> bool:
        child = node
        while child in self.parents:
            parent = self.parents[child]
            if isinstance(parent, ast.While):
                return True
            if parent is fn:
                return False
            child = parent
        return False

    def _add(self, kind: str, qualname: str, detail: str, lineno: int,
             message: str) -> None:
        f = Finding(kind, self.relpath, qualname, detail, lineno, message)
        if all(f.id != g.id for g in self.findings):
            self.findings.append(f)


# ----------------------------------------------------------------- driver
def scan_paths(paths: list[str]) -> tuple[list[Finding], list[str]]:
    """Analyze every .py file under ``paths``. Returns (findings,
    scanned relpaths). The analysis package itself is exempt (the
    witness legitimately builds raw locks)."""
    files: list[tuple[str, str]] = []  # (abspath, relpath)
    for path in paths:
        path = os.path.abspath(path)
        if os.path.isfile(path):
            files.append((path, os.path.basename(path)))
            continue
        for dirpath, dirnames, filenames in os.walk(path):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                full = os.path.join(dirpath, fn)
                rel = os.path.relpath(full, path).replace(os.sep, "/")
                files.append((full, rel))
    findings: list[Finding] = []
    scanned: list[str] = []
    for full, rel in files:
        if "analysis/" in rel.replace(os.sep, "/") or \
                os.path.basename(os.path.dirname(full)) == "analysis":
            continue
        scanned.append(rel)
        with open(full, "r", encoding="utf-8") as fh:
            tree = ast.parse(fh.read(), filename=full)
        scan = _ModuleScan(rel, tree)
        scan.collect()
        scan.check()
        findings.extend(scan.findings)
    return findings, scanned


def apply_allowlist(
    findings: list[Finding],
    allowlist: list[tuple[str, str]],
    scanned: list[str],
) -> tuple[list[Finding], list[Finding], list[str], list[str]]:
    """Split findings into (reported, suppressed); also return stale
    entry patterns (matched nothing although their file glob applies to
    a scanned path) and malformed entries (empty justification)."""
    malformed = [p for p, j in allowlist if not (j or "").strip()]
    suppressed: list[Finding] = []
    reported: list[Finding] = []
    hit: set[str] = set()
    for f in findings:
        pat = next((p for p, _ in allowlist if fnmatch.fnmatch(f.id, p)), None)
        if pat is not None:
            hit.add(pat)
            suppressed.append(f)
        else:
            reported.append(f)
    stale = []
    for p, _ in allowlist:
        if p in hit:
            continue
        parts = p.split(":")
        fglob = parts[1] if len(parts) > 1 else "*"
        if any(fnmatch.fnmatch(rel, fglob) for rel in scanned):
            stale.append(p)
    return reported, suppressed, stale, malformed


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis.lockcheck",
        description="static lock-hierarchy analyzer (DESIGN.md §12)")
    ap.add_argument("paths", nargs="+", help="files or directories to scan")
    ap.add_argument("--no-allowlist", action="store_true",
                    help="ignore the checked-in allowlist (fixture runs)")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="also write the full report as JSON to this path")
    args = ap.parse_args(argv)

    findings, scanned = scan_paths(args.paths)
    if args.no_allowlist:
        allowlist: list[tuple[str, str]] = []
    else:
        from repro.analysis.lockcheck_allowlist import ALLOWLIST
        allowlist = list(ALLOWLIST)
    reported, suppressed, stale, malformed = apply_allowlist(
        findings, allowlist, scanned)

    if malformed:
        for p in malformed:
            print(f"MALFORMED allowlist entry (empty justification): {p}")
        return 2

    for f in sorted(reported, key=lambda f: f.id):
        print(f"{f.path}:{f.lineno}: [{f.kind}] {f.message}")
        print(f"    id: {f.id}")
    for p in stale:
        print(f"STALE allowlist entry (matches nothing): {p}")

    if args.json_out:
        with open(args.json_out, "w", encoding="utf-8") as fh:
            json.dump({
                "reported": [vars(f) | {"id": f.id} for f in reported],
                "suppressed": [vars(f) | {"id": f.id} for f in suppressed],
                "stale": stale,
            }, fh, indent=2, sort_keys=True)

    n_files = len(scanned)
    print(f"lockcheck: {n_files} files, {len(findings)} findings "
          f"({len(suppressed)} allowlisted, {len(reported)} reported, "
          f"{len(stale)} stale allowlist entries)")
    return 1 if (reported or stale) else 0


if __name__ == "__main__":
    sys.exit(main())
