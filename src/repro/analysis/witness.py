"""Runtime lock-order witness (DESIGN.md §12, layer 2).

A lockdep-style drop-in wrapper around ``threading.Lock``/``RLock``:
every lock carries a *class* from the declared rank table
(:mod:`repro.analysis.ranks`), each thread keeps its held-stack in
``threading.local``, and every acquire is checked against the ranks of
the locks already held — strictly increasing order, reentrancy on the
same object allowed, leaf classes terminal, sanctioned inversions from
``ALLOWED_EDGES`` suppressed. Independently of the per-acquire check,
the witness accumulates the *observed* acquisition-order graph (class →
class edges, including sanctioned ones) so cycle detection at teardown
reports potential deadlocks that never manifested in the interleavings
a run happened to see.

Construction sites call :func:`make_lock` / :func:`make_rlock`. With
``REPRO_LOCK_WITNESS`` unset (the default) these return plain
``threading`` primitives — zero steady-state overhead, decided once at
import. With ``REPRO_LOCK_WITNESS=1`` they return witnessed locks in
*record* mode: violations are recorded (not raised) and a session-scoped
conftest fixture fails the run if any were seen, so one bad
interleaving cannot crash mid-test and mask the report. With
``REPRO_LOCK_WITNESS=strict`` a violation raises
:class:`LockOrderViolation` at the acquire site (before blocking on the
inner lock).

``REPRO_LOCK_GRAPH=<path>`` makes the conftest fixture dump the full
observed graph + report as JSON (the nightly CI artifact).
"""

from __future__ import annotations

import json
import os
import threading
import time

from repro.analysis.ranks import ALLOWED_EDGES, LEAF, RANKS

_MODE = os.environ.get("REPRO_LOCK_WITNESS", "")
ENABLED = _MODE not in ("", "0")
STRICT = _MODE == "strict"

# report only the first N distinct violations / long holds — a broken
# hierarchy hits the same site millions of times in a tight loop
_MAX_RECORDS = 200
# runtime analog of the static sleep-under-lock check: warn (never
# fail) when a lock is held longer than this many seconds
_HOLD_WARN_S = float(os.environ.get("REPRO_LOCK_HOLD_WARN_S", "1.0"))


class LockOrderViolation(RuntimeError):
    """Raised at the acquire site in strict mode."""


class _Held:
    __slots__ = ("lock", "cls", "rank", "name", "reentrant", "t0")

    def __init__(self, lock, cls, rank, name, reentrant, t0):
        self.lock = lock
        self.cls = cls
        self.rank = rank
        self.name = name
        self.reentrant = reentrant
        self.t0 = t0


class Witness:
    """One observation domain: rank assertions + observed-order graph.

    Tests build private instances; production wiring uses the module
    :func:`global_witness` so every lock in the process shares one
    graph.
    """

    def __init__(self, *, strict: bool = STRICT,
                 ranks: dict[str, int] | None = None,
                 leaf: frozenset[str] | None = None,
                 allowed: dict | None = None,
                 hold_warn_s: float = _HOLD_WARN_S):
        self.strict = strict
        self.ranks = dict(RANKS if ranks is None else ranks)
        self.leaf = frozenset(LEAF if leaf is None else leaf)
        self.allowed = dict(ALLOWED_EDGES if allowed is None else allowed)
        self.hold_warn_s = hold_warn_s
        self._mu = threading.Lock()  # guards the shared tallies below
        self._tls = threading.local()
        self.violations: list[dict] = []
        self._vkeys: set[tuple] = set()
        self.edges: dict[tuple[str, str], int] = {}
        self.long_holds: list[dict] = []
        self._held_by_thread: dict[int, list[str]] = {}

    # ------------------------------------------------------------ wiring
    def lock(self, lock_class: str, name: str | None = None) -> "_WitnessLock":
        return _WitnessLock(self, threading.Lock(), lock_class, name)

    def rlock(self, lock_class: str, name: str | None = None) -> "_WitnessLock":
        return _WitnessLock(self, threading.RLock(), lock_class, name)

    def _stack(self) -> list[_Held]:
        try:
            return self._tls.stack
        except AttributeError:
            s: list[_Held] = []
            self._tls.stack = s
            return s

    # ----------------------------------------------------------- checks
    def _on_acquire(self, wlock: "_WitnessLock") -> bool:
        """Rank checks + edge recording BEFORE blocking on the inner
        lock (so strict mode reports instead of deadlocking). Returns
        True if this is a reentrant acquire."""
        stack = self._stack()
        if any(h.lock is wlock for h in stack):
            return True
        cls, rank = wlock.lock_class, wlock.rank
        new_edges = []
        worst = None
        for h in stack:
            if (h.cls, cls) not in self.allowed:
                if h.cls in self.leaf:
                    worst = ("leaf-held", h)
                elif h.cls != cls and rank < h.rank:
                    worst = worst or ("order", h)
                elif h.cls == cls and h.lock is not wlock:
                    # two distinct locks of the same class nested —
                    # self-deadlock fodder unless explicitly sanctioned
                    worst = worst or ("same-class", h)
            if h.cls != cls:
                new_edges.append((h.cls, cls))
        if new_edges:
            with self._mu:
                for e in new_edges:
                    self.edges[e] = self.edges.get(e, 0) + 1
        if worst is not None:
            kind, h = worst
            self._record_violation(kind, h, wlock)
        return False

    def _record_violation(self, kind: str, held: _Held,
                          wlock: "_WitnessLock") -> None:
        key = (kind, held.cls, wlock.lock_class)
        msg = (f"{kind}: acquiring {wlock.lock_class!r} "
               f"(rank {wlock.rank}, {wlock.name}) while holding "
               f"{held.cls!r} (rank {held.rank}, {held.name})")
        with self._mu:
            if key not in self._vkeys:
                self._vkeys.add(key)
                if len(self.violations) < _MAX_RECORDS:
                    self.violations.append({
                        "kind": kind,
                        "held": held.cls,
                        "acquired": wlock.lock_class,
                        "thread": threading.current_thread().name,
                        "detail": msg,
                    })
        if self.strict:
            raise LockOrderViolation(msg)

    def _did_acquire(self, wlock: "_WitnessLock", reentrant: bool) -> None:
        self._stack().append(_Held(
            wlock, wlock.lock_class, wlock.rank, wlock.name, reentrant,
            time.monotonic()))
        if not reentrant:
            with self._mu:
                self._held_by_thread.setdefault(
                    threading.get_ident(), []).append(wlock.name)

    def _on_release(self, wlock: "_WitnessLock") -> None:
        stack = self._stack()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i].lock is wlock:
                h = stack.pop(i)
                if not h.reentrant:
                    dur = time.monotonic() - h.t0
                    with self._mu:
                        held = self._held_by_thread.get(
                            threading.get_ident(), [])
                        if h.name in held:
                            held.remove(h.name)
                        if dur > self.hold_warn_s and \
                                len(self.long_holds) < _MAX_RECORDS:
                            self.long_holds.append({
                                "lock": h.name, "class": h.cls,
                                "seconds": round(dur, 3),
                                "thread":
                                    threading.current_thread().name,
                            })
                return
        # release without a matching tracked acquire: the runtime analog
        # of the static unbalanced-acquire finding
        with self._mu:
            key = ("unbalanced-release", wlock.lock_class, wlock.name)
            if key not in self._vkeys:
                self._vkeys.add(key)
                if len(self.violations) < _MAX_RECORDS:
                    self.violations.append({
                        "kind": "unbalanced-release",
                        "held": None,
                        "acquired": wlock.lock_class,
                        "thread": threading.current_thread().name,
                        "detail": f"release of {wlock.name} with no "
                                  f"tracked acquire on this thread",
                    })

    # ---------------------------------------------------------- teardown
    def cycles(self) -> list[list[str]]:
        """Elementary cycles in the observed class graph (including
        sanctioned edges: an ALLOWED_EDGES exemption plus a later
        reverse edge is exactly the deadlock the exemption argued could
        not happen)."""
        adj: dict[str, list[str]] = {}
        for a, b in self.edges:
            adj.setdefault(a, []).append(b)
        out: list[list[str]] = []
        seen_cycles: set[tuple[str, ...]] = set()

        def dfs(node: str, path: list[str], on_path: set[str],
                done: set[str]) -> None:
            on_path.add(node)
            path.append(node)
            for nxt in adj.get(node, ()):
                if nxt in on_path:
                    cyc = path[path.index(nxt):] + [nxt]
                    canon = tuple(sorted(cyc[:-1]))
                    if canon not in seen_cycles:
                        seen_cycles.add(canon)
                        out.append(cyc)
                elif nxt not in done:
                    dfs(nxt, path, on_path, done)
            on_path.discard(node)
            path.pop()
            done.add(node)

        done: set[str] = set()
        for node in sorted(adj):
            if node not in done:
                dfs(node, [], set(), done)
        return out

    def held_at_teardown(self) -> dict[str, list[str]]:
        """Locks still held per live thread — leaked daemons show here."""
        with self._mu:
            live = {t.ident: t.name for t in threading.enumerate()}
            return {
                live[tid]: list(names)
                for tid, names in self._held_by_thread.items()
                if names and tid in live
            }

    def report(self) -> dict:
        with self._mu:
            edges = {f"{a}->{b}": n for (a, b), n in sorted(self.edges.items())}
            violations = list(self.violations)
            long_holds = list(self.long_holds)
        return {
            "enabled": True,
            "strict": self.strict,
            "violations": violations,
            "edges": edges,
            "cycles": self.cycles(),
            "held_at_teardown": self.held_at_teardown(),
            "long_holds": long_holds,
            "ranks": dict(self.ranks),
            "allowed_edges": [f"{a}->{b}" for a, b in sorted(self.allowed)],
        }

    def dump(self, path: str) -> None:
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(self.report(), fh, indent=2, sort_keys=True)


class _WitnessLock:
    """Drop-in for ``threading.Lock``/``RLock`` under a witness."""

    __slots__ = ("_witness", "_inner", "lock_class", "rank", "name")

    def __init__(self, witness: Witness, inner, lock_class: str,
                 name: str | None):
        if lock_class not in witness.ranks:
            raise ValueError(f"unknown lock class {lock_class!r} — add it "
                             f"to repro.analysis.ranks.RANKS")
        self._witness = witness
        self._inner = inner
        self.lock_class = lock_class
        self.rank = witness.ranks[lock_class]
        self.name = name or f"{lock_class}@{id(self):x}"

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        reentrant = self._witness._on_acquire(self)
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            self._witness._did_acquire(self, reentrant)
        return ok

    def release(self) -> None:
        self._witness._on_release(self)
        self._inner.release()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        inner = self._inner
        return inner.locked() if hasattr(inner, "locked") else False

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<WitnessLock {self.name} rank={self.rank}>"


_global: Witness | None = None
_global_mu = threading.Lock()


def global_witness() -> Witness:
    """The process-wide witness (created on first use)."""
    global _global
    with _global_mu:
        if _global is None:
            _global = Witness()
        return _global


def make_lock(lock_class: str, name: str | None = None):
    """A ``threading.Lock`` — witnessed iff REPRO_LOCK_WITNESS is set."""
    if not ENABLED:
        return threading.Lock()
    return global_witness().lock(lock_class, name)


def make_rlock(lock_class: str, name: str | None = None):
    """A ``threading.RLock`` — witnessed iff REPRO_LOCK_WITNESS is set."""
    if not ENABLED:
        return threading.RLock()
    return global_witness().rlock(lock_class, name)
