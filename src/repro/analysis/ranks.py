"""The machine-checked lock-rank table (DESIGN.md §12).

One declaration shared by both enforcement layers — the static analyzer
(:mod:`repro.analysis.lockcheck`) and the runtime witness
(:mod:`repro.analysis.witness`) — so the hierarchy documented in
DESIGN.md §4/§5 can never drift from what is enforced.

Rule: a thread may only acquire a lock whose rank is **strictly
greater** than every rank it already holds (re-acquiring the *same*
RLock object is reentrancy, always allowed). Leaf classes may be
acquired at any point but nothing may be acquired while holding one.

The DESIGN.md §4/§5 hierarchy ``metadata → partition → controller``
maps onto the coarse ranks ``metadata=0, group/partition=1, log=2,
controller=3, metrics/registry=leaf``; the table below refines each
level with the sub-orderings the code actually relies on (e.g. a
``StreamLog``'s topics lock is acquired before its per-partition locks,
and the controller's *internal* metadata ``StreamLog`` nests inside the
controller lock, so it is a distinct lock class ranked above it).
"""

from __future__ import annotations

# lock class -> rank. Strictly-increasing acquisition order; gaps are
# deliberate so future classes slot in without renumbering.
RANKS: dict[str, int] = {
    # BrokerCluster._txn_locks[pid] — per-pid 2PC phase-two serialization.
    # Documented in cluster.py as "acquired BEFORE the metadata lock,
    # never while holding it", hence the only class below metadata.
    "txn": -10,
    # BrokerCluster._meta_lock — topology / offset store (coarse rank 0).
    "metadata": 0,
    # ConsumerGroup._lock — membership/assignment (coarse rank 1).
    "group": 10,
    # _PartitionCtl.lock / BrokerCluster._data_lock (coarse rank 1).
    "partition": 10,
    # StreamLog._lock — broker-local topics dict (coarse rank 2).
    "log": 20,
    # log._Partition.lock — per-partition segment state (coarse rank 2;
    # StreamLog acquires it while holding its topics lock).
    "log-part": 25,
    # QuorumController._lock (coarse rank 3).
    "controller": 30,
    # A controller NODE's internal metadata StreamLog: appended to while
    # the controller lock is held, so it is a distinct class nested
    # strictly inside "controller" (a broker data log never is).
    "ctl-log": 40,
    "ctl-log-part": 45,
    # LMEngine/ContinuousLMEngine._lock — serving request queue. Guards
    # only deque/slot bookkeeping; polled consumers and the decode loop
    # submit/admit concurrently. Never held across broker calls, so it
    # ranks above every broker class.
    "engine": 80,
    # MetricsRegistry._lock — series maps; snapshot() reads series values
    # (their leaf locks) while holding it, so it ranks just below leaf.
    "metrics-registry": 90,
    # Leaves: Counter/Gauge/Histogram._lock and the model Registry._lock.
    "metrics": 99,
    "registry": 99,
}

# Classes that must be terminal: acquiring ANY lock while holding one of
# these is a violation even if the ranks would allow it.
LEAF: frozenset[str] = frozenset({"metrics", "registry"})

# Sanctioned rank inversions, each with a one-line justification. Both
# layers consult this: the witness suppresses the acquire-time assertion
# for these (held, acquired) class pairs; teardown cycle detection still
# sees the edges, so a future reverse edge turns the exemption into a
# reported cycle.
ALLOWED_EDGES: dict[tuple[str, str], str] = {
    ("group", "metadata"): (
        "offset commits / rebalances resolve cluster state under the "
        "group lock for generation-fencing atomicity; the broker side "
        "never acquires consumer-group locks, so no cycle is possible"
    ),
    ("group", "log"): (
        "same path on a bare StreamLog backend: the log never calls "
        "back into consumer groups"
    ),
}

# Where locks live in the tree: (module basename, class, attribute) ->
# lock class. The static analyzer resolves `with self.X:` through this
# table (falling back to (module, attribute), then to a substring match
# against class names for out-of-tree fixtures); a constructed lock that
# resolves to nothing is itself a finding, so the table cannot rot.
SITE_TABLE: dict[tuple[str, str, str], str] = {
    ("cluster.py", "BrokerCluster", "_meta_lock"): "metadata",
    ("cluster.py", "BrokerCluster", "_data_lock"): "partition",
    ("cluster.py", "BrokerCluster", "_txn_locks"): "txn",
    ("cluster.py", "_PartitionCtl", "lock"): "partition",
    ("log.py", "StreamLog", "_lock"): "log",
    ("log.py", "_Partition", "lock"): "log-part",
    ("controller.py", "QuorumController", "_lock"): "controller",
    ("consumer.py", "ConsumerGroup", "_lock"): "group",
    ("lm_engine.py", "LMEngine", "_lock"): "engine",
    ("lm_engine.py", "ContinuousLMEngine", "_lock"): "engine",
    ("registry.py", "Registry", "_lock"): "registry",
    ("metrics.py", "MetricsRegistry", "_lock"): "metrics-registry",
    ("metrics.py", "Counter", "_lock"): "metrics",
    ("metrics.py", "Gauge", "_lock"): "metrics",
    ("metrics.py", "Histogram", "_lock"): "metrics",
}

# (module basename, attribute) fallback for locks reached through a
# non-self receiver (`ctl.lock`, `part.lock`) whose static type the AST
# pass does not track.
ATTR_TABLE: dict[tuple[str, str], str] = {
    ("cluster.py", "_meta_lock"): "metadata",
    ("cluster.py", "_data_lock"): "partition",
    ("cluster.py", "_txn_locks"): "txn",
    ("cluster.py", "lock"): "partition",
    ("log.py", "_lock"): "log",
    ("log.py", "lock"): "log-part",
    ("controller.py", "_lock"): "controller",
    ("consumer.py", "_lock"): "group",
    ("lm_engine.py", "_lock"): "engine",
    ("registry.py", "_lock"): "registry",
    ("metrics.py", "_lock"): "metrics",
}


def rank_of(lock_class: str) -> int:
    return RANKS[lock_class]


def classify_attr(
    module: str, cls: str | None, attr: str
) -> str | None:
    """Resolve a lock attribute to its class, most-specific key first."""
    if cls is not None:
        hit = SITE_TABLE.get((module, cls, attr))
        if hit is not None:
            return hit
    hit = ATTR_TABLE.get((module, attr))
    if hit is not None:
        return hit
    # out-of-tree modules (seeded test fixtures): a name like
    # `_partition_lock` or `metadata_mu` self-declares its class
    low = attr.lower()
    for name in sorted(RANKS, key=len, reverse=True):
        if name.replace("-", "_") in low:
            return name
    return None
