"""Concurrency-correctness toolkit (DESIGN.md §12).

Two layers over the same declared lock-rank table (:mod:`.ranks`):

* :mod:`.lockcheck` — a static AST pass over ``src/repro`` that builds
  the may-acquire-while-holding graph from ``with``-block nesting plus
  intra-module call edges and checks it against the rank table; also
  flags unbalanced raw ``.acquire()`` calls, blocking calls made while
  statically holding a metadata/partition lock, and silent
  ``except: pass`` handlers in daemon loops. CI gate:
  ``python -m repro.analysis.lockcheck src/repro``.
* :mod:`.witness` — a lockdep-style runtime witness: a drop-in lock
  wrapper that asserts rank ordering per-thread at acquire time and
  accumulates the *observed* acquisition-order graph so teardown cycle
  detection reports potential deadlocks that never manifested. Enabled
  for the whole test suite via ``REPRO_LOCK_WITNESS=1``.
"""
