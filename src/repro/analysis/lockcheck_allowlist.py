"""Sanctioned lockcheck findings. Every entry is (id pattern,
one-line justification); empty justifications fail the gate (exit 2)
and entries whose file glob matches a scanned file but suppress nothing
are *stale* and fail the gate (exit 1) — the list can only shrink
unless new code arrives with its own justified entry.

Patterns are fnmatch globs over finding ids
(``kind:path:qualname:detail`` — no line numbers, so entries survive
unrelated edits).
"""

from __future__ import annotations

ALLOWLIST: list[tuple[str, str]] = [
    (
        "blocking-under-lock:core/cluster.py:*:metadata->controller.submit",
        "sanctioned direction: DESIGN §4/§5 orders metadata→partition→"
        "controller, so quorum submits happen under these locks by design; "
        "submit is an in-process bounded append, not network I/O",
    ),
    (
        "blocking-under-lock:core/cluster.py:*:partition->controller.submit",
        "same sanctioned metadata→partition→controller direction as above "
        "(elections / ISR changes committed while the ctl lock is held)",
    ),
    (
        "lock-order:core/cluster.py:*:partition->metadata",
        "static over-approximation through _apply_metadata's command-kind "
        "dispatch: partition-scoped commands (the only kinds applied under "
        "a ctl lock) never take the metadata lock — only topic/broker "
        "branches do, reached solely from metadata-first paths; the runtime "
        "witness is path-sensitive and confirms no partition->metadata edge",
    ),
    (
        "unknown-lock:core/log.py:StreamLog.__init__:class(dynamic)",
        "the topics-lock class is a constructor parameter ('log' default, "
        "'ctl-log' for a controller node's internal metadata log); both are "
        "ranked, and make_rlock validates against RANKS at construction",
    ),
    (
        "unknown-lock:core/log.py:_Partition.__init__:class(dynamic)",
        "the partition lock class is threaded from the owning StreamLog "
        "('log-part' or 'ctl-log-part', both ranked); make_rlock validates "
        "the class against RANKS at construction time, so a typo still "
        "fails fast at runtime",
    ),
]
