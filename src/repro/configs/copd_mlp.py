"""copd-mlp — the paper's own validation model (§VI).

Kafka-ML's evaluation trains a small Keras MLP on the HCOPD dataset
(age / smoking status / gender / biosensor features -> diagnosis class).
This is the paper-faithful model used by the quickstart example and the
Table I/II benchmark reproduction. It is not an LM, so it gets its own
tiny functional model rather than an ArchConfig.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

ID = "copd-mlp"

N_FEATURES = 5  # age, smoking, gender, + 2 biosensor readings
N_CLASSES = 4  # COPD / HC / Asthma / Infected
HIDDEN = 32


def init(rng, n_features: int = N_FEATURES, hidden: int = HIDDEN, n_classes: int = N_CLASSES):
    k1, k2 = jax.random.split(rng)
    return {
        "w1": jax.random.normal(k1, (n_features, hidden), jnp.float32) / math.sqrt(n_features),
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": jax.random.normal(k2, (hidden, n_classes), jnp.float32) / math.sqrt(hidden),
        "b2": jnp.zeros((n_classes,), jnp.float32),
    }


def forward(params, x):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def loss_fn(params, batch):
    """Sparse categorical cross-entropy, as the paper's Listing 2 compiles."""
    logits = forward(params, batch["data"])
    labels = batch["label"].astype(jnp.int32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(lse - picked)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"loss": loss, "accuracy": acc}


def synth_dataset(rng_seed: int = 0, n: int = 220):
    """Synthetic HCOPD-like tabular data (the real CSV is not bundled)."""
    import numpy as np

    rng = np.random.default_rng(rng_seed)
    labels = rng.integers(0, N_CLASSES, size=n).astype(np.int32)
    centers = rng.normal(size=(N_CLASSES, N_FEATURES)).astype(np.float32) * 2.0
    data = centers[labels] + rng.normal(size=(n, N_FEATURES)).astype(np.float32)
    return {"data": data.astype(np.float32), "label": labels}
