"""yi-6b — llama-arch GQA [arXiv:2403.04652]."""
from repro.models.model import ArchConfig

ID = "yi-6b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ID,
        d_model=4096,
        n_layers=32,
        n_heads=32,
        n_kv_heads=4,
        d_ff=11008,
        vocab=64000,
        rope_theta=5e6,
        norm_eps=1e-5,
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name=ID + "-smoke",
        d_model=64,
        n_layers=3,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
    )
