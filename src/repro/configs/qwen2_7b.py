"""qwen2-7b — GQA + QKV bias [arXiv:2407.10671]."""
from repro.models.model import ArchConfig

ID = "qwen2-7b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ID,
        d_model=3584,
        n_layers=28,
        n_heads=28,
        n_kv_heads=4,
        d_ff=18944,
        vocab=152064,
        attn_bias=True,
        rope_theta=1e6,
        norm_eps=1e-6,
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name=ID + "-smoke",
        d_model=64,
        n_layers=3,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        attn_bias=True,
    )
