"""pixtral-12b — pixtral-ViT frontend (STUB: precomputed patch embeddings)
+ mistral-nemo decoder [hf:mistralai/Pixtral-12B-2409]."""
from repro.models.model import ArchConfig

ID = "pixtral-12b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ID,
        d_model=5120,
        n_layers=40,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=131072,
        frontend="patches",
        frontend_len=1024,
        rope_theta=1e9,
        norm_eps=1e-5,
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name=ID + "-smoke",
        d_model=64,
        n_layers=3,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        frontend="patches",
        frontend_len=8,
    )
