"""whisper-tiny — enc-dec; conv frontend STUB (precomputed frame
embeddings) [arXiv:2212.04356]."""
from repro.models.model import ArchConfig

ID = "whisper-tiny"


def config() -> ArchConfig:
    return ArchConfig(
        name=ID,
        d_model=384,
        n_layers=4,
        n_heads=6,
        n_kv_heads=6,
        d_ff=1536,
        vocab=51865,
        pattern=("encdec",),
        enc_dec=True,
        enc_layers=4,
        enc_seq=1500,
        frontend="frames",
        norm="ln",
        mlp_kind="plain",
        mlp_act="gelu",
        learned_pos=True,
        max_learned_pos=32768,
        tie_embeddings=True,
        norm_eps=1e-5,
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name=ID + "-smoke",
        d_model=64,
        n_layers=2,
        n_heads=2,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        pattern=("encdec",),
        enc_dec=True,
        enc_layers=2,
        enc_seq=24,
        frontend="frames",
        norm="ln",
        mlp_kind="plain",
        mlp_act="gelu",
        learned_pos=True,
        max_learned_pos=128,
        tie_embeddings=True,
        norm_eps=1e-5,
    )
