"""qwen3-moe-30b-a3b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]."""
from repro.models.model import ArchConfig
from repro.models.moe import MoEParams

ID = "qwen3-moe-30b-a3b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ID,
        d_model=2048,
        n_layers=48,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab=151936,
        pattern=("attn",),
        moe=MoEParams(n_experts=128, top_k=8, d_ff=768),
        rope_theta=1e6,
        norm_eps=1e-6,
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name=ID + "-smoke",
        d_model=64,
        n_layers=2,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=32,
        vocab=256,
        pattern=("attn",),
        moe=MoEParams(n_experts=8, top_k=2, d_ff=32, capacity_factor=4.0),
        rope_theta=1e6,
    )
