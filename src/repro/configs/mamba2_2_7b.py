"""mamba2-2.7b — SSD (state-space duality) [arXiv:2405.21060]."""
from repro.models.model import ArchConfig
from repro.models.ssm import SSMParams

ID = "mamba2-2.7b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ID,
        d_model=2560,
        n_layers=64,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=50280,
        pattern=("ssm",),
        mlp_kind="none",
        ssm=SSMParams(d_inner=5120, head_dim=64, state_dim=128, n_groups=1, chunk=256),
        tie_embeddings=True,
        norm_eps=1e-5,
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name=ID + "-smoke",
        d_model=64,
        n_layers=4,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=256,
        pattern=("ssm",),
        mlp_kind="none",
        ssm=SSMParams(d_inner=128, head_dim=32, state_dim=16, n_groups=1, chunk=16),
        tie_embeddings=True,
        norm_eps=1e-5,
    )
