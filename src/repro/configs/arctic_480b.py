"""arctic-480b — 128 experts top-2 + dense residual [hf:Snowflake/snowflake-arctic-base]."""
from repro.models.model import ArchConfig
from repro.models.moe import MoEParams

ID = "arctic-480b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ID,
        d_model=7168,
        n_layers=35,
        n_heads=56,
        n_kv_heads=8,
        d_ff=4864,
        vocab=32000,
        pattern=("attn",),
        moe=MoEParams(n_experts=128, top_k=2, d_ff=4864, dense_residual=True),
        rope_theta=1e6,
        norm_eps=1e-5,
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name=ID + "-smoke",
        d_model=64,
        n_layers=2,
        n_heads=7,  # keeps the non-divisible-heads (seq-parallel) path honest
        n_kv_heads=1,
        head_dim=16,
        d_ff=48,
        vocab=256,
        pattern=("attn",),
        moe=MoEParams(n_experts=8, top_k=2, d_ff=48, dense_residual=True, capacity_factor=4.0),
    )
