"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407]."""
from repro.models.model import ArchConfig

ID = "mistral-large-123b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ID,
        d_model=12288,
        n_layers=88,
        n_heads=96,
        n_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab=32768,
        rope_theta=1e6,
        norm_eps=1e-5,
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name=ID + "-smoke",
        d_model=64,
        n_layers=4,
        n_heads=8,
        n_kv_heads=2,
        head_dim=8,
        d_ff=128,
        vocab=256,
    )
