"""recurrentgemma-9b — RG-LRU + local attention, 1 attn : 2 recurrent
[arXiv:2402.19427]."""
from repro.models.model import ArchConfig
from repro.models.rglru import RGLRUParams

ID = "recurrentgemma-9b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ID,
        d_model=4096,
        n_layers=38,
        n_heads=16,
        n_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab=256000,
        pattern=("rec", "rec", "local"),
        window=2048,
        rglru=RGLRUParams(d_rnn=4096, conv_width=4, n_blocks=16),
        norm_plus_one=True,
        embed_scale=True,
        tie_embeddings=True,
        mlp_act="gelu",
        norm_eps=1e-6,
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name=ID + "-smoke",
        d_model=64,
        n_layers=5,  # 1 full group + tail of 2 — exercises the tail path
        n_heads=2,
        n_kv_heads=1,
        head_dim=32,
        d_ff=128,
        vocab=256,
        pattern=("rec", "rec", "local"),
        window=16,
        rglru=RGLRUParams(d_rnn=64, conv_width=4, n_blocks=4),
        norm_plus_one=True,
        embed_scale=True,
        tie_embeddings=True,
        mlp_act="gelu",
    )
