"""gemma2-2b — local/global alternating attention + logit softcaps [arXiv:2408.00118]."""
from repro.models.model import ArchConfig

ID = "gemma2-2b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ID,
        d_model=2304,
        n_layers=26,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        vocab=256000,
        pattern=("local", "attn"),
        window=4096,
        attn_softcap=50.0,
        final_softcap=30.0,
        post_norms=True,
        norm_plus_one=True,
        embed_scale=True,
        tie_embeddings=True,
        mlp_act="gelu",
        rope_theta=10000.0,
        norm_eps=1e-6,
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name=ID + "-smoke",
        d_model=64,
        n_layers=4,
        n_heads=2,
        n_kv_heads=1,
        head_dim=32,
        d_ff=128,
        vocab=256,
        pattern=("local", "attn"),
        window=16,
        attn_softcap=50.0,
        final_softcap=30.0,
        post_norms=True,
        norm_plus_one=True,
        embed_scale=True,
        tie_embeddings=True,
        mlp_act="gelu",
    )
