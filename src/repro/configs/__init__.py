"""Architecture registry: ``--arch <id>`` -> ArchConfig (+ reduced smoke twin).

Also defines the four assigned input-shape cells and ``input_specs`` that
produce ShapeDtypeStruct stand-ins for the dry-run (no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (
    arctic_480b,
    gemma2_2b,
    mamba2_2_7b,
    mistral_large_123b,
    pixtral_12b,
    qwen2_7b,
    qwen3_moe_30b_a3b,
    recurrentgemma_9b,
    whisper_tiny,
    yi_6b,
)
from repro.models.model import ArchConfig

_MODULES = [
    mamba2_2_7b,
    qwen3_moe_30b_a3b,
    arctic_480b,
    qwen2_7b,
    gemma2_2b,
    yi_6b,
    mistral_large_123b,
    pixtral_12b,
    recurrentgemma_9b,
    whisper_tiny,
]

ARCHS: dict[str, Any] = {m.ID: m for m in _MODULES}


def names() -> list[str]:
    return list(ARCHS)


def get(arch_id: str) -> ArchConfig:
    return ARCHS[arch_id].config()


def get_reduced(arch_id: str) -> ArchConfig:
    return ARCHS[arch_id].reduced_config()


# ------------------------------------------------------------------- shapes
@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic attention over the context; pure
# full-attention archs are skipped (DESIGN.md §5).
LONG_CONTEXT_OK = {"mamba2-2.7b", "gemma2-2b", "recurrentgemma-9b"}


def cell_supported(arch_id: str, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and arch_id not in LONG_CONTEXT_OK:
        return False, "pure full attention: 500k context unsupported (DESIGN.md §5)"
    return True, ""


def input_specs(cfg: ArchConfig, shape: ShapeCell) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    * train/prefill: the full token batch (frontend stubs provide
      precomputed patch/frame embeddings for [vlm]/[audio] — DESIGN.md §5);
    * decode: one new token per sequence (the KV cache is state, not input).
    """
    b, s = shape.global_batch, shape.seq_len
    emb = jnp.bfloat16
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "patches":
            return {
                "tokens": jax.ShapeDtypeStruct((b, s - cfg.frontend_len), jnp.int32),
                "patch_embeds": jax.ShapeDtypeStruct((b, cfg.frontend_len, cfg.d_model), emb),
            }
        if cfg.frontend == "frames":
            return {
                "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                "frames": jax.ShapeDtypeStruct((b, cfg.enc_seq, cfg.d_model), emb),
            }
        return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    # decode: one token per sequence; cache length = seq_len
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def make_batch(cfg: ArchConfig, shape: ShapeCell, rng: np.random.Generator) -> dict:
    """Materialize a random batch matching input_specs (smoke/bench use)."""
    out = {}
    for k, sds in input_specs(cfg, shape).items():
        if sds.dtype == jnp.int32:
            out[k] = rng.integers(0, cfg.vocab, size=sds.shape).astype(np.int32)
        else:
            out[k] = rng.normal(size=sds.shape).astype(np.float32)
    return out
