"""Mamba-2 SSD (state-space duality) mixer — chunked, TP over heads.

The SSD algorithm (arXiv:2405.21060) splits the sequence into chunks of
length Q: within a chunk the recurrence is computed as a (masked) attention
-like quadratic form; across chunks a tiny (N x P per head) state is
carried by a scan. This maps cleanly to the TPU: the intra-chunk einsums
are MXU matmuls, the inter-chunk scan carries (B, H, N, P) through
``lax.scan`` (or the Pallas kernel in repro.kernels.ssd_scan for the fused
hot path).

Sharding: heads over the model axis (80 heads / 16 = 5 local for
mamba2-2.7b); B/C projections are group-shared (G=1) and replicated; the
only collective is the out-projection's row-parallel psum.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import _normal, rms_norm, wsc
from repro.models.policy import Policy

__all__ = ["SSMParams", "ssd_chunked", "ssm_decode_step", "ssm_init", "ssm_mixer", "ssm_pspecs"]


@dataclasses.dataclass(frozen=True)
class SSMParams:
    d_inner: int  # expand * d_model
    head_dim: int = 64  # P
    state_dim: int = 128  # N
    n_groups: int = 1  # G (B/C shared across heads within a group)
    conv_width: int = 4
    chunk: int = 256  # Q

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def ssm_init(rng, L: int, d: int, sp: SSMParams, dtype) -> dict:
    ks = jax.random.split(rng, 10)
    s = 1.0 / math.sqrt(d)
    gn = sp.n_groups * sp.state_dim
    h = sp.n_heads
    return {
        "w_z": _normal(ks[0], (L, d, sp.d_inner), s, dtype),
        "w_x": _normal(ks[1], (L, d, sp.d_inner), s, dtype),
        "w_B": _normal(ks[2], (L, d, gn), s, dtype),
        "w_C": _normal(ks[3], (L, d, gn), s, dtype),
        "w_dt": _normal(ks[4], (L, d, h), s, dtype),
        "conv_x": _normal(ks[5], (L, sp.conv_width, sp.d_inner), 0.5, dtype),
        "conv_bc": _normal(ks[6], (L, sp.conv_width, 2 * gn), 0.5, dtype),
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32), (L, h))
        ),
        "D": jnp.ones((L, h), jnp.float32),
        "dt_bias": jnp.zeros((L, h), jnp.float32),
        "norm_w": jnp.ones((L, sp.d_inner), dtype),
        "w_out": _normal(ks[7], (L, sp.d_inner, d), 1.0 / math.sqrt(sp.d_inner), dtype),
    }


def ssm_pspecs(policy: Policy, d: int, sp: SSMParams) -> dict:
    tp_in = policy.tp(sp.d_inner)
    tp_h = policy.tp(sp.n_heads)
    f_in = policy.fsdp(d, has_tp=tp_in is not None)
    f_h = policy.fsdp(d, has_tp=tp_h is not None)
    f = policy.fsdp(d)
    return {
        "w_z": P(None, f_in, tp_in),
        "w_x": P(None, f_in, tp_in),
        "w_B": P(None, f, None),
        "w_C": P(None, f, None),
        "w_dt": P(None, f_h, tp_h),
        "conv_x": P(None, None, tp_in),
        "conv_bc": P(None, None, None),
        "A_log": P(None, tp_h),
        "D": P(None, tp_h),
        "dt_bias": P(None, tp_h),
        "norm_w": P(None, tp_in),
        "w_out": P(None, tp_in, f_in),
    }


def causal_conv(x: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv. x: (B, S, C), w: (W, C).

    With ``state`` (B, W-1, C) the conv is stateful (decode); returns
    (y, new_state).
    """
    b, s, c = x.shape
    wd = w.shape[0]
    if state is None:
        pad = jnp.zeros((b, wd - 1, c), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+W-1, C)
    y = sum(
        xp[:, i : i + s, :] * w[i][None, None, :] for i in range(wd)
    )
    new_state = xp[:, -(wd - 1) :, :] if wd > 1 else jnp.zeros((b, 0, c), x.dtype)
    return jax.nn.silu(y), new_state


def _segsum(dA: jax.Array) -> jax.Array:
    """Lower-triangular pairwise decay logs within a chunk.

    dA: (..., Q). Returns (..., Q, Q): out[i, j] = sum_{j < t <= i} dA[t],
    -inf above the diagonal.
    """
    q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum over (j, i]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) fp32, post-softplus
    A: jax.Array,  # (H,) fp32, negative
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
    chunk: int,
    init_state: jax.Array | None = None,  # (B, H, N, P)
    unroll: bool = False,
):
    """Chunked SSD. Returns (y (B,S,H,P), final_state (B,H,N,P)).

    Pure-jnp; the Pallas kernel in repro.kernels.ssd_scan fuses the same
    computation for the TPU hot path (validated against this function).
    """
    b, s0, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    q = min(chunk, s0)
    if s0 % q:  # pad tail: dt=0 => decay 1 and zero contribution (causal-safe)
        pad = q - s0 % q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s = x.shape[1]
    nc = s // q
    rep = h // g

    xr = x.reshape(b, nc, q, h, p)
    dtr = dt.reshape(b, nc, q, h).astype(jnp.float32)
    Br = Bm.reshape(b, nc, q, g, n)
    Cr = Cm.reshape(b, nc, q, g, n)
    dA = dtr * A[None, None, None, :]  # (B, nc, Q, H) log-decay, <= 0
    dAc = jnp.cumsum(dA, axis=2)  # within-chunk cumulative
    dAtot = dAc[:, :, -1, :]  # (B, nc, H)

    # intra-chunk (quadratic within chunk)
    Lmat = jnp.exp(_segsum(jnp.moveaxis(dA, 3, 2)))  # (B, nc, H, Q, Q)
    CB = jnp.einsum("bcqgn,bckgn->bcgqk", Cr, Br)  # (B, nc, G, Q, Q)
    CB = jnp.repeat(CB, rep, axis=2) if g != h else CB  # (B, nc, H, Q, Q)
    scores = CB * Lmat * dtr[:, :, None, :, :].transpose(0, 1, 4, 2, 3)
    # scores[b,c,h,i,j] = C_i B_j exp(segsum) dt_j
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", scores.astype(x.dtype), xr)

    # chunk -> state contribution: S_c = sum_j exp(dA_end - dAc_j) B_j dt_j x_j
    decay_to_end = jnp.exp(dAtot[:, :, None, :] - dAc)  # (B, nc, Q, H)
    Bh = jnp.repeat(Br, rep, axis=3) if g != h else Br  # (B, nc, Q, H, N)
    chunk_states = jnp.einsum(
        "bcqhn,bcqhp->bchnp",
        Bh,
        xr * (dtr * decay_to_end)[..., None].astype(x.dtype),
    )  # (B, nc, H, N, P)

    # inter-chunk scan
    if init_state is None:
        init_state = jnp.zeros((b, h, n, p), jnp.float32)

    def step(state, inp):
        cs, dtot = inp  # (B,H,N,P), (B,H)
        prev = state
        new = prev * jnp.exp(dtot)[:, :, None, None] + cs.astype(jnp.float32)
        return new, prev

    (final_state, prev_states) = jax.lax.scan(
        step,
        init_state,
        (jnp.moveaxis(chunk_states, 1, 0), jnp.moveaxis(dAtot, 1, 0)),
        unroll=True if unroll else 1,
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B, nc, H, N, P)

    # inter-chunk output: y_j += C_j exp(dAc_j) . state_prev
    Cin = jnp.repeat(Cr, rep, axis=3) if g != h else Cr  # (B,nc,Q,H,N)
    y_inter = jnp.einsum(
        "bcqhn,bchnp->bcqhp",
        (Cin * jnp.exp(dAc)[..., None]).astype(x.dtype),
        prev_states.astype(x.dtype),
    )
    y = (y_intra + y_inter).reshape(b, s, h, p)[:, :s0]
    return y, final_state


def ssm_mixer(
    p: dict,
    xin: jax.Array,  # (B, S, d)
    sp: SSMParams,
    policy: Policy,
    state: dict | None = None,  # decode: {"conv": (B,W-1,C), "ssd": (B,H,N,P)}
    norm_eps: float = 1e-5,
):
    """Full Mamba-2 block (without the residual add). Returns (y, new_state)."""
    b, s, d = xin.shape
    batch = policy.batch_spec(b)
    tp = policy.tp_axis
    gn = sp.n_groups * sp.state_dim

    z = jnp.einsum("bsd,de->bse", xin, p["w_z"])
    xh = jnp.einsum("bsd,de->bse", xin, p["w_x"])
    bc = jnp.einsum(
        "bsd,de->bse", xin, jnp.concatenate([p["w_B"], p["w_C"]], axis=-1)
    )
    dt_raw = jnp.einsum("bsd,dh->bsh", xin, p["w_dt"])
    xh = wsc(xh, P(batch, None, tp))

    conv_state = state["conv"] if state is not None else None
    cs_x = conv_state[:, :, : sp.d_inner] if conv_state is not None else None
    cs_bc = conv_state[:, :, sp.d_inner :] if conv_state is not None else None
    xh, ns_x = causal_conv(xh, p["conv_x"], cs_x)
    bc, ns_bc = causal_conv(bc, p["conv_bc"], cs_bc)
    new_conv = jnp.concatenate([ns_x, ns_bc], axis=-1)

    Bm = bc[..., :gn].reshape(b, s, sp.n_groups, sp.state_dim)
    Cm = bc[..., gn:].reshape(b, s, sp.n_groups, sp.state_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    xheads = xh.reshape(b, s, sp.n_heads, sp.head_dim)
    xheads = wsc(xheads, P(batch, None, tp, None))
    init_ssd = state["ssd"] if state is not None else None

    if s == 1 and state is not None:
        y, new_ssd = _ssd_step(xheads, dt, A, Bm, Cm, init_ssd)
    else:
        y, new_ssd = ssd_chunked(
            xheads, dt, A, Bm, Cm, sp.chunk, init_ssd, unroll=policy.unroll
        )

    y = y + xheads * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(b, s, sp.d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    out = wsc(out, P(batch, None, None))
    return out, {"conv": new_conv, "ssd": new_ssd}


def _ssd_step(x, dt, A, Bm, Cm, state):
    """Single-token recurrent update (decode).

    x: (B,1,H,P), dt: (B,1,H), state: (B,H,N,P).
    """
    b, _, h, p = x.shape
    g = Bm.shape[2]
    rep = h // g
    dA = jnp.exp(dt[:, 0, :] * A[None, :])  # (B, H)
    Bh = jnp.repeat(Bm[:, 0], rep, axis=1) if g != h else Bm[:, 0]  # (B,H,N)
    Ch = jnp.repeat(Cm[:, 0], rep, axis=1) if g != h else Cm[:, 0]
    upd = jnp.einsum("bhn,bhp->bhnp", Bh.astype(jnp.float32), (x[:, 0] * dt[:, 0, :, None].astype(x.dtype)).astype(jnp.float32))
    new_state = state * dA[:, :, None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), new_state)
    return y[:, None].astype(x.dtype), new_state


def ssm_decode_step(p, xin, sp, policy, state, norm_eps=1e-5):
    return ssm_mixer(p, xin, sp, policy, state=state, norm_eps=norm_eps)


def ssm_init_state(b: int, sp: SSMParams, dtype=jnp.float32) -> dict:
    conv_c = sp.d_inner + 2 * sp.n_groups * sp.state_dim
    return {
        "conv": jnp.zeros((b, sp.conv_width - 1, conv_c), dtype),
        "ssd": jnp.zeros((b, sp.n_heads, sp.state_dim, sp.head_dim), jnp.float32),
    }
