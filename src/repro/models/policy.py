"""Parallelism policy: how tensors map onto the production mesh.

One policy object threads through param init, forward, and the launcher so
that ``in_shardings`` for pjit and ``with_sharding_constraint`` annotations
inside the model always agree.

Axes (DESIGN.md §4):
  * ``batch_axes``  — data parallel: activations' batch dim ( ('pod','data') )
  * ``tp_axis``     — tensor parallel: heads / d_ff / experts / vocab
  * ``fsdp_axes``   — ZeRO-3 style parameter sharding on top of TP (big archs)
  * ``seq_axis``    — shard a decode KV cache on sequence (long-context cells
                      where batch < data-parallel degree)

Sharding is *best effort by divisibility*: a dimension is sharded over an
axis only when evenly divisible (e.g. gemma2's 8 query heads cannot split
over a 16-way model axis → heads stay replicated, d_ff still splits).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["Policy", "logical_to_pspec"]


@dataclass(frozen=True)
class Policy:
    # mesh axis name -> size; decisions are divisibility-driven
    mesh_axes: Mapping[str, int] = field(default_factory=dict)
    batch_axes: tuple[str, ...] = ("data",)
    tp_axis: str | None = "model"
    fsdp_axes: tuple[str, ...] = ()
    seq_axis: str | tuple | None = None
    remat: str = "none"  # none | block | full
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # serving extras (DESIGN.md §4): int8 post-training-quantized weights,
    # and a second sharding axis *inside* each expert's d_ff (2D EP) —
    # both needed to fit arctic-480b / mistral-large-123b decode on 256
    # v5e chips.
    weights_int8: bool = False
    ep_inner_axes: tuple[str, ...] = ()
    kv_cache_dtype: str = "bfloat16"  # fp8 halves decode cache footprint
    fsdp_selective: bool = True  # see Policy.fsdp
    # measurement mode: unroll every lax.scan so XLA cost_analysis counts
    # loop bodies times their trip count (HloCostAnalysis visits a while
    # body once) — used by the dry-run's 1/2-group roofline variants
    unroll: bool = False

    def ep_inner(self, dim_size: int):
        if not self.ep_inner_axes:
            return None
        return self._axis_if_divides(tuple(self.ep_inner_axes), dim_size)

    @classmethod
    def for_mesh(cls, mesh: Mesh, **kw) -> "Policy":
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        batch = tuple(a for a in ("pod", "data") if a in sizes)
        kw.setdefault("batch_axes", batch)
        kw.setdefault("tp_axis", "model" if "model" in sizes else None)
        return cls(mesh_axes=sizes, **kw)

    # ------------------------------------------------------------ axis sizes
    def size(self, axis: str | Sequence[str] | None) -> int:
        if axis is None:
            return 1
        if isinstance(axis, str):
            return self.mesh_axes.get(axis, 1)
        n = 1
        for a in axis:
            n *= self.mesh_axes.get(a, 1)
        return n

    @property
    def dp_degree(self) -> int:
        return self.size(self.batch_axes)

    # --------------------------------------------------------- spec builders
    def _axis_if_divides(self, axis, dim_size: int):
        """Return ``axis`` if it exists and evenly divides ``dim_size``."""
        if axis is None:
            return None
        if isinstance(axis, tuple):
            ok = all(a in self.mesh_axes for a in axis)
            return axis if ok and dim_size % self.size(axis) == 0 else None
        if axis not in self.mesh_axes:
            return None
        return axis if dim_size % self.size(axis) == 0 else None

    def batch_spec(self, batch_size: int):
        """Largest prefix of batch_axes that divides the batch."""
        axes: list[str] = []
        for a in self.batch_axes:
            trial = axes + [a]
            if batch_size % self.size(tuple(trial)) == 0:
                axes = trial
            else:
                break
        return tuple(axes) if axes else None

    def tp(self, dim_size: int):
        return self._axis_if_divides(self.tp_axis, dim_size)

    def fsdp(self, dim_size: int, has_tp: bool = False):
        """ZeRO-3 spec for a param dim. With ``fsdp_selective`` (default),
        params that already have a tensor-parallel dim are NOT fsdp-sharded:
        their per-device footprint is already /tp, and skipping the
        per-layer all-gather cut measured train collective bytes 156->10
        GB/dev on qwen2-7b (EXPERIMENTS.md §Perf it-A1). Full-ZeRO archs
        (arctic, mistral-large: optimizer state cannot fit otherwise) set
        fsdp_selective=False."""
        if not self.fsdp_axes:
            return None
        if has_tp and self.fsdp_selective:
            return None
        return self._axis_if_divides(tuple(self.fsdp_axes), dim_size)

    def seq(self, dim_size: int):
        return self._axis_if_divides(self.seq_axis, dim_size)

    def with_mesh_axes(self, sizes: Mapping[str, int]) -> "Policy":
        return replace(self, mesh_axes=dict(sizes))


def logical_to_pspec(policy: Policy, dims: Sequence[tuple[str, int]]) -> P:
    """Build a PartitionSpec from (logical_name, size) dims.

    Logical names: ``batch, seq, heads, kv_heads, head_dim, embed(=d_model,
    FSDP target), ff, experts, vocab, state, none``.
    """
    spec = []
    for name, size in dims:
        if name == "batch":
            spec.append(policy.batch_spec(size))
        elif name == "seq":
            spec.append(policy.seq(size))
        elif name in ("heads", "kv_heads", "ff", "vocab", "experts"):
            spec.append(policy.tp(size))
        elif name == "embed":
            spec.append(policy.fsdp(size))
        elif name in ("none", "layers", "head_dim", "state"):
            spec.append(None)
        else:
            raise ValueError(f"unknown logical dim {name!r}")
    return P(*spec)
