"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

The Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    log a_t = -c * softplus(Lambda) * r_t
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill runs the recurrence with ``jax.lax.associative_scan``
(log-depth; channel-parallel); decode is the one-step update. The gate
projections are block-diagonal (Griffin's choice) with blocks aligned to
the tensor-parallel shards, so the whole recurrence is collective-free —
only the block's out-projection psums.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import _normal, wsc
from repro.models.policy import Policy
from repro.models.ssm import causal_conv

__all__ = ["RGLRUParams", "rglru_init", "rglru_mixer", "rglru_pspecs", "rglru_scan"]

_C = 8.0  # Griffin's fixed gate sharpness


@dataclasses.dataclass(frozen=True)
class RGLRUParams:
    d_rnn: int
    conv_width: int = 4
    n_blocks: int = 16  # block-diagonal gate projections

    @property
    def block_dim(self) -> int:
        return self.d_rnn // self.n_blocks


def rglru_init(rng, L: int, d: int, rp: RGLRUParams, dtype) -> dict:
    ks = jax.random.split(rng, 8)
    s = 1.0 / math.sqrt(d)
    bd = rp.block_dim
    sb = 1.0 / math.sqrt(bd)
    # Lambda init so a^c in (0.9, 0.999) — Griffin appendix
    u = jax.random.uniform(ks[6], (L, rp.d_rnn), jnp.float32, 0.9**2, 0.999**2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2 * _C)))
    return {
        "w_x_branch": _normal(ks[0], (L, d, rp.d_rnn), s, dtype),
        "w_gate_branch": _normal(ks[1], (L, d, rp.d_rnn), s, dtype),
        "conv": _normal(ks[2], (L, rp.conv_width, rp.d_rnn), 0.5, dtype),
        "w_a": _normal(ks[3], (L, rp.n_blocks, bd, bd), sb, dtype),
        "b_a": jnp.zeros((L, rp.d_rnn), jnp.float32),
        "w_i": _normal(ks[4], (L, rp.n_blocks, bd, bd), sb, dtype),
        "b_i": jnp.zeros((L, rp.d_rnn), jnp.float32),
        "Lambda": lam,
        "w_out": _normal(ks[5], (L, rp.d_rnn, d), 1.0 / math.sqrt(rp.d_rnn), dtype),
    }


def rglru_pspecs(policy: Policy, d: int, rp: RGLRUParams) -> dict:
    tp_r = policy.tp(rp.d_rnn)
    tp_b = policy.tp(rp.n_blocks)
    f = policy.fsdp(d, has_tp=tp_r is not None)
    return {
        "w_x_branch": P(None, f, tp_r),
        "w_gate_branch": P(None, f, tp_r),
        "conv": P(None, None, tp_r),
        "w_a": P(None, tp_b, None, None),
        "b_a": P(None, tp_r),
        "w_i": P(None, tp_b, None, None),
        "b_i": P(None, tp_r),
        "Lambda": P(None, tp_r),
        "w_out": P(None, tp_r, f),
    }


def _block_diag_proj(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B,S,D), w: (nb, bd, bd) block-diagonal, b: (D,)."""
    bsz, s, dd = x.shape
    nb, bd, _ = w.shape
    xb = x.reshape(bsz, s, nb, bd)
    y = jnp.einsum("bsnd,nde->bsne", xb, w).reshape(bsz, s, dd)
    return y.astype(jnp.float32) + b


def rglru_scan(
    x: jax.Array,  # (B, S, D) gated input, fp32
    log_a: jax.Array,  # (B, S, D) fp32 log decay, <= 0
    h0: jax.Array | None = None,  # (B, D)
):
    """First-order linear recurrence via associative scan.

    h_t = a_t h_{t-1} + b_t with b_t = sqrt(1-a_t^2) x_t.
    Returns (h (B,S,D) fp32, h_last (B,D)).
    """
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 0.0)) * x
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def rglru_mixer(
    p: dict,
    xin: jax.Array,  # (B, S, d)
    rp: RGLRUParams,
    policy: Policy,
    state: dict | None = None,  # decode: {"conv": (B,W-1,D), "h": (B,D)}
):
    """Griffin recurrent block (without residual). Returns (y, new_state)."""
    b, s, d = xin.shape
    batch = policy.batch_spec(b)
    tp = policy.tp_axis

    xb = jnp.einsum("bsd,de->bse", xin, p["w_x_branch"])
    gate = jnp.einsum("bsd,de->bse", xin, p["w_gate_branch"])
    xb = wsc(xb, P(batch, None, tp))

    conv_state = state["conv"] if state is not None else None
    xb, new_conv = causal_conv(xb, p["conv"], conv_state)

    r = jax.nn.sigmoid(_block_diag_proj(xb, p["w_a"], p["b_a"]))
    i = jax.nn.sigmoid(_block_diag_proj(xb, p["w_i"], p["b_i"]))
    log_a = -_C * jax.nn.softplus(p["Lambda"]) * r  # (B,S,D) fp32
    gated = i * xb.astype(jnp.float32)

    h0 = state["h"] if state is not None else None
    if s == 1 and state is not None:
        a = jnp.exp(log_a[:, 0])
        h_last = a * h0 + jnp.sqrt(jnp.maximum(1 - a * a, 0.0)) * gated[:, 0]
        h = h_last[:, None]
    else:
        h, h_last = rglru_scan(gated, log_a, h0)

    y = h.astype(xin.dtype) * jax.nn.gelu(gate, approximate=True)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    out = wsc(out, P(batch, None, None))
    return out, {"conv": new_conv, "h": h_last}


def rglru_init_state(b: int, rp: RGLRUParams, dtype=jnp.float32) -> dict:
    return {
        "conv": jnp.zeros((b, rp.conv_width - 1, rp.d_rnn), dtype),
        "h": jnp.zeros((b, rp.d_rnn), jnp.float32),
    }
