"""Shared transformer layers — JAX-functional, policy-sharded.

Conventions:
* params are nested dicts of jnp arrays; every init function has a matching
  ``*_pspecs`` returning the same treedef of ``PartitionSpec``s (tested).
* layer stacks are **scanned**: per-layer params carry a leading ``layers``
  dim (spec ``None``) — this keeps HLO size and compile time flat in depth.
* attention tensor-parallel strategy is divisibility-driven (``attn_strategy``):
  - ``heads``: KV repeated to H heads then Q/K/V sharded on heads over the
    model axis (repeat-then-shard is a local slice, not a broadcast copy);
  - ``seq``:   context parallelism for head counts that don't divide the
    model axis (qwen2 28H, arctic 56H, gemma2 8H, whisper 6H): the query
    *block* is sharded on its sequence dim, K/V stay unrepeated+replicated
    and the GQA einsum runs grouped — per-device score block is
    (B_loc, K, G, Qb/tp, S);
  - ``none``:  replicated attention compute (no model axis / tiny models).
* queries are processed in chunks (``lax.map`` over blocks) so fp32 score
  blocks never exceed (B, H, q_block, S) — the "XLA-flash" pattern. Sliding
  window layers slice K/V to a static (window + q_block) span per block:
  O(S·window) work, not O(S²).
* RoPE uses the *interleaved* (GPT-J) pairing so rotation partners are
  adjacent and never straddle a shard boundary.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.policy import Policy

__all__ = [
    "AttnParams",
    "attention",
    "attention_init",
    "attention_pspecs",
    "attn_strategy",
    "decode_attention",
    "embed_init",
    "paged_decode_attention",
    "layer_norm",
    "mlp",
    "mlp_init",
    "mlp_pspecs",
    "rms_norm",
    "rope",
    "softcap",
    "wsc",
]


def wsc(x: jax.Array, spec: P | None) -> jax.Array:
    """with_sharding_constraint that no-ops outside a mesh context."""
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x  # no mesh (unit tests on CPU without mesh context)


# ---------------------------------------------------------------------- norms
def rms_norm(x: jax.Array, w: jax.Array, eps: float, *, plus_one: bool = False) -> jax.Array:
    """RMSNorm in fp32 (gemma-style ``(1 + w)`` scaling when plus_one)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if plus_one else w.astype(jnp.float32)
    return (y * scale).astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ----------------------------------------------------------------------- init
def _normal(rng, shape, scale, dtype):
    return (scale * jax.random.normal(rng, shape, dtype=jnp.float32)).astype(dtype)


def embed_init(rng, vocab: int, d: int, dtype) -> jax.Array:
    return _normal(rng, (vocab, d), 1.0 / math.sqrt(d), dtype)


# ----------------------------------------------------------------------- RoPE
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Interleaved rotary embedding.

    x: (B, S, H, D) with D even; positions: (S,) or (B, S).
    """
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)  # (half,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B?, S, half)
    cos = jnp.cos(ang)[:, :, None, :]  # (B?, S, 1, half)
    sin = jnp.sin(ang)[:, :, None, :]
    xf = x.astype(jnp.float32).reshape(x.shape[:-1] + (half, 2))
    x0, x1 = xf[..., 0], xf[..., 1]
    y0 = x0 * cos - x1 * sin
    y1 = x0 * sin + x1 * cos
    y = jnp.stack([y0, y1], axis=-1).reshape(x.shape)
    return y.astype(x.dtype)


# ------------------------------------------------------------------------ MLP
def mlp_init(rng, L: int, d: int, d_ff: int, kind: str, dtype) -> dict:
    ks = jax.random.split(rng, 3)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(d_ff)
    p = {
        "w_in": _normal(ks[0], (L, d, d_ff), s_in, dtype),
        "w_out": _normal(ks[1], (L, d_ff, d), s_out, dtype),
    }
    if kind == "gated":
        p["w_gate"] = _normal(ks[2], (L, d, d_ff), s_in, dtype)
    return p


def mlp_pspecs(policy: Policy, d: int, d_ff: int, kind: str) -> dict:
    tp = policy.tp(d_ff)
    io = P(None, policy.fsdp(d, has_tp=tp is not None), tp)
    oi = P(None, tp, policy.fsdp(d, has_tp=tp is not None))
    p = {"w_in": io, "w_out": oi}
    if kind == "gated":
        p["w_gate"] = io
    return p


def mlp(p: dict, x: jax.Array, kind: str, act: str = "silu") -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    actf = {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True)}[act]
    if kind == "gated":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = actf(g) * h
    else:
        h = actf(h)
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"])


# ------------------------------------------------------------------ attention
@dataclasses.dataclass(frozen=True)
class AttnParams:
    """Static attention hyper-params for one block kind."""

    n_heads: int
    n_kv: int
    head_dim: int
    rope_theta: float = 10000.0
    use_rope: bool = True
    causal: bool = True
    window: int | None = None  # sliding-window size (local attention)
    softcap: float | None = None  # gemma2 attn-logit capping
    bias: bool = False  # qwen2 QKV bias
    q_block: int = 512  # query chunk for the XLA-flash path
    cross: bool = False  # enc-dec cross attention (K/V from encoder)


def attn_strategy(ap: AttnParams, policy: Policy, seq_len: int) -> str:
    """heads | seq | none — see module docstring."""
    tp = policy.size(policy.tp_axis)
    if tp == 1:
        return "none"
    if ap.n_heads % tp == 0:
        return "heads"
    if seq_len % tp == 0 and seq_len >= tp:
        return "seq"
    return "none"


def attention_init(rng, L: int, d: int, ap: AttnParams, dtype) -> dict:
    ks = jax.random.split(rng, 5)
    s = 1.0 / math.sqrt(d)
    so = 1.0 / math.sqrt(ap.n_heads * ap.head_dim)
    p = {
        "wq": _normal(ks[0], (L, d, ap.n_heads, ap.head_dim), s, dtype),
        "wk": _normal(ks[1], (L, d, ap.n_kv, ap.head_dim), s, dtype),
        "wv": _normal(ks[2], (L, d, ap.n_kv, ap.head_dim), s, dtype),
        "wo": _normal(ks[3], (L, ap.n_heads, ap.head_dim, d), so, dtype),
    }
    if ap.bias:
        p["bq"] = jnp.zeros((L, ap.n_heads, ap.head_dim), dtype)
        p["bk"] = jnp.zeros((L, ap.n_kv, ap.head_dim), dtype)
        p["bv"] = jnp.zeros((L, ap.n_kv, ap.head_dim), dtype)
    return p


def attention_pspecs(policy: Policy, d: int, ap: AttnParams) -> dict:
    h = policy.tp(ap.n_heads)
    kv = policy.tp(ap.n_kv)
    eq = policy.fsdp(d, has_tp=h is not None)
    ekv = policy.fsdp(d, has_tp=kv is not None)
    p = {
        "wq": P(None, eq, h, None),
        "wk": P(None, ekv, kv, None),
        "wv": P(None, ekv, kv, None),
        "wo": P(None, h, None, eq),
    }
    if ap.bias:
        p["bq"] = P(None, h, None)
        p["bk"] = P(None, kv, None)
        p["bv"] = P(None, kv, None)
    return p


def _project_qkv(p: dict, x: jax.Array, ap: AttnParams, positions: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if ap.bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if ap.use_rope:
        q = rope(q, positions, ap.rope_theta)
        k = rope(k, positions, ap.rope_theta)
    return q, k, v


def _repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B,S,Kv,D) -> (B,S,H,D), kv head h serves q heads [h*rep, (h+1)*rep)."""
    b, s, kv, d = k.shape
    rep = n_heads // kv
    if rep == 1:
        return k
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, rep, d)).reshape(
        b, s, n_heads, d
    )


def _mask_bias(q_pos, k_pos, causal: bool, window: int | None) -> jax.Array:
    """(Q, K) additive fp32 mask."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def attention(
    p: dict,
    x: jax.Array,  # (B, S, d)
    ap: AttnParams,
    policy: Policy,
    positions: jax.Array | None = None,  # (S,)
    kv_source: jax.Array | None = None,  # encoder states for cross attention
    return_kv: bool = False,  # prefill: also return unrepeated K/V
):
    """Full-sequence attention (training / prefill), chunked over queries."""
    b, s, d = x.shape
    if positions is None:
        positions = jnp.arange(s)
    strat = attn_strategy(ap, policy, s)
    batch = policy.batch_spec(b)
    tp = policy.tp_axis
    scale = 1.0 / math.sqrt(ap.head_dim)

    if ap.cross:
        src = kv_source
        src_pos = jnp.arange(src.shape[1])
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    else:
        q, k, v = _project_qkv(p, x, ap, positions)
        src_pos = positions
    kv_out = (k, v) if return_kv else None

    if strat == "seq":
        out = _context_parallel_attention(q, k, v, positions, src_pos, ap, policy)
    else:
        if strat == "heads":
            k = _repeat_kv(k, ap.n_heads)
            v = _repeat_kv(v, ap.n_heads)
            spec = P(batch, None, tp, None)
        else:
            spec = P(batch, None, None, None)
        q, k, v = wsc(q, spec), wsc(k, spec if strat == "heads" else spec), wsc(v, spec)
        out = _chunked_attention(
            q, k, v, positions, src_pos, ap,
            block_spec=spec, out_spec=spec, grouped=False,
            unroll=policy.unroll,
        )
        out = wsc(out, spec)

    y = jnp.einsum("bshd,hdm->bsm", out, p["wo"])
    y = wsc(y, P(batch, None, None))
    if return_kv:
        return y, kv_out[0], kv_out[1]
    return y


def _chunked_attention(q, k, v, q_pos, k_pos, ap: AttnParams, *,
                       block_spec=None, out_spec=None, grouped: bool,
                       pos_offset=None, unroll: bool = False):
    """lax.map over query chunks; scores never exceed (B, H, qb, Sk).

    ``grouped`` keeps KV unrepeated and runs the GQA einsum with a group
    dim (used inside the context-parallel shard_map where per-shard KV
    replication would waste memory).
    """
    b, s, h, d = q.shape
    scale = 1.0 / math.sqrt(ap.head_dim)
    sk = k.shape[1]
    qb = min(ap.q_block, s)
    if s % qb != 0:
        qb = s
    nb = s // qb
    causal = ap.causal and not ap.cross
    sliced_window = ap.window is not None and not ap.cross and ap.window + qb < sk
    span = min(ap.window + qb, sk) if ap.window is not None else sk
    gq = ap.n_heads // ap.n_kv

    def block(i):
        qs = i * qb
        qi = jax.lax.dynamic_slice_in_dim(q, qs, qb, axis=1)
        if block_spec is not None:
            qi = wsc(qi, block_spec)
        qp = jax.lax.dynamic_slice_in_dim(q_pos, qs, qb, axis=0)
        if pos_offset is not None:
            qp = qp + pos_offset
        if sliced_window:
            # static-size K/V span ending at this block's last query
            last_q = (qp[-1] if pos_offset is None else qp[-1])
            ks = jnp.clip(last_q + 1 - span, 0, sk - span)
            ki = jax.lax.dynamic_slice_in_dim(k, ks, span, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v, ks, span, axis=1)
            kp = jax.lax.dynamic_slice_in_dim(k_pos, ks, span, axis=0)
        else:
            ki, vi, kp = k, v, k_pos
        bias = _mask_bias(qp, kp, causal, ap.window)
        if grouped and gq > 1:
            qg = qi.reshape(qi.shape[:2] + (ap.n_kv, gq, ap.head_dim))
            sc = jnp.einsum("bqkgd,bskd->bkgqs", qg, ki).astype(jnp.float32) * scale
            sc = softcap(sc, ap.softcap) if ap.softcap else sc
            sc = sc + bias[None, None, None]
            w = jax.nn.softmax(sc, axis=-1).astype(qi.dtype)
            ob = jnp.einsum("bkgqs,bskd->bqkgd", w, vi).reshape(qi.shape)
        else:
            ki2 = _repeat_kv(ki, ap.n_heads) if ki.shape[2] != ap.n_heads else ki
            vi2 = _repeat_kv(vi, ap.n_heads) if vi.shape[2] != ap.n_heads else vi
            sc = jnp.einsum("bqhd,bkhd->bhqk", qi, ki2).astype(jnp.float32) * scale
            sc = softcap(sc, ap.softcap) if ap.softcap else sc
            sc = sc + bias[None, None]
            w = jax.nn.softmax(sc, axis=-1).astype(qi.dtype)
            ob = jnp.einsum("bhqk,bkhd->bqhd", w, vi2)
        if block_spec is not None:
            ob = wsc(ob, block_spec)
        return ob

    if nb == 1:
        return block(jnp.int32(0))
    _, outs = jax.lax.scan(
        lambda c, i: (c, block(i)), 0, jnp.arange(nb),
        unroll=True if unroll else 1,
    )  # (nb, B, qb, H, D)
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, h, d)


def _context_parallel_attention(q, k, v, positions, src_pos, ap: AttnParams, policy: Policy):
    """Context parallelism via shard_map: the query sequence is sharded
    over the model axis; K/V are (explicitly) all-gathered once per layer.

    Used when head counts don't divide the model axis (qwen2 28H, arctic
    56H, gemma2 8H, whisper 6H). Expressing this through the SPMD
    partitioner instead breaks at the query-chunking reshape (the
    partitioner falls back to fully-replicated fp32 Q/K/V — an 8.6 GB/dev
    regression measured in EXPERIMENTS.md §Perf it-1).
    """
    from jax.experimental.shard_map import shard_map

    mesh = getattr(policy, "_mesh_obj", None)
    b, s, h, d = q.shape
    batch = policy.batch_spec(b)
    tp = policy.tp_axis
    if mesh is None:  # no mesh: plain chunked attention (test path)
        return _chunked_attention(
            q, k, v, positions, src_pos, ap, grouped=ap.n_kv != ap.n_heads,
            unroll=policy.unroll,
        )

    cross = ap.cross

    def body(q_l, k_l, v_l, qpos_l, kpos):
        # q_l: (B_l, S/tp, H, D); k_l/v_l: cross ? (B_l, S_src, Kv, D)
        #                                        : (B_l, S/tp, Kv, D)
        if not cross:
            k_g = jax.lax.all_gather(k_l, tp, axis=1, tiled=True)
            v_g = jax.lax.all_gather(v_l, tp, axis=1, tiled=True)
        else:
            k_g, v_g = k_l, v_l
        return _chunked_attention(
            q_l, k_g, v_g, qpos_l[0], kpos[0], ap,
            grouped=ap.n_kv != ap.n_heads, unroll=policy.unroll,
        )

    qpos = positions[None].astype(jnp.int32)  # (1, S) -> shard over tp
    kpos = src_pos[None].astype(jnp.int32)
    kv_in = P(batch, None, None, None) if cross else P(batch, tp, None, None)
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(batch, tp, None, None),
            kv_in,
            kv_in,
            P(None, tp),
            P(None, None),
        ),
        out_specs=P(batch, tp, None, None),
        check_rep=False,
    )(q, k, v, qpos, kpos)


# ------------------------------------------------------------- decode (1-tok)
def decode_attention(
    p: dict,
    x: jax.Array,  # (B, 1, d)
    cache_k: jax.Array,  # (B, S_cache, Kv, D)
    cache_v: jax.Array,
    cache_pos: jax.Array,  # int32 count of tokens already in cache:
    #                        scalar (whole batch in lockstep) or (B,)
    #                        per-row (continuous batching: each slot at
    #                        its own position)
    ap: AttnParams,
    policy: Policy,
    *,
    ring: bool = False,  # cache is a window-sized ring buffer (local layers)
    cache_seq_spec=None,  # mesh axes sharding the cache seq dim, if any
):
    """One-token decode against a KV cache; returns (out, new_k, new_v).

    With a seq-sharded cache the softmax over the sharded key axis lowers to
    a local masked reduce + a tiny cross-shard reduction — flash-decode's
    schedule, derived by the SPMD partitioner.

    A vector ``cache_pos`` switches every position-dependent step to
    per-row form: RoPE rotates each row by its own position, the new K/V
    lands at each row's own slot (one scatter instead of a slice update),
    and the validity/window masks become (B, S). The flash-decode
    shard_map path stays scalar-only (its predicated slot write assumes
    one slot per step); per-row decode falls through to the plain path.
    """
    b, one, d = x.shape
    s_cache = cache_k.shape[1]
    pos = jnp.asarray(cache_pos, jnp.int32)
    per_row = pos.ndim == 1
    positions = pos[:, None] if per_row else jnp.reshape(pos, (1,))
    batch = policy.batch_spec(b)
    cache_spec = P(batch, cache_seq_spec, None, None)

    if ap.cross:
        # K/V are the (precomputed) encoder projections: no update, no mask.
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        kf = _repeat_kv(cache_k, ap.n_heads).astype(q.dtype)
        vf = _repeat_kv(cache_v, ap.n_heads).astype(q.dtype)
        sc = jnp.einsum("bqhd,bkhd->bhqk", q, kf).astype(jnp.float32)
        sc = sc / math.sqrt(ap.head_dim)
        w = jax.nn.softmax(sc, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", w, vf)
        y = jnp.einsum("bshd,hdm->bsm", out, p["wo"])
        return y, cache_k, cache_v

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    kn = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    vn = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if ap.bias:
        q, kn, vn = q + p["bq"], kn + p["bk"], vn + p["bv"]
    if ap.use_rope:
        q = rope(q, positions, ap.rope_theta)
        kn = rope(kn, positions, ap.rope_theta)

    mesh = getattr(policy, "_mesh_obj", None)
    if cache_seq_spec is not None and mesh is not None and not per_row:
        out, cache_k, cache_v = _flash_decode(
            q, kn, vn, cache_k, cache_v, pos, ap, policy, mesh,
            ring=ring, seq_axes=cache_seq_spec,
        )
    else:
        slot = jnp.mod(pos, s_cache) if ring else pos
        if per_row:
            rows = jnp.arange(b)
            cache_k = cache_k.at[rows, slot].set(kn[:, 0].astype(cache_k.dtype))
            cache_v = cache_v.at[rows, slot].set(vn[:, 0].astype(cache_v.dtype))
        else:
            cache_k = jax.lax.dynamic_update_slice_in_dim(
                cache_k, kn.astype(cache_k.dtype), slot, axis=1
            )
            cache_v = jax.lax.dynamic_update_slice_in_dim(
                cache_v, vn.astype(cache_v.dtype), slot, axis=1
            )
        cache_k = wsc(cache_k, cache_spec)
        cache_v = wsc(cache_v, cache_spec)
        kf = _repeat_kv(cache_k, ap.n_heads).astype(q.dtype)
        vf = _repeat_kv(cache_v, ap.n_heads).astype(q.dtype)
        rep_spec = P(batch, None, policy.tp(ap.n_heads), None)
        kf = wsc(kf, rep_spec)
        vf = wsc(vf, rep_spec)
        scale = 1.0 / math.sqrt(ap.head_dim)
        sc = jnp.einsum("bqhd,bkhd->bhqk", q, kf).astype(jnp.float32) * scale
        sc = softcap(sc, ap.softcap) if ap.softcap else sc
        valid = _decode_valid(pos, s_cache, ring=ring, window=ap.window)
        sc = jnp.where(
            valid[:, None, None, :] if per_row else valid[None, None, None, :],
            sc, -1e30,
        )
        w = jax.nn.softmax(sc, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", w, vf)
    y = jnp.einsum("bshd,hdm->bsm", out, p["wo"])
    return y, cache_k, cache_v


def _decode_valid(pos, s_cache: int, *, ring: bool, window: int | None):
    """Cache-slot validity for one-token decode: slots holding positions
    0..pos (inclusive of the token just written), intersected with the
    sliding window for non-ring window layers. Scalar ``pos`` → (S,);
    vector ``pos`` (B,) → per-row (B, S) windows."""
    idx = jnp.arange(s_cache)
    if pos.ndim == 1:
        valid = idx[None, :] <= pos[:, None]
        if not ring and window is not None:
            valid &= idx[None, :] > pos[:, None] - window
        return valid
    valid = idx <= pos
    if not ring and window is not None:
        valid &= idx > pos - window
    return valid


def paged_decode_attention(
    p: dict,
    x: jax.Array,  # (B, 1, d)
    cache_k: jax.Array,  # (N_blocks, block, Kv, D) — physical block pool,
    #                       shared by every slot (no batch dim)
    cache_v: jax.Array,
    cache_pos: jax.Array,  # (B,) int32 per-row token counts
    block_table: jax.Array,  # (B, max_blocks) int32 physical block ids;
    #                          virtual position p of row b lives at
    #                          (block_table[b, p // block], p % block)
    ap: AttnParams,
    policy: Policy,
):
    """One-token decode against a paged (block-table) KV cache.

    Rows with different prompt lengths share one physical pool without
    fragmentation: each row owns ceil(len / block) blocks, mapped through
    its block-table row. The new token's K/V is scattered to the owning
    (block, offset) pair; reads gather each row's table into a contiguous
    (B, max_blocks * block) view and run the same per-row masked softmax
    as the plain decode path — data beyond a row's ``cache_pos`` (stale
    freed-block contents included) is masked to -1e30, so block recycling
    needs no zeroing. Idle rows must point their table at the reserved
    scratch block 0 so their (discarded) writes never land in a live
    row's blocks. Returns (out, new_k, new_v).
    """
    b = x.shape[0]
    n_phys, blk_sz, n_kv, hd = cache_k.shape
    max_blocks = block_table.shape[1]
    pos = jnp.asarray(cache_pos, jnp.int32)
    positions = pos[:, None]  # (B, 1)

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    kn = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    vn = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if ap.bias:
        q, kn, vn = q + p["bq"], kn + p["bk"], vn + p["bv"]
    if ap.use_rope:
        q = rope(q, positions, ap.rope_theta)
        kn = rope(kn, positions, ap.rope_theta)

    # scatter the new token: row r -> (table[r, pos_r // blk], pos_r % blk).
    # Rows whose pos drifted past their table (recycled slots) clamp to
    # the last table entry — an all-zeros table routes them to scratch.
    rows = jnp.arange(b)
    tbl_idx = jnp.minimum(pos // blk_sz, max_blocks - 1)
    blk = block_table[rows, tbl_idx]
    off = jnp.mod(pos, blk_sz)
    cache_k = cache_k.at[blk, off].set(kn[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[blk, off].set(vn[:, 0].astype(cache_v.dtype))

    # gather each row's blocks into a contiguous virtual sequence
    s_virt = max_blocks * blk_sz
    kf = cache_k[block_table].reshape(b, s_virt, n_kv, hd)
    vf = cache_v[block_table].reshape(b, s_virt, n_kv, hd)
    kf = _repeat_kv(kf, ap.n_heads).astype(q.dtype)
    vf = _repeat_kv(vf, ap.n_heads).astype(q.dtype)
    scale = 1.0 / math.sqrt(ap.head_dim)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, kf).astype(jnp.float32) * scale
    sc = softcap(sc, ap.softcap) if ap.softcap else sc
    valid = _decode_valid(pos, s_virt, ring=False, window=ap.window)
    sc = jnp.where(valid[:, None, None, :], sc, -1e30)
    w = jax.nn.softmax(sc, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, vf)
    y = jnp.einsum("bshd,hdm->bsm", out, p["wo"])
    return y, cache_k, cache_v


def _flash_decode(
    q, kn, vn, cache_k, cache_v, pos, ap: AttnParams, policy: Policy, mesh,
    *, ring: bool, seq_axes,
):
    """Flash-decode: the KV cache's sequence dim is sharded over ``seq_axes``
    (typically the model axis, plus data when batch < DP degree); each shard
    streams only its cache slice and partial softmax statistics are merged
    with a log-sum-exp psum — the collective is O(B·H·D), not O(S).

    The new token's K/V is written by exactly the shard owning its slot
    (predicated dynamic_update_slice). Queries/heads stay replicated across
    ``seq_axes`` — decode attention is cache-bandwidth-bound, and this keeps
    head counts free of divisibility constraints.
    """
    from jax.experimental.shard_map import shard_map

    b, one, h_, d_ = q.shape
    s_cache = cache_k.shape[1]
    batch = policy.batch_spec(b)
    axes = seq_axes if isinstance(seq_axes, tuple) else (seq_axes,)
    nshard = policy.size(axes)
    s_loc = s_cache // nshard
    scale = 1.0 / math.sqrt(ap.head_dim)
    gq = ap.n_heads // ap.n_kv

    def body(q_l, kn_l, vn_l, ck, cv, pos_l):
        bl = q_l.shape[0]  # local batch (sharded when batch covers data axes)
        # shard coordinate along the (possibly composite) seq axes
        idx = jnp.int32(0)
        for a in axes:
            idx = idx * policy.mesh_axes[a] + jax.lax.axis_index(a)
        offset = idx * s_loc
        pos_s = pos_l[0]
        slot = jnp.mod(pos_s, s_cache) if ring else pos_s
        lslot = jnp.clip(slot - offset, 0, s_loc - 1)
        in_range = (slot >= offset) & (slot < offset + s_loc)
        # predicated in-place write: out-of-range shards rewrite the
        # current value (a full-cache select would double the cache temps)
        cur_k = jax.lax.dynamic_slice_in_dim(ck, lslot, 1, axis=1)
        cur_v = jax.lax.dynamic_slice_in_dim(cv, lslot, 1, axis=1)
        up_k = jnp.where(in_range, kn_l.astype(ck.dtype), cur_k)
        up_v = jnp.where(in_range, vn_l.astype(cv.dtype), cur_v)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, up_k, lslot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, up_v, lslot, axis=1)

        qg = q_l.reshape(bl, 1, ap.n_kv, gq, ap.head_dim)
        sc = jnp.einsum(
            "bqkgd,bskd->bkgqs", qg, ck.astype(q_l.dtype)
        ).astype(jnp.float32) * scale  # (B, K, G, 1, S_loc)
        sc = softcap(sc, ap.softcap) if ap.softcap else sc
        gidx = offset + jnp.arange(s_loc)
        valid = gidx <= pos_s
        if not ring and ap.window is not None:
            valid &= gidx > pos_s - ap.window
        sc = jnp.where(valid[None, None, None, None, :], sc, -jnp.inf)
        m_loc = jnp.max(sc, axis=-1, keepdims=True)  # (B,K,G,1,1)
        m_safe = jnp.where(jnp.isfinite(m_loc), m_loc, 0.0)
        p_ = jnp.where(jnp.isfinite(sc), jnp.exp(sc - m_safe), 0.0)
        l_loc = jnp.sum(p_, axis=-1, keepdims=True)
        o_loc = jnp.einsum(
            "bkgqs,bskd->bkgqd", p_.astype(q_l.dtype), cv.astype(q_l.dtype)
        )
        # merge across shards
        m_g = jax.lax.pmax(m_safe, axes)
        corr = jnp.exp(m_safe - m_g)
        l_g = jax.lax.psum(l_loc * corr, axes)
        o_g = jax.lax.psum(o_loc * corr.astype(o_loc.dtype), axes)
        out = (o_g / jnp.maximum(l_g, 1e-30).astype(o_loc.dtype)).astype(q_l.dtype)
        return out.reshape(bl, 1, ap.n_heads, ap.head_dim), ck, cv

    cache_in = P(batch, seq_axes, None, None)
    rep = P(batch, None, None, None)
    out, ck, cv = shard_map(
        body,
        mesh=mesh,
        in_specs=(rep, rep, rep, cache_in, cache_in, P(None)),
        out_specs=(rep, cache_in, cache_in),
        check_rep=False,
    )(q, kn, vn, cache_k, cache_v, jnp.reshape(pos, (1,)))
    return out, ck, cv
