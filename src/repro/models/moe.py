"""Mixture-of-Experts FFN — expert parallelism via shard_map.

Design (DESIGN.md §4): activations are replicated across the ``model`` axis
(they are batch-sharded on the data axes), experts are sharded over
``model``. Each model shard therefore already *has* every token; it locally
selects the (token, k) pairs routed to its resident experts, computes them
under a capacity bound, and contributes a partial output. One
``psum('model')`` combines — the same collective volume as a standard
tensor-parallel FFN all-reduce, with zero dispatch all-to-all.

Inside the shard each expert's tokens are gathered into an (E_local, C, d)
buffer via a sort-free rank computation (searchsorted over the sorted
expert ids), the classic capacity-factor dispatch: tokens beyond C per
expert are dropped (their combine weight is zero).

arctic-style *dense residual*: a dense MLP runs in parallel with the MoE
and the two outputs are summed.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import _normal, wsc
from repro.models.policy import Policy

__all__ = ["MoEParams", "moe_ffn", "moe_init", "moe_pspecs"]


@dataclasses.dataclass(frozen=True)
class MoEParams:
    n_experts: int
    top_k: int
    d_ff: int  # per-expert hidden
    capacity_factor: float = 1.25
    dense_residual: bool = False  # arctic: dense MLP summed with MoE out
    router_aux_weight: float = 0.01


def moe_init(rng, L: int, d: int, mp: MoEParams, dtype) -> dict:
    ks = jax.random.split(rng, 5)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(mp.d_ff)
    p = {
        "router": _normal(ks[0], (L, d, mp.n_experts), s_in, jnp.float32),
        "w_in": _normal(ks[1], (L, mp.n_experts, d, mp.d_ff), s_in, dtype),
        "w_gate": _normal(ks[2], (L, mp.n_experts, d, mp.d_ff), s_in, dtype),
        "w_out": _normal(ks[3], (L, mp.n_experts, mp.d_ff, d), s_out, dtype),
    }
    return p


def moe_pspecs(policy: Policy, d: int, mp: MoEParams) -> dict:
    e = policy.tp(mp.n_experts)
    f = policy.fsdp(d, has_tp=e is not None)
    inner = policy.ep_inner(mp.d_ff)  # 2D EP: shard each expert's d_ff too
    return {
        "router": P(None, None, None),
        "w_in": P(None, e, f, inner),
        "w_gate": P(None, e, f, inner),
        "w_out": P(None, e, inner, f),
    }


def _capacity(mp: MoEParams, n_tokens: int) -> int:
    c = int(math.ceil(mp.top_k * n_tokens * mp.capacity_factor / mp.n_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly shapes


def _local_moe(
    x2: jax.Array,  # (T, d) local tokens (flattened batch*seq)
    probs: jax.Array,  # (T, E) fp32 router probabilities
    w_in: jax.Array,  # (E_loc, d, f)
    w_gate: jax.Array,
    w_out: jax.Array,  # (E_loc, f, d)
    *,
    mp: MoEParams,
    e_start: jax.Array,  # first global expert id on this shard
    capacity: int,
):
    """Per-shard expert compute. Returns the partial output (T, d)."""
    t, d = x2.shape
    e_loc = w_in.shape[0]
    topw, tope = jax.lax.top_k(probs, mp.top_k)  # (T, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)  # renormalize
    flat_e = tope.reshape(-1)  # (T*k,) global expert ids
    flat_w = topw.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), mp.top_k)

    # capacity rank within each expert (global ranks — identical on every
    # shard, so drops are consistent): sort by expert id, rank = position -
    # first-position-of-that-expert.
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    first = jnp.searchsorted(e_sorted, e_sorted, side="left")
    rank_sorted = jnp.arange(flat_e.shape[0]) - first
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)

    local_e = flat_e - e_start  # local expert index, valid iff in [0, e_loc)
    keep = (local_e >= 0) & (local_e < e_loc) & (rank < capacity)
    slot = jnp.where(keep, local_e * capacity + rank, e_loc * capacity)  # drop row

    # dispatch via token-id scatter: scatter (T*k,) int32 ids, then gather
    # only the (E_loc*C, d) rows that will actually be computed — this
    # avoids materializing the full (T*k, d) selection (12x the dispatch
    # traffic for top-8, EXPERIMENTS.md §Perf it-C1). Slot id T points at
    # an all-zero pad row.
    slot_tok = jnp.full((e_loc * capacity + 1,), t, jnp.int32)
    slot_tok = slot_tok.at[slot].set(jnp.where(keep, flat_tok, t))
    x2p = jnp.concatenate([x2, jnp.zeros((1, d), x2.dtype)])
    xe = x2p[slot_tok[:-1]].reshape(e_loc, capacity, d)

    h = jnp.einsum("ecd,edf->ecf", xe, w_in)
    g = jnp.einsum("ecd,edf->ecf", xe, w_gate)
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, w_out)

    # combine: gather slots back and weight
    ye_flat = jnp.concatenate([ye.reshape(e_loc * capacity, d), jnp.zeros((1, d), ye.dtype)])
    contrib = ye_flat[slot] * (flat_w * keep).astype(ye.dtype)[:, None]
    out = jnp.zeros((t, d), ye.dtype).at[flat_tok].add(contrib)
    return out


def moe_ffn(
    p: dict,
    x: jax.Array,  # (B, S, d)
    mp: MoEParams,
    policy: Policy,
    dense_mlp=None,  # callable(x) -> (B,S,d) for the arctic dense residual
):
    """MoE FFN with EP over the model axis. Returns (out, aux_loss)."""
    b, s, d = x.shape
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype)).astype(
        jnp.float32
    )
    probs = jax.nn.softmax(logits, axis=-1)
    # load-balancing aux loss (Switch): E * sum(frac_tokens * frac_probs)
    top1 = jnp.argmax(probs, axis=-1)
    frac_tok = jnp.mean(
        jax.nn.one_hot(top1, mp.n_experts, dtype=jnp.float32), axis=(0, 1)
    )
    frac_prob = jnp.mean(probs, axis=(0, 1))
    aux = mp.n_experts * jnp.sum(frac_tok * frac_prob) * mp.router_aux_weight

    tp = policy.tp_axis
    tp_size = policy.size(tp)
    mesh = policy.mesh_axes
    n_tokens = b * s  # global; per data-shard count below
    dp = policy.dp_degree
    capacity = _capacity(mp, max(n_tokens // max(dp, 1), 1))

    if tp is None or tp_size == 1 or mp.n_experts % max(tp_size, 1) != 0:
        # no EP: single-shard dispatch (test/smoke path)
        out = _local_moe(
            x.reshape(-1, d),
            probs.reshape(-1, mp.n_experts),
            p["w_in"],
            p["w_gate"],
            p["w_out"],
            mp=mp,
            e_start=jnp.int32(0),
            capacity=capacity,
        ).reshape(b, s, d)
    else:
        out = _ep_moe(x, probs, p, mp, policy, capacity)

    if mp.dense_residual and dense_mlp is not None:
        out = out + dense_mlp(x)
    return out, aux


def _ep_moe(x, probs, p, mp: MoEParams, policy: Policy, capacity: int):
    """shard_map over the full mesh: batch axes shard tokens, model axis
    shards experts; each shard computes its experts' partial sums, then
    psum over the model axis."""
    from jax.experimental.shard_map import shard_map

    b, s, d = x.shape
    mesh = policy._mesh_obj  # set by the model wrapper before tracing
    batch = policy.batch_spec(b)
    tp = policy.tp_axis
    e_loc = mp.n_experts // policy.size(tp)

    fsdp = policy.fsdp(d, has_tp=policy.tp(mp.n_experts) is not None)
    inner = policy.ep_inner(mp.d_ff)  # d_ff sharded within each expert
    inner_axes = (inner,) if isinstance(inner, str) else tuple(inner or ())
    if set(inner_axes) & set(policy.batch_axes):
        raise ValueError(
            "2D expert parallelism requires replicated tokens on the inner "
            f"axes; got inner={inner_axes} overlapping batch={policy.batch_axes}"
        )
    reduce_axes = (tp,) + inner_axes

    def body(x_l, probs_l, w_in, w_gate, w_out):
        if fsdp is not None:  # ZeRO-3: gather the expert weights' d dim
            w_in = jax.lax.all_gather(w_in, fsdp, axis=1, tiled=True)
            w_gate = jax.lax.all_gather(w_gate, fsdp, axis=1, tiled=True)
            w_out = jax.lax.all_gather(w_out, fsdp, axis=2, tiled=True)
        e_start = jax.lax.axis_index(tp) * e_loc
        bl, sl, dl = x_l.shape
        out = _local_moe(
            x_l.reshape(-1, dl),
            probs_l.reshape(-1, mp.n_experts),
            w_in,
            w_gate,
            w_out,
            mp=mp,
            e_start=e_start,
            capacity=capacity,
        )
        # partial over experts (tp) and, in 2D EP, over each expert's d_ff
        out = jax.lax.psum(out, reduce_axes)
        return out.reshape(bl, sl, dl)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(batch, None, None),
            P(batch, None, None),
            P(tp, fsdp, inner),
            P(tp, fsdp, inner),
            P(tp, inner, fsdp),
        ),
        out_specs=P(batch, None, None),
        check_rep=False,
    )(x, probs, p["w_in"], p["w_gate"], p["w_out"])
