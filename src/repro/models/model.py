"""Model assembler: ArchConfig -> scanned, policy-sharded transformer.

One class covers all ten assigned architectures:

* ``pattern`` cycles block kinds over depth — ``("attn",)`` dense,
  ``("local","attn")`` gemma2, ``("rec","rec","local")`` recurrentgemma,
  ``("ssm",)`` mamba2, ``("encdec",)`` whisper decoder.
* repeated groups are **scanned** (weights stacked on a leading ``groups``
  dim) so HLO size / compile time are depth-independent; a non-divisible
  tail gets its own short stack.
* every init function has a twin pspec function; ``param_pspecs`` mirrors
  ``init`` exactly (tree-structure equality is property-tested).
* caches are stacked per slot: attention KV, SSD state, RG-LRU state, and
  (whisper) precomputed cross-attention KV.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models.layers import AttnParams, wsc
from repro.models.moe import MoEParams, moe_ffn, moe_init, moe_pspecs
from repro.models.policy import Policy
from repro.models.rglru import (
    RGLRUParams,
    rglru_init,
    rglru_init_state,
    rglru_mixer,
    rglru_pspecs,
)
from repro.models.ssm import (
    SSMParams,
    ssm_init,
    ssm_init_state,
    ssm_mixer,
    ssm_pspecs,
)

__all__ = ["ArchConfig", "StreamModel"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    pattern: tuple[str, ...] = ("attn",)
    window: int | None = None
    attn_softcap: float | None = None
    final_softcap: float | None = None
    attn_bias: bool = False
    rope_theta: float = 10000.0
    mlp_kind: str = "gated"  # gated | plain | none
    mlp_act: str = "silu"
    norm: str = "rms"  # rms | ln
    norm_plus_one: bool = False
    post_norms: bool = False  # gemma2 sandwich norms
    embed_scale: bool = False
    tie_embeddings: bool = False
    moe: MoEParams | None = None
    ssm: SSMParams | None = None
    rglru: RGLRUParams | None = None
    enc_dec: bool = False
    enc_layers: int = 0
    enc_seq: int = 0
    frontend: str = "none"  # none | frames | patches
    frontend_len: int = 0
    norm_eps: float = 1e-6
    learned_pos: bool = False
    max_learned_pos: int = 32768
    q_block: int = 512

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab // 128) * 128

    def attn_params(self, kind: str) -> AttnParams:
        return AttnParams(
            n_heads=self.n_heads,
            n_kv=self.n_kv_heads,
            head_dim=self.hd,
            rope_theta=self.rope_theta,
            use_rope=not self.learned_pos,
            causal=kind != "bidir",
            window=self.window if kind == "local" else None,
            softcap=self.attn_softcap,
            bias=self.attn_bias,
            q_block=self.q_block,
            cross=kind == "cross",
        )

    # ------------------------------------------------------------ accounting
    def param_count(self) -> int:
        shapes = jax.eval_shape(
            lambda: StreamModel(self, Policy()).init(jax.random.PRNGKey(0))
        )
        return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        total = self.param_count()
        if self.moe is None:
            return total
        per_expert = 3 * self.d_model * self.moe.d_ff
        moe_total = self.n_layers * self.moe.n_experts * per_expert
        moe_active = self.n_layers * self.moe.top_k * per_expert
        return total - moe_total + moe_active


_Q8_MIN_SIZE = 1 << 16


def _is_q8(x) -> bool:
    return isinstance(x, dict) and "q8" in x


def _should_quantize(leaf) -> bool:
    return (
        hasattr(leaf, "ndim")
        and leaf.ndim >= 2
        and jnp.issubdtype(leaf.dtype, jnp.floating)
        and int(np.prod(leaf.shape)) >= _Q8_MIN_SIZE
    )


_Q8_SUBTREES = ("slots", "tail", "encoder")


def quantize_params(params):
    """Post-training int8 weight quantization for serving (DESIGN.md §4).

    Every large (>=64Ki elements) float matrix inside the layer stacks
    becomes {"q8": int8 codes, "scale": fp32 per-row (trailing-dim absmax)
    scales}. Per-row quantization makes dequantization a pure broadcast
    multiply — no reshape — so it is transparent to ANY sharding (a
    256-block variant forced XLA to replicate arctic's expert weights:
    +88 GB/dev of all-gather, EXPERIMENTS.md §Perf it-B1). Embeddings and
    norms stay bf16. Halves (vs bf16) the weight-streaming memory term —
    and makes arctic-480b / mistral-large-123b decode fit v5e HBM.
    """

    def one(leaf):
        if not _should_quantize(leaf):
            return leaf
        x = leaf.astype(jnp.float32)
        scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
        safe = jnp.where(scale == 0, 1.0, scale)
        codes = jnp.clip(jnp.round(x / safe), -127, 127).astype(jnp.int8)
        return {"q8": codes, "scale": scale}

    out = dict(params)
    for key in _Q8_SUBTREES:
        if key in out:
            out[key] = jax.tree.map(one, out[key])
    return out


def quantized_pspecs(params_sds, pspecs):
    """Transform a param pspec tree to match ``quantize_params`` output."""
    from jax.sharding import PartitionSpec

    def one(leaf, spec):
        if not _should_quantize(leaf):
            return spec
        base = tuple(spec) + (None,) * (leaf.ndim - len(spec))
        return {"q8": spec, "scale": PartitionSpec(*base[:-1], None)}

    out = dict(pspecs)
    for key in _Q8_SUBTREES:
        if key in out:
            out[key] = jax.tree.map(one, params_sds[key], pspecs[key])
    return out


def _dq_leaf(leaf, dtype):
    if _is_q8(leaf):
        # broadcast multiply: sharding-transparent, fuses into the matmul
        return (leaf["q8"].astype(jnp.float32) * leaf["scale"]).astype(dtype)
    return leaf


def _dq_tree(tree, dtype):
    return jax.tree.map(
        lambda x: _dq_leaf(x, dtype), tree, is_leaf=_is_q8
    )


def _norm_init(L_: int, d: int, norm: str, dtype):
    if norm == "ln":
        return {"w": jnp.ones((L_, d), dtype), "b": jnp.zeros((L_, d), dtype)}
    init = jnp.zeros if False else jnp.ones
    return {"w": jnp.ones((L_, d), dtype)}


def _norm_pspecs(norm: str):
    return {"w": P(None, None), "b": P(None, None)} if norm == "ln" else {"w": P(None, None)}


class StreamModel:
    """Functional model wrapper; all state is explicit."""

    def __init__(self, cfg: ArchConfig, policy: Policy, mesh=None):
        self.cfg = cfg
        self.policy = policy
        if mesh is not None:
            object.__setattr__(policy, "_mesh_obj", mesh)
        self.mesh = mesh
        p = len(cfg.pattern)
        self.n_groups = cfg.n_layers // p
        self.tail = cfg.n_layers - self.n_groups * p  # leftover layers

    # ------------------------------------------------------------------ init
    def _block_init(self, rng, n: int, kind: str, dtype) -> dict:
        cfg = self.cfg
        ks = jax.random.split(rng, 8)
        blk: dict[str, Any] = {"norm1": _norm_init(n, cfg.d_model, cfg.norm, dtype)}
        if kind in ("attn", "local", "bidir"):
            blk["mixer"] = L.attention_init(ks[0], n, cfg.d_model, cfg.attn_params(kind), dtype)
        elif kind == "ssm":
            blk["mixer"] = ssm_init(ks[0], n, cfg.d_model, cfg.ssm, dtype)
        elif kind == "rec":
            blk["mixer"] = rglru_init(ks[0], n, cfg.d_model, cfg.rglru, dtype)
        elif kind == "encdec":
            blk["mixer"] = L.attention_init(ks[0], n, cfg.d_model, cfg.attn_params("attn"), dtype)
            blk["norm_x"] = _norm_init(n, cfg.d_model, cfg.norm, dtype)
            blk["cross"] = L.attention_init(ks[3], n, cfg.d_model, cfg.attn_params("cross"), dtype)
        else:
            raise ValueError(f"unknown block kind {kind}")
        if cfg.post_norms:
            blk["post1"] = _norm_init(n, cfg.d_model, cfg.norm, dtype)
        if cfg.mlp_kind != "none" or cfg.moe is not None:
            blk["norm2"] = _norm_init(n, cfg.d_model, cfg.norm, dtype)
            if cfg.moe is not None:
                blk["moe"] = moe_init(ks[1], n, cfg.d_model, cfg.moe, dtype)
                if cfg.moe.dense_residual:
                    blk["mlp"] = L.mlp_init(ks[2], n, cfg.d_model, cfg.d_ff, "gated", dtype)
            else:
                blk["mlp"] = L.mlp_init(
                    ks[2], n, cfg.d_model, cfg.d_ff, "gated" if cfg.mlp_kind == "gated" else "plain", dtype
                )
            if cfg.post_norms:
                blk["post2"] = _norm_init(n, cfg.d_model, cfg.norm, dtype)
        return blk

    def _block_pspecs(self, kind: str) -> dict:
        cfg, pol = self.cfg, self.policy
        blk: dict[str, Any] = {"norm1": _norm_pspecs(cfg.norm)}
        if kind in ("attn", "local", "bidir"):
            blk["mixer"] = L.attention_pspecs(pol, cfg.d_model, cfg.attn_params(kind))
        elif kind == "ssm":
            blk["mixer"] = ssm_pspecs(pol, cfg.d_model, cfg.ssm)
        elif kind == "rec":
            blk["mixer"] = rglru_pspecs(pol, cfg.d_model, cfg.rglru)
        elif kind == "encdec":
            blk["mixer"] = L.attention_pspecs(pol, cfg.d_model, cfg.attn_params("attn"))
            blk["norm_x"] = _norm_pspecs(cfg.norm)
            blk["cross"] = L.attention_pspecs(pol, cfg.d_model, cfg.attn_params("cross"))
        if cfg.post_norms:
            blk["post1"] = _norm_pspecs(cfg.norm)
        if cfg.mlp_kind != "none" or cfg.moe is not None:
            blk["norm2"] = _norm_pspecs(cfg.norm)
            if cfg.moe is not None:
                blk["moe"] = moe_pspecs(pol, cfg.d_model, cfg.moe)
                if cfg.moe.dense_residual:
                    blk["mlp"] = L.mlp_pspecs(pol, cfg.d_model, cfg.d_ff, "gated")
            else:
                blk["mlp"] = L.mlp_pspecs(
                    pol, cfg.d_model, cfg.d_ff, "gated" if cfg.mlp_kind == "gated" else "plain"
                )
            if cfg.post_norms:
                blk["post2"] = _norm_pspecs(cfg.norm)
        return blk

    def init(self, rng) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(self.policy.param_dtype)
        ks = jax.random.split(rng, 8)
        params: dict[str, Any] = {
            "embed": L.embed_init(ks[0], cfg.vocab_padded, cfg.d_model, dtype),
            "final_norm": _norm_init(1, cfg.d_model, cfg.norm, dtype),
        }
        params["slots"] = {
            f"s{i}": self._block_init(jax.random.fold_in(ks[1], i), self.n_groups, k, dtype)
            for i, k in enumerate(cfg.pattern)
        }
        if self.tail:
            params["tail"] = {
                f"s{i}": self._block_init(jax.random.fold_in(ks[2], i), 1, cfg.pattern[i], dtype)
                for i in range(self.tail)
            }
        if not cfg.tie_embeddings:
            params["unembed"] = L._normal(
                ks[3], (cfg.d_model, cfg.vocab_padded), 1.0 / math.sqrt(cfg.d_model), dtype
            )
        if cfg.learned_pos:
            params["pos_embed"] = L._normal(
                ks[4], (cfg.max_learned_pos, cfg.d_model), 0.02, dtype
            )
        if cfg.enc_dec:
            params["encoder"] = {
                "slots": {
                    "s0": self._block_init(ks[5], cfg.enc_layers, "bidir", dtype)
                },
                "final_norm": _norm_init(1, cfg.d_model, cfg.norm, dtype),
            }
        return params

    def param_pspecs(self) -> dict:
        cfg, pol = self.cfg, self.policy
        vtp = pol.tp(cfg.vocab_padded)
        specs: dict[str, Any] = {
            "embed": P(vtp, pol.fsdp(cfg.d_model, has_tp=vtp is not None)),
            "final_norm": _norm_pspecs(cfg.norm),
        }
        specs["slots"] = {
            f"s{i}": self._block_pspecs(k) for i, k in enumerate(cfg.pattern)
        }
        if self.tail:
            specs["tail"] = {
                f"s{i}": self._block_pspecs(cfg.pattern[i]) for i in range(self.tail)
            }
        if not cfg.tie_embeddings:
            specs["unembed"] = P(
                pol.fsdp(cfg.d_model, has_tp=vtp is not None), vtp
            )
        if cfg.learned_pos:
            specs["pos_embed"] = P(None, pol.fsdp(cfg.d_model))
        if cfg.enc_dec:
            specs["encoder"] = {
                "slots": {"s0": self._block_pspecs("bidir")},
                "final_norm": _norm_pspecs(cfg.norm),
            }
        return specs

    # ----------------------------------------------------------------- norms
    def _norm(self, p, x):
        if self.cfg.norm == "ln":
            return L.layer_norm(x, p["w"], p["b"], self.cfg.norm_eps)
        return L.rms_norm(x, p["w"], self.cfg.norm_eps, plus_one=self.cfg.norm_plus_one)

    # ------------------------------------------------------------ full-seq fwd
    def _apply_block(
        self, blk: dict, kind: str, x, positions, enc_out=None, state=None
    ):
        """One block; params have NO leading group dim here. Returns (x, new_state)."""
        cfg, pol = self.cfg, self.policy
        if pol.weights_int8:
            blk = _dq_tree(blk, jnp.dtype(pol.compute_dtype))
        h = self._norm(blk["norm1"], x)
        new_state = state
        decode = state is not None and x.shape[1] == 1
        if kind in ("attn", "local", "bidir"):
            ap = cfg.attn_params(kind)
            if decode:
                if "bt" in state:  # paged cache (init_paged_cache)
                    out, nk, nv = L.paged_decode_attention(
                        blk["mixer"], h, state["k"], state["v"],
                        state["pos"], state["bt"], ap, pol,
                    )
                    new_state = {
                        "k": nk, "v": nv, "pos": state["pos"] + 1,
                        "bt": state["bt"],
                    }
                else:
                    out, nk, nv = L.decode_attention(
                        blk["mixer"], h, state["k"], state["v"], state["pos"], ap, pol,
                        ring=kind == "local",
                        cache_seq_spec=pol.seq_axis,
                    )
                    new_state = {"k": nk, "v": nv, "pos": state["pos"] + 1}
            elif state is not None:  # prefill: fill the cache while attending
                out, k, v = L.attention(blk["mixer"], h, ap, pol, positions, return_kv=True)
                new_state = _fill_kv_cache(state, k, v)
            else:
                out = L.attention(blk["mixer"], h, ap, pol, positions)
        elif kind == "ssm":
            out, new_state = ssm_mixer(blk["mixer"], h, cfg.ssm, pol, state, cfg.norm_eps)
        elif kind == "rec":
            out, new_state = rglru_mixer(blk["mixer"], h, cfg.rglru, pol, state)
        elif kind == "encdec":
            ap = cfg.attn_params("attn")
            if decode:
                out, nk, nv = L.decode_attention(
                    blk["mixer"], h, state["k"], state["v"], state["pos"], ap, pol,
                    cache_seq_spec=pol.seq_axis,
                )
                new_state = dict(state, k=nk, v=nv, pos=state["pos"] + 1)
            elif state is not None:
                out, k, v = L.attention(blk["mixer"], h, ap, pol, positions, return_kv=True)
                new_state = dict(state, **_fill_kv_cache(state, k, v))
            else:
                out = L.attention(blk["mixer"], h, ap, pol, positions)
            x = x + (self._norm(blk["post1"], out) if cfg.post_norms else out)
            hx = self._norm(blk["norm_x"], x)
            capx = cfg.attn_params("cross")
            if decode:
                out, _, _ = L.decode_attention(
                    blk["cross"], hx, state["xk"], state["xv"], state["pos"], capx, pol
                )
            else:
                out = L.attention(blk["cross"], hx, capx, pol, positions, kv_source=enc_out)
                if state is not None:  # cache the encoder projections once
                    xk = jnp.einsum("bsd,dhk->bshk", enc_out, blk["cross"]["wk"])
                    xv = jnp.einsum("bsd,dhk->bshk", enc_out, blk["cross"]["wv"])
                    new_state = dict(new_state, xk=xk.astype(state["xk"].dtype), xv=xv.astype(state["xv"].dtype))
            x = x + out
            out = None
        if out is not None:
            x = x + (self._norm(blk["post1"], out) if cfg.post_norms else out)

        aux = jnp.float32(0.0)
        if cfg.mlp_kind != "none" or cfg.moe is not None:
            h2 = self._norm(blk["norm2"], x)
            if cfg.moe is not None:
                dense = (
                    (lambda t: L.mlp(blk["mlp"], t, "gated", cfg.mlp_act))
                    if cfg.moe.dense_residual
                    else None
                )
                y, aux = moe_ffn(blk["moe"], h2, cfg.moe, pol, dense_mlp=dense)
            else:
                y = L.mlp(blk["mlp"], h2, "gated" if cfg.mlp_kind == "gated" else "plain", cfg.mlp_act)
            x = x + (self._norm(blk["post2"], y) if cfg.post_norms else y)
        return x, new_state, aux

    def _run_stack(self, params, x, positions, enc_out=None, caches=None):
        """Scan the grouped stack (+tail). caches: None (train/prefill w/o
        cache) or dict of stacked per-slot states; returns (x, new_caches, aux)."""
        cfg = self.cfg
        pat = cfg.pattern
        use_cache = caches is not None

        def group_body(carry, xs):
            xc, aux_acc = carry
            blkstack, cache_in = xs
            new_cache = {}
            for i, kind in enumerate(pat):
                st = cache_in.get(f"s{i}") if use_cache else None
                xc, nst, aux = self._apply_block(
                    {k: v for k, v in blkstack[f"s{i}"].items()}, kind, xc, positions, enc_out, st
                )
                if use_cache:
                    new_cache[f"s{i}"] = nst
            return (xc, aux_acc + aux), new_cache if use_cache else 0.0

        body = group_body
        if self.policy.remat in ("block", "full"):
            body = jax.checkpoint(
                group_body,
                policy=jax.checkpoint_policies.nothing_saveable
                if self.policy.remat == "full"
                else jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                prevent_cse=False,
            )

        slot_stacks = params["slots"]
        cache_stacks = caches["slots"] if use_cache else jax.tree.map(lambda _: 0.0, jnp.zeros(self.n_groups))
        xs = (slot_stacks, caches["slots"] if use_cache else None)
        if self.n_groups > 0:
            unroll = True if self.policy.unroll else 1
            if use_cache:
                (x, aux), new_slot_caches = jax.lax.scan(
                    body, (x, jnp.float32(0.0)), (slot_stacks, caches["slots"]),
                    unroll=unroll,
                )
            else:
                dummy = jnp.zeros((self.n_groups,))
                (x, aux), _ = jax.lax.scan(
                    lambda c, xs_: (body(c, (xs_[0], {}))[0], 0.0),
                    (x, jnp.float32(0.0)),
                    (slot_stacks, dummy),
                    unroll=unroll,
                )
                new_slot_caches = None
        else:
            aux = jnp.float32(0.0)
            new_slot_caches = caches["slots"] if use_cache else None

        new_caches = {"slots": new_slot_caches} if use_cache else None
        # tail layers (pattern remainder), unscanned
        if self.tail:
            new_tail = {}
            for i in range(self.tail):
                blk = jax.tree.map(lambda a: a[0], params["tail"][f"s{i}"])
                st = caches["tail"][f"s{i}"] if use_cache else None
                x, nst, a2 = self._apply_block(blk, pat[i], x, positions, enc_out, st)
                aux = aux + a2
                if use_cache:
                    new_tail[f"s{i}"] = nst
            if use_cache:
                new_caches["tail"] = new_tail
        return x, new_caches, aux

    # ------------------------------------------------------------- embeddings
    def _embed_tokens(self, params, tokens):
        cfg = self.cfg
        embed = _dq_leaf(params["embed"], jnp.dtype(self.policy.compute_dtype))
        x = jnp.take(embed, tokens, axis=0)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        return x

    def _logits(self, params, x):
        cfg = self.cfg
        dt = jnp.dtype(self.policy.compute_dtype)
        x = self._norm(jax.tree.map(lambda a: a[0], params["final_norm"]), x)
        w = (
            _dq_leaf(params["embed"], dt).T
            if cfg.tie_embeddings
            else _dq_leaf(params.get("unembed"), dt)
        )
        logits = jnp.einsum("bsd,dv->bsv", x, w)
        logits = L.softcap(logits, cfg.final_softcap)
        pol = self.policy
        return wsc(
            logits.astype(jnp.float32),
            P(pol.batch_spec(x.shape[0]), None, pol.tp(cfg.vocab_padded)),
        )

    def _encode(self, params, frames):
        """Whisper encoder over precomputed frame embeddings (stub frontend)."""
        cfg = self.cfg
        pos = jnp.arange(frames.shape[1])
        frames = frames.astype(jnp.dtype(self.policy.compute_dtype))
        x = frames + _sinusoid(frames.shape[1], cfg.d_model, frames.dtype)
        enc = params["encoder"]
        x, _, _ = StreamModel(
            dataclasses.replace(cfg, pattern=("bidir",), n_layers=cfg.enc_layers, moe=None, enc_dec=False),
            self.policy,
            self.mesh,
        )._run_stack(enc, x, pos)
        return self._norm(jax.tree.map(lambda a: a[0], enc["final_norm"]), x)

    # ------------------------------------------------------------- public API
    def forward(self, params, batch):
        """Full forward to logits. batch: tokens (B,S) [+ patch_embeds | frames]."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed_tokens(params, tokens)
        if cfg.frontend == "patches":
            x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
        s = x.shape[1]
        positions = jnp.arange(s)
        if cfg.learned_pos:
            x = x + params["pos_embed"][:s][None]
        x = wsc(x, P(self.policy.batch_spec(x.shape[0]), None, None))
        enc_out = self._encode(params, batch["frames"]) if cfg.enc_dec else None
        x, _, aux = self._run_stack(params, x, positions, enc_out)
        return self._logits(params, x), aux

    def hidden(self, params, batch):
        """Forward to final hidden states (pre-unembed). Returns (h, aux)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed_tokens(params, tokens)
        if cfg.frontend == "patches":
            x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
        s = x.shape[1]
        positions = jnp.arange(s)
        if cfg.learned_pos:
            x = x + params["pos_embed"][:s][None]
        x = wsc(x, P(self.policy.batch_spec(x.shape[0]), None, None))
        enc_out = self._encode(params, batch["frames"]) if cfg.enc_dec else None
        x, _, aux = self._run_stack(params, x, positions, enc_out)
        return x, aux

    def loss(self, params, batch, *, loss_chunk: int = 1024):
        """Next-token CE with **chunked** unembed+softmax.

        Full-vocab logits for a (256, 4096) batch over a 256k vocab are
        ~0.5 TB in bf16 (1 TB fp32) — they must never be materialized.
        The unembed matmul + logsumexp + pick run inside a checkpointed
        scan over sequence chunks, so the live set is one
        (B, chunk, vocab) block; the backward recomputes per chunk.
        The label pick is a one-hot einsum (vocab-sharded friendly: partial
        sums + a tiny psum, never a cross-shard gather).
        """
        cfg = self.cfg
        h, aux = self.hidden(params, batch)
        h = self._norm(jax.tree.map(lambda a: a[0], params["final_norm"]), h)
        tokens = batch["tokens"].astype(jnp.int32)
        front = cfg.frontend_len if cfg.frontend == "patches" else 0
        pred_h = h[:, front:-1] if front == 0 else h[:, front - 1 : -1]
        labels = tokens[:, 1:] if front == 0 else tokens
        b, n, d = pred_h.shape
        w = params["embed"].T if cfg.tie_embeddings else params["unembed"]

        chunk = min(loss_chunk, n)
        n_main = (n // chunk) * chunk
        pol = self.policy
        vspec = pol.tp(cfg.vocab_padded)

        def chunk_nll(hc, lc):
            logits = jnp.einsum("bsd,dv->bsv", hc, w)
            logits = L.softcap(logits, cfg.final_softcap)
            logits = wsc(logits, P(pol.batch_spec(b), None, vspec))
            logits = logits.astype(jnp.float32)
            mask = (lc < cfg.vocab).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            onehot = jax.nn.one_hot(lc, cfg.vocab_padded, dtype=logits.dtype)
            picked = jnp.einsum("bsv,bsv->bs", logits, onehot)
            return jnp.sum((lse - picked) * mask), jnp.sum(mask)

        chunk_nll = jax.checkpoint(chunk_nll, prevent_cse=False)

        def scan_body(carry, xs):
            hc, lc = xs
            nll, cnt = chunk_nll(hc, lc)
            return (carry[0] + nll, carry[1] + cnt), None

        hc_main = pred_h[:, :n_main].reshape(b, n_main // chunk, chunk, d)
        lc_main = labels[:, :n_main].reshape(b, n_main // chunk, chunk)
        (tot, cnt), _ = jax.lax.scan(
            scan_body,
            (jnp.float32(0.0), jnp.float32(0.0)),
            (jnp.moveaxis(hc_main, 1, 0), jnp.moveaxis(lc_main, 1, 0)),
            unroll=True if pol.unroll else 1,
        )
        if n_main < n:  # ragged tail
            nll_t, cnt_t = chunk_nll(pred_h[:, n_main:], labels[:, n_main:])
            tot, cnt = tot + nll_t, cnt + cnt_t
        loss = tot / jnp.maximum(cnt, 1.0)
        return loss + aux, {"loss": loss, "aux": aux}

    # ------------------------------------------------------------------ cache
    def _slot_cache(self, kind: str, n: int, b: int, s_cache: int, dtype):
        cfg = self.cfg

        def stack(tree):
            return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), tree)

        if kind in ("attn", "local"):
            sz = min(cfg.window, s_cache) if kind == "local" and cfg.window else s_cache
            kv = jnp.zeros((b, sz, cfg.n_kv_heads, cfg.hd), dtype)
            return stack({"k": kv, "v": kv, "pos": jnp.int32(0)})
        if kind == "ssm":
            return stack(ssm_init_state(b, cfg.ssm))
        if kind == "rec":
            return stack(rglru_init_state(b, cfg.rglru))
        if kind == "encdec":
            kv = jnp.zeros((b, s_cache, cfg.n_kv_heads, cfg.hd), dtype)
            xkv = jnp.zeros((b, cfg.enc_seq, cfg.n_kv_heads, cfg.hd), dtype)
            return stack({"k": kv, "v": kv, "pos": jnp.int32(0), "xk": xkv, "xv": xkv})
        raise ValueError(kind)

    def init_cache(self, batch_size: int, s_cache: int, dtype=None):
        if dtype is None:
            dtype = jnp.dtype(self.policy.kv_cache_dtype)
        pat = self.cfg.pattern
        caches = {
            "slots": {
                f"s{i}": self._slot_cache(k, self.n_groups, batch_size, s_cache, dtype)
                for i, k in enumerate(pat)
            }
        }
        if self.tail:
            caches["tail"] = {
                f"s{i}": jax.tree.map(
                    lambda a: a[0], self._slot_cache(pat[i], 1, batch_size, s_cache, dtype)
                )
                for i in range(self.tail)
            }
        return caches

    # ------------------------------------------------------------ paged cache
    # Blocked/paged KV layout for continuous batching (DESIGN.md §13):
    # one physical pool of (n_blocks, block_size) KV blocks per layer
    # group — no batch dim — plus per-row int32 positions and block
    # tables. Rows with different prompt lengths share the pool without
    # fragmentation; block 0 is the reserved scratch target for idle
    # rows' discarded writes.

    def init_paged_cache(
        self, batch_size: int, n_blocks: int, block_size: int,
        max_blocks: int, dtype=None,
    ):
        """Paged decode cache: physical block pool + per-row block tables.

        Supports dense-attention patterns only (window/ring, SSM, RG-LRU
        and enc-dec states are per-slot recurrences with no paging story
        yet — recorded follow-up).
        """
        cfg = self.cfg
        if any(k != "attn" for k in cfg.pattern):
            raise NotImplementedError(
                f"paged KV cache supports dense 'attn' patterns only "
                f"(got {cfg.pattern!r})"
            )
        if dtype is None:
            dtype = jnp.dtype(self.policy.kv_cache_dtype)

        def slot(n: int):
            kv = jnp.zeros((n_blocks, block_size, cfg.n_kv_heads, cfg.hd), dtype)
            state = {
                "k": kv, "v": kv,
                "pos": jnp.zeros((batch_size,), jnp.int32),
                "bt": jnp.zeros((batch_size, max_blocks), jnp.int32),
            }
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), state
            )

        caches = {
            "slots": {f"s{i}": slot(self.n_groups) for i in range(len(cfg.pattern))}
        }
        if self.tail:
            caches["tail"] = {
                f"s{i}": jax.tree.map(lambda a: a[0], slot(1))
                for i in range(self.tail)
            }
        return caches

    def paged_insert(self, caches, small_caches, row, block_ids, bt_row, plen):
        """Admit one prefilled request into a paged cache.

        ``small_caches`` is a batch-1 contiguous cache from
        :meth:`prefill` with ``s_cache`` padded to ``len(block_ids) *
        block_size``; its K/V splits into whole blocks scattered to the
        physical ids in ``block_ids``. ``bt_row`` is the row's full block
        table (reserved ids first — including growth blocks the prompt
        has not reached — zero-padded), ``plen`` the prompt length that
        becomes the row's position. Row/scalar args may be traced;
        ``block_ids``' length is static per prompt-length bucket.
        """
        nb = len(block_ids)

        def insert(dst, src, grouped: bool):
            blk = dst["k"].shape[-3]  # block_size dim
            if grouped:
                ng = dst["k"].shape[0]
                src_k = src["k"][:, 0].reshape(ng, nb, blk, *dst["k"].shape[-2:])
                src_v = src["v"][:, 0].reshape(ng, nb, blk, *dst["v"].shape[-2:])
                nk = dst["k"].at[:, block_ids].set(src_k.astype(dst["k"].dtype))
                nv = dst["v"].at[:, block_ids].set(src_v.astype(dst["v"].dtype))
                pos = dst["pos"].at[:, row].set(plen)
                bt = dst["bt"].at[:, row].set(bt_row)
            else:
                src_k = src["k"][0].reshape(nb, blk, *dst["k"].shape[-2:])
                src_v = src["v"][0].reshape(nb, blk, *dst["v"].shape[-2:])
                nk = dst["k"].at[block_ids].set(src_k.astype(dst["k"].dtype))
                nv = dst["v"].at[block_ids].set(src_v.astype(dst["v"].dtype))
                pos = dst["pos"].at[row].set(plen)
                bt = dst["bt"].at[row].set(bt_row)
            return {"k": nk, "v": nv, "pos": pos, "bt": bt}

        out = {
            "slots": {
                key: insert(caches["slots"][key], small_caches["slots"][key], True)
                for key in caches["slots"]
            }
        }
        if "tail" in caches:
            out["tail"] = {
                key: insert(caches["tail"][key], small_caches["tail"][key], False)
                for key in caches["tail"]
            }
        return out

    def paged_clear(self, caches, row):
        """Recycle one slot: zero its position and block table so its
        subsequent (idle) writes land in the scratch block. The K/V
        blocks themselves need no zeroing — the validity mask hides
        them, and the freed physical ids return to the allocator."""

        def clear(dst, grouped: bool):
            if grouped:
                pos = dst["pos"].at[:, row].set(0)
                bt = dst["bt"].at[:, row].set(0)
            else:
                pos = dst["pos"].at[row].set(0)
                bt = dst["bt"].at[row].set(0)
            return dict(dst, pos=pos, bt=bt)

        out = {
            "slots": {
                key: clear(caches["slots"][key], True) for key in caches["slots"]
            }
        }
        if "tail" in caches:
            out["tail"] = {
                key: clear(caches["tail"][key], False) for key in caches["tail"]
            }
        return out

    def cache_pspecs(self, batch_size: int):
        pol, cfg = self.policy, self.cfg
        batch = pol.batch_spec(batch_size)
        seq = pol.seq_axis
        kv_tp = pol.tp(cfg.n_kv_heads)

        def attn_spec():
            return {
                "k": P(None, batch, seq, kv_tp, None),
                "v": P(None, batch, seq, kv_tp, None),
                "pos": P(None),
            }

        def slot_spec(kind):
            if kind in ("attn", "local"):
                return attn_spec()
            if kind == "ssm":
                return {
                    "conv": P(None, batch, None, pol.tp(cfg.ssm.d_inner)),
                    "ssd": P(None, batch, pol.tp(cfg.ssm.n_heads), None, None),
                }
            if kind == "rec":
                return {
                    "conv": P(None, batch, None, pol.tp(cfg.rglru.d_rnn)),
                    "h": P(None, batch, pol.tp(cfg.rglru.d_rnn)),
                }
            if kind == "encdec":
                sp = attn_spec()
                sp["xk"] = P(None, batch, None, kv_tp, None)
                sp["xv"] = P(None, batch, None, kv_tp, None)
                return sp
            raise ValueError(kind)

        specs = {"slots": {f"s{i}": slot_spec(k) for i, k in enumerate(cfg.pattern)}}
        if self.tail:
            specs["tail"] = {
                f"s{i}": jax.tree.map(
                    lambda sp: P(*sp[1:]), slot_spec(cfg.pattern[i]), is_leaf=lambda x: isinstance(x, P)
                )
                for i in range(self.tail)
            }
        return specs

    def prefill(self, params, batch, s_cache: int, cache_dtype=jnp.bfloat16):
        """Run the full prompt, populate a cache of size s_cache, return the
        last-position logits — the serving engine's first step."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b = tokens.shape[0]
        x = self._embed_tokens(params, tokens)
        if cfg.frontend == "patches":
            x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
        s = x.shape[1]
        positions = jnp.arange(s)
        if cfg.learned_pos:
            x = x + params["pos_embed"][:s][None]
        x = wsc(x, P(self.policy.batch_spec(b), None, None))
        enc_out = self._encode(params, batch["frames"]) if cfg.enc_dec else None
        caches = self.init_cache(b, s_cache, cache_dtype)
        x, new_caches, _ = self._run_stack(params, x, positions, enc_out, caches)
        return self._logits(params, x[:, -1:, :])[:, 0], new_caches

    def decode_step(self, params, caches, tokens, pos):
        """One decode step. tokens: (B, 1) int32; pos: scalar int32
        position, or (B,) per-row positions (continuous batching — each
        slot decodes at its own depth)."""
        cfg = self.cfg
        x = self._embed_tokens(params, tokens)
        pos = jnp.asarray(pos, jnp.int32)
        if cfg.learned_pos:
            if pos.ndim == 1:
                x = x + jnp.take(params["pos_embed"], pos, axis=0)[:, None]
            else:
                x = x + jax.lax.dynamic_slice_in_dim(params["pos_embed"], pos, 1, 0)[None]
        positions = jnp.reshape(pos, (-1,))
        x = wsc(x, P(self.policy.batch_spec(x.shape[0]), None, None))
        x, new_caches, _ = self._run_stack(params, x, positions, None, caches)
        return self._logits(params, x), new_caches


def _fill_kv_cache(state, k, v):
    """Write prefill K/V (B,S,Kv,D) into a cache buffer (B,sz,Kv,D).

    For window (ring) caches sz < S: keep the last sz positions, rotated so
    that slot == position % sz, matching the decode-time ring writes.
    """
    sz = state["k"].shape[1]
    b, s = k.shape[0], k.shape[1]
    if s >= sz:
        k_last = k[:, s - sz :]
        v_last = v[:, s - sz :]
        shift = s % sz
        if shift:
            k_last = jnp.roll(k_last, shift, axis=1)
            v_last = jnp.roll(v_last, shift, axis=1)
        nk, nv = k_last.astype(state["k"].dtype), v_last.astype(state["v"].dtype)
    else:
        nk = jax.lax.dynamic_update_slice_in_dim(state["k"], k.astype(state["k"].dtype), 0, axis=1)
        nv = jax.lax.dynamic_update_slice_in_dim(state["v"], v.astype(state["v"].dtype), 0, axis=1)
    return {"k": nk, "v": nv, "pos": jnp.int32(s)}


def _sinusoid(s: int, d: int, dtype):
    pos = np.arange(s)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / d)
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, dtype)[None]
