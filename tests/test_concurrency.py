"""Concurrent data plane: per-partition locking, the replication daemon,
follower reads, and the upper-layer parallelism that rides on them.

Fast tier: follower reads never surface records above the high watermark;
the background daemon advances HWs and completes deferred elections;
prefetch iterators preserve order and propagate errors; the stable
partitioner pins known key→partition mappings; parallel produce/ingest/
poll paths stay correct. Slow tier: a producer×consumer stress test
asserting no lost or duplicated offsets and HW monotonicity under real
thread interleavings.
"""

import threading
import time

import numpy as np
import pytest

import repro.core as core
import repro.data as data
from repro.core.cluster import (
    BrokerCluster,
    ClusterConsumer,
    ClusterProducer,
    NotLeaderError,
    ReplicationService,
)
from repro.core.consumer import ConsumerGroup
from repro.core.log import LogConfig, StreamLog, TopicPartition, default_partition
from repro.data.formats import RawCodec
from repro.data.pipeline import BatchIterator, PrefetchIterator, prefetch_iter


def wait_until(cond, timeout=10.0, interval=0.005, msg="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return
        time.sleep(interval)
    raise AssertionError(f"timed out waiting for {msg}")


def make_cluster(parts=2, **kw):
    c = BrokerCluster(3, default_acks="all", **kw)
    c.create_topic("t", LogConfig(num_partitions=parts, replication_factor=3))
    return c


# ------------------------------------------------------- stable partitioner
class TestStablePartitioner:
    def test_known_key_to_partition_mappings_pinned(self):
        """CRC32 key routing is a cross-process contract: these mappings
        must never change (Python's salted hash() would shift them every
        run)."""
        pinned = {
            b"k": 1, b"key-0": 0, b"key-1": 2, b"key-2": 0,
            b"alpha": 2, b"beta": 3,
        }
        for key, part in pinned.items():
            assert default_partition([key], 4, 0) == part, key

    def test_same_key_same_partition_on_log_and_cluster(self):
        log = StreamLog()
        log.create_topic("t", LogConfig(num_partitions=4))
        c = BrokerCluster(3)
        c.create_topic("t", LogConfig(num_partitions=4))
        for key in (b"k", b"alpha", b"beta"):
            p_log, _ = log.produce("t", b"v", key=key)
            p_clu, _ = c.produce("t", b"v", key=key)
            assert p_log == p_clu == default_partition([key], 4, 0)


# ----------------------------------------------------------- follower reads
class TestFollowerReads:
    def test_follower_reads_never_return_records_above_hw(self):
        """Fast-tier acceptance: an in-sync follower serves only below the
        high watermark, even while the leader holds unreplicated records."""
        c = make_cluster(parts=1)
        c.produce_batch("t", [b"a", b"b"], partition=0, acks="all")  # hw=2
        leader = c.leader_for("t", 0)
        # leader-only suffix: above the HW until a replication pass runs
        c.broker_append(leader, "t", 0, [b"x", b"y", b"z"], acks=1)
        follower = next(
            b for b in c.metadata("t")[0].replicas if b != leader
        )
        batch = c.broker_fetch(follower, "t", 0, 0, 100, allow_follower=True)
        assert [bytes(v) for v in batch.values] == [b"a", b"b"]  # capped at hw
        c.replicate_all()  # suffix replicates; hw advances to 5
        batch = c.broker_fetch(follower, "t", 0, 0, 100, allow_follower=True)
        assert len(batch) == 5

    def test_follower_fetch_requires_flag_and_isr_membership(self):
        c = make_cluster(parts=1)
        c.produce_batch("t", [b"a"], partition=0, acks="all")
        m = c.metadata("t")[0]
        follower = next(b for b in m.replicas if b != m.leader)
        with pytest.raises(NotLeaderError):
            c.broker_fetch(follower, "t", 0, 0, 10)  # no flag -> leader only
        # an out-of-sync replica must never serve: its log may diverge
        c._ctl("t", 0).isr.discard(follower)
        with pytest.raises(NotLeaderError):
            c.broker_fetch(follower, "t", 0, 0, 10, allow_follower=True)

    def test_cluster_consumer_falls_back_to_follower_on_dead_leader(self):
        c = make_cluster(parts=1)
        msgs = [f"m{i}".encode() for i in range(40)]
        c.produce_batch("t", msgs, partition=0, acks="all")
        cons = ClusterConsumer(c, follower_reads=True)
        assert len(cons.fetch("t", 0, 0, 100)) == 40  # caches the leader
        # leader dies; controller hasn't noticed (deferred election)
        c.kill_broker(c.leader_for("t", 0), defer_election=True)
        batch = cons.fetch("t", 0, 10, 100)
        assert [bytes(v) for v in batch.values] == msgs[10:]
        assert cons.follower_fetches >= 1

    def test_facade_read_serves_below_hw_while_election_pending(self):
        """The StreamBackend read path keeps answering from an in-sync
        follower while the dead leader awaits election — and recovers to
        the new leader afterwards."""
        c = make_cluster(parts=1)
        msgs = [f"m{i}".encode() for i in range(30)]
        c.produce_batch("t", msgs, partition=0, acks="all")
        old_leader = c.leader_for("t", 0)
        c.kill_broker(old_leader, defer_election=True)
        assert c.leader_for("t", 0) == old_leader  # election still pending
        got = c.read("t", 0, 0, 100)
        assert [bytes(v) for v in got.values] == msgs
        c.replicate_all()  # the daemon's pass completes the election
        assert c.leader_for("t", 0) != old_leader
        assert [bytes(v) for v in c.read("t", 0, 0, 100).values] == msgs


# ------------------------------------------------------- replication daemon
class TestReplicationService:
    def test_daemon_advances_hw_without_explicit_ticks(self):
        c = make_cluster(parts=2)
        svc = c.start_replication(interval_s=0.002)
        try:
            leader = c.leader_for("t", 0)
            c.broker_append(leader, "t", 0, [b"a", b"b", b"c"], acks=1)
            wait_until(
                lambda: c.metadata("t")[0].high_watermark == 3,
                msg="daemon HW advance",
            )
        finally:
            c.stop_replication()
        assert svc.errors == []
        assert not svc.running

    def test_daemon_completes_deferred_election(self):
        c = make_cluster(parts=1)
        c.produce_batch("t", [b"x"], partition=0, acks="all")
        with ReplicationService(c, interval_s=0.002) as svc:
            victim = c.leader_for("t", 0)
            c.kill_broker(victim, defer_election=True)
            wait_until(
                lambda: (
                    c.leader_for("t", 0) not in (victim, None)
                    and c.brokers[c.leader_for("t", 0)].up
                ),
                msg="background election",
            )
        assert svc.errors == []

    def test_start_stop_idempotent(self):
        c = make_cluster(parts=1)
        svc = ReplicationService(c, interval_s=0.01)
        assert svc.start() is svc.start()
        assert svc.running
        svc.stop()
        svc.stop()
        assert not svc.running
        # restartable after stop
        svc.start()
        assert svc.running
        svc.stop()

    def test_read_range_forces_pass_when_daemon_hw_is_stale(self):
        """With a daemon registered but between ticks, a read_range that
        falls short on a stale HW must force one replication pass and
        serve, not raise a spurious OffsetOutOfRange."""
        c = make_cluster(parts=1)
        c.produce_batch("t", [b"a"] * 5, partition=0, acks="all")  # hw=5
        leader = c.leader_for("t", 0)
        c.broker_append(leader, "t", 0, [b"b"] * 5, acks=1)  # leo=10, hw=5
        # pose as a running daemon that never ticks: deterministic staleness
        svc = ReplicationService(c, interval_s=60.0)
        svc._threads = [threading.main_thread()]
        c._services.append(svc)
        try:
            assert c._daemon_active
            got = c.read_range("t", 0, 0, 10)
            assert len(got) == 10
        finally:
            c._services = []

    def test_workers_exit_when_cluster_dropped_without_stop(self):
        """The daemon holds its cluster weakly: dropping the last outside
        reference (without calling stop_replication) lets the cluster be
        collected and the workers exit on their next sweep."""
        import gc

        c = make_cluster(parts=1)
        svc = c.start_replication(interval_s=0.01)
        threads = list(svc._threads)
        del c
        gc.collect()
        deadline = time.monotonic() + 5
        while any(t.is_alive() for t in threads) and time.monotonic() < deadline:
            time.sleep(0.02)
        assert not any(t.is_alive() for t in threads)
        assert svc.cluster is None

    def test_daemon_keeps_acked_records_on_isr_through_acks1_traffic(self):
        """acks=1 appends interleaved with daemon passes must still leave
        every replica converged once traffic stops."""
        c = make_cluster(parts=1)
        with ReplicationService(c, interval_s=0.001):
            for i in range(50):
                c.produce_batch("t", [f"r{i}".encode()], partition=0, acks=1)
            wait_until(
                lambda: c.metadata("t")[0].high_watermark == 50,
                msg="daemon catch-up",
            )
        for b in c.metadata("t")[0].replicas:
            assert c.brokers[b].log.end_offset("t", 0) == 50


# ----------------------------------------------------- mid-append failures
class TestMidAppendLeaderDeath:
    def test_committed_batch_acked_once_when_pushed_follower_wins_election(self):
        """Leader dies between its local append and the commit, with one
        follower mid-epoch-reconciliation (normal post-election state):
        the direct-pushed follower wins the election, so the batch IS
        committed — the ack must be given (hw > last), not withheld, or
        the client retry would append the acked records a second time."""
        c = make_cluster(parts=1)  # replicas [0,1,2], leader 0
        c.produce_batch("t", [b"base"], partition=0, acks="all")
        ctl = c._meta[("t", 0)]
        # post-election shape: follower 1 current, follower 2 missed the
        # epoch (still in ISR, reconciles on its next fetch)
        ctl.epoch += 1
        ctl.epoch_starts[ctl.epoch] = 1
        ctl.synced_epoch[0] = ctl.epoch
        ctl.synced_epoch[1] = ctl.epoch

        orig = c._commit_batch
        died = []

        def dying_commit(ctl, values, keys, now_ms, first, last,
                         producer=None, **kw):
            if not died:
                died.append(0)
                c.brokers[0].alive = False  # dies append -> commit
            orig(ctl, values, keys, now_ms, first, last, producer, **kw)

        c._commit_batch = dying_commit
        prod = ClusterProducer(c, acks="all")
        p, first, last = prod.send_batch("t", [b"x1", b"x2"], partition=0)
        assert died and (first, last) == (1, 2)
        assert ctl.hw == 3  # committed on the new leader (the pushed follower)
        got = c.read_range("t", 0, 0, 3)
        assert [bytes(v) for v in got.values] == [b"base", b"x1", b"x2"]
        assert c.end_offset("t", 0) == 3  # exactly once — no retry duplicate
        # the deposed leader reconciles and converges on rejoin
        c.restart_broker(0)
        c.replicate_all()
        assert c.brokers[0].log.end_offset("t", 0) == 3

    def test_uncommitted_batch_not_acked_when_unpushed_follower_wins(self):
        """Same death, but the election winner never received the batch:
        the ack must be withheld (hw <= last) and the client retry lands
        the records on the new leader — zero acked loss, zero duplicates."""
        c = make_cluster(parts=1)
        c.produce_batch("t", [b"base"], partition=0, acks="all")
        ctl = c._meta[("t", 0)]
        ctl.epoch += 1
        ctl.epoch_starts[ctl.epoch] = 1
        ctl.synced_epoch[0] = ctl.epoch  # leader current
        # followers 1 and 2 both stale: the winner won't have the batch

        orig = c._commit_batch
        died = []

        def dying_commit(ctl, values, keys, now_ms, first, last,
                         producer=None, **kw):
            if not died:
                died.append(0)
                c.brokers[0].alive = False
            orig(ctl, values, keys, now_ms, first, last, producer, **kw)

        c._commit_batch = dying_commit
        prod = ClusterProducer(c, acks="all")
        p, first, last = prod.send_batch("t", [b"x1", b"x2"], partition=0)
        assert died and (first, last) == (1, 2)  # acked on the retry
        got = c.read_range("t", 0, 0, 3)
        assert [bytes(v) for v in got.values] == [b"base", b"x1", b"x2"]
        assert c.end_offset("t", 0) == 3


def test_restart_broker_with_deferred_dead_leader_mirrors_offsets():
    """A rejoin that hits an offline partition (recorded leader dead with
    the election deferred, no other live ISR member) must skip it and
    still mirror the replicated offset store onto the restarted broker."""
    c = BrokerCluster(2)
    c.create_topic("t", LogConfig(num_partitions=1, replication_factor=2))
    a = c.leader_for("t", 0)
    b = 1 - a
    tp = TopicPartition("t", 0)
    c.commit_offset("g", tp, 5)
    c.kill_broker(b)
    c.kill_broker(a, defer_election=True)  # leader stays pointed at dead a
    c.restart_broker(b)  # must not raise PartitionOffline
    assert c.brokers[b].log.committed_offset("g", tp) == 5


# ------------------------------------------------------ parallel data plane
class TestParallelProduce:
    def test_threaded_producers_to_distinct_partitions_lose_nothing(self):
        c = BrokerCluster(3, default_acks="all")
        c.create_topic("t", LogConfig(num_partitions=4, replication_factor=3))
        n_each = 60

        def run(tid):
            prod = ClusterProducer(c, acks="all")
            for j in range(n_each):
                prod.send_batch("t", [f"p{tid}-{j}".encode()], partition=tid)

        threads = [threading.Thread(target=run, args=(i,)) for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for p in range(4):
            got = c.read_range("t", p, 0, n_each)
            assert [bytes(v) for v in got.values] == [
                f"p{p}-{j}".encode() for j in range(n_each)
            ]

    def test_ingest_num_threads_roundtrip_preserves_order(self):
        log = StreamLog()
        log.create_topic("t", LogConfig(num_partitions=4))
        codec = RawCodec("float32", (3,), "int32", ())
        n = 203
        arrays = {
            "data": np.arange(n * 3, dtype=np.float32).reshape(n, 3),
            "label": np.arange(n, dtype=np.int32),
        }
        msg = data.ingest(
            log, "t", codec, arrays, "D",
            validation_rate=0.2, message_set_size=16, num_threads=4,
        )
        assert msg.total_msg == n
        assert sum(r.length for r in msg.ranges) == n
        # shards map to distinct partitions
        assert len({r.partition for r in msg.ranges}) == 4
        got = data.StreamDataset(log, msg).read()
        np.testing.assert_array_equal(got["label"], arrays["label"])
        np.testing.assert_array_equal(got["data"], arrays["data"])

    def test_ingest_num_threads_on_cluster(self):
        c = BrokerCluster(3, default_acks="all")
        c.create_topic("t", LogConfig(num_partitions=4, replication_factor=3))
        codec = RawCodec("float32", (2,), "int32", ())
        n = 120
        arrays = {
            "data": np.arange(n * 2, dtype=np.float32).reshape(n, 2),
            "label": np.arange(n, dtype=np.int32),
        }
        msg = data.ingest(c, "t", codec, arrays, "D", message_set_size=8,
                          num_threads=4)
        got = data.StreamDataset(c, msg).read()
        np.testing.assert_array_equal(got["label"], arrays["label"])


# ----------------------------------------------------------------- prefetch
class TestPrefetch:
    def test_prefetch_preserves_order_and_content(self):
        src = list(range(100))
        assert list(prefetch_iter(iter(src), 4)) == src

    def test_depth_zero_is_passthrough(self):
        it = prefetch_iter(iter([1, 2]), 0)
        assert not isinstance(it, PrefetchIterator)
        assert list(it) == [1, 2]

    def test_worker_exception_propagates_to_consumer(self):
        def gen():
            yield 1
            raise ValueError("boom")

        it = prefetch_iter(gen(), 2)
        assert next(it) == 1
        with pytest.raises(ValueError, match="boom"):
            next(it)

    def test_close_stops_worker_on_infinite_stream(self):
        def forever():
            i = 0
            while True:
                yield i
                i += 1

        it = prefetch_iter(forever(), 2)
        assert next(it) == 0
        it.close()
        assert not it._thread.is_alive()
        # terminal after close: StopIteration, never a blocked get()
        with pytest.raises(StopIteration):
            next(it)

    def test_next_after_propagated_error_raises_stop_iteration(self):
        def gen():
            raise ValueError("boom")
            yield  # pragma: no cover

        it = prefetch_iter(gen(), 2)
        with pytest.raises(ValueError):
            next(it)
        with pytest.raises(StopIteration):  # error delivered once, then done
            next(it)

    def test_abandoned_iterator_worker_exits_after_gc(self):
        """A consumer that breaks out of a prefetched loop and drops the
        iterator (never calling close()) must not leave the pump thread
        spinning: the pump holds no reference to the iterator, so GC runs
        __del__, which stops it."""
        import gc

        def forever():
            i = 0
            while True:
                yield i
                i += 1

        it = prefetch_iter(forever(), 2)
        assert next(it) == 0
        thread = it._thread
        del it
        gc.collect()
        deadline = time.monotonic() + 5
        while thread.is_alive() and time.monotonic() < deadline:
            time.sleep(0.02)
        assert not thread.is_alive()

    def test_batch_iterator_prefetch_matches_synchronous(self):
        arrays = {"x": np.arange(40)}
        plain = [b["x"] for b in BatchIterator(arrays, 10, seed=3, epochs=2)]
        pre_it = BatchIterator(arrays, 10, seed=3, epochs=2, prefetch=3)
        pre = [b["x"] for b in pre_it]
        assert len(plain) == len(pre) == 8
        for a, b in zip(plain, pre):
            np.testing.assert_array_equal(a, b)

    def test_batch_iterator_close_joins_prefetch_workers(self):
        """Deterministic shutdown: abandoning a prefetched BatchIterator
        mid-epoch and calling close() leaves no pump thread running."""
        arrays = {"x": np.arange(1000)}
        bi = BatchIterator(arrays, 10, epochs=None, prefetch=2)
        it = iter(bi)
        assert isinstance(it, PrefetchIterator)
        next(it)
        bi.close()
        assert not it._thread.is_alive()
        assert bi._prefetchers == []

    def test_prefetch_source_failure_counted_in_daemon_errors(self):
        from repro.core.metrics import default_registry

        reg = default_registry()
        before = reg.counter_value("daemon_errors_total", daemon="boom-src")

        def gen():
            yield 1
            raise ValueError("boom")

        it = PrefetchIterator(gen(), depth=2, name="boom-src")
        with pytest.raises(ValueError, match="boom"):
            list(it)
        after = reg.counter_value("daemon_errors_total", daemon="boom-src")
        assert after == before + 1


class TestDaemonErrorCounters:
    def test_replication_daemon_counts_quorum_window_retries(self):
        """A controller-quorum outage under the daemon is an *expected*
        retry (daemon_retries), never an unexpected daemon_errors."""
        c = make_cluster(parts=2)

        def retries():
            return c.metrics.counter_value(
                "daemon_retries_total", daemon="replication")

        assert retries() == 0
        # lose quorum (kill 2 of 3 nodes), then crash a partition leader
        # undetected: the daemon's election attempt hits
        # ControllerUnavailable every pass until quorum returns
        for nid in sorted(c.controller.nodes)[:2]:
            c.controller.kill_node(nid)
        c.kill_broker(0, defer_election=True)
        with c.start_replication(interval_s=0.005):
            wait_until(lambda: retries() > 0, msg="daemon retry counter")
        assert c.metrics.counter_value(
            "daemon_errors_total", daemon="replication") == 0


# ------------------------------------------------------------ serving layer
def _fabricated_result(reg):
    spec = reg.register_model("copd-mlp")
    cfg = reg.create_configuration([spec.model_id])
    dep = reg.deploy(cfg.config_id, "inference")
    codec = RawCodec("float32", (3,), "int32", ())
    reg.upload_result(
        dep.deployment_id, spec.model_id, {}, {},
        input_format=codec.FORMAT, input_config=codec.input_config(),
    )
    return reg.results_for(dep.deployment_id)[-1].result_id


class TestParallelPolling:
    def _deployment(self, log, parallel):
        from repro.serve import InferenceDeployment

        reg = core.Registry()
        return InferenceDeployment(
            log, reg, _fabricated_result(reg),
            predict_fn=lambda d: d["data"][:, :1],
            input_topic="requests", output_topic="preds",
            replicas=2, parallel_poll=parallel,
        )

    @pytest.mark.parametrize("parallel", [False, True])
    def test_poll_all_processes_every_request(self, parallel):
        log = StreamLog()
        log.create_topic("requests", LogConfig(num_partitions=2))
        infer = self._deployment(log, parallel)
        reqs = np.arange(60, dtype=np.float32).reshape(20, 3)
        log.produce_batch("requests", [r.tobytes() for r in reqs[:10]], partition=0)
        log.produce_batch("requests", [r.tobytes() for r in reqs[10:]], partition=1)
        try:
            assert infer.drain() == 20
            assert log.end_offset("preds", 0) == 20
        finally:
            infer.close()

    def test_parallel_poll_output_order_matches_serial(self):
        """Parallel ticks publish in replica order, so the output topic's
        record order is identical to a serial deployment's."""
        outs = {}
        for parallel in (False, True):
            log = StreamLog()
            log.create_topic("requests", LogConfig(num_partitions=2))
            infer = self._deployment(log, parallel)
            reqs = np.arange(60, dtype=np.float32).reshape(20, 3)
            log.produce_batch("requests", [r.tobytes() for r in reqs[:10]], partition=0)
            log.produce_batch("requests", [r.tobytes() for r in reqs[10:]], partition=1)
            try:
                infer.drain()
            finally:
                infer.close()
            outs[parallel] = [
                bytes(v) for v in log.read("preds", 0, 0, 100).values
            ]
        assert outs[True] == outs[False]

    def test_parallel_poll_publishes_healthy_replicas_when_one_fails(self):
        """One replica's failed predict must not discard a sibling's
        already-polled work: healthy outputs publish, then the error
        surfaces."""
        from repro.serve import InferenceDeployment

        log = StreamLog()
        log.create_topic("requests", LogConfig(num_partitions=2))
        reg = core.Registry()

        def predict(d):
            if np.any(d["data"] < 0):
                raise RuntimeError("poisoned batch")
            return d["data"][:, :1]

        infer = InferenceDeployment(
            log, reg, _fabricated_result(reg), predict_fn=predict,
            input_topic="requests", output_topic="preds",
            replicas=2, parallel_poll=True,
        )
        bad = -np.ones((10, 3), dtype=np.float32)
        good = np.ones((10, 3), dtype=np.float32)
        log.produce_batch("requests", [r.tobytes() for r in bad], partition=0)
        log.produce_batch("requests", [r.tobytes() for r in good], partition=1)
        try:
            with pytest.raises(RuntimeError, match="poisoned"):
                infer.poll_all()
            # the healthy replica's predictions still reached the output
            assert log.end_offset("preds", 0) == 10
        finally:
            infer.close()


# -------------------------------------------------------------- stress test
@pytest.mark.slow
def test_stress_concurrent_produce_consume_no_loss_no_dup():
    """N producer threads + M group consumers + the replication daemon on
    one cluster: every produced record lands exactly once per partition in
    produced order, the high watermark never regresses, and the consumer
    group sees exactly the produced set."""
    c = BrokerCluster(3, default_acks="all")
    parts, n_producers, n_each = 4, 4, 250
    c.create_topic("t", LogConfig(num_partitions=parts, replication_factor=3))
    c.start_replication(interval_s=0.002, workers=2)
    stop_monitor = threading.Event()
    hw_regressions: list[tuple] = []

    def monitor():
        last = {p: 0 for p in range(parts)}
        while not stop_monitor.is_set():
            for p, m in c.metadata("t").items():
                if m.high_watermark < last[p]:
                    hw_regressions.append((p, last[p], m.high_watermark))
                last[p] = m.high_watermark
            time.sleep(0.002)

    def produce(tid):
        prod = ClusterProducer(c, acks="all")
        sent = 0
        while sent < n_each:
            n = min(8, n_each - sent)
            vals = [f"p{tid}-{sent + j}".encode() for j in range(n)]
            prod.send_batch("t", vals, partition=tid % parts)
            sent += n

    group = ConsumerGroup(c, "stress", ["t"])
    members = [group.join(f"m{i}") for i in range(2)]
    consumed: dict[int, list[bytes]] = {p: [] for p in range(parts)}
    consumed_lock = threading.Lock()
    total = n_producers * n_each
    done_consuming = threading.Event()

    def consume(member):
        while not done_consuming.is_set():
            got_any = False
            for batch in member.poll(max_records=64):
                got_any = True
                with consumed_lock:
                    consumed[batch.partition].extend(
                        bytes(v) for v in batch.values
                    )
            member.commit()
            with consumed_lock:
                if sum(len(v) for v in consumed.values()) >= total:
                    done_consuming.set()
            if not got_any:
                time.sleep(0.002)

    threads = (
        [threading.Thread(target=monitor)]
        + [threading.Thread(target=produce, args=(i,)) for i in range(n_producers)]
        + [threading.Thread(target=consume, args=(m,)) for m in members]
    )
    for t in threads[1:]:
        t.start()
    threads[0].start()
    for t in threads[1 : 1 + n_producers]:
        t.join(timeout=60)
        assert not t.is_alive(), "producer hung"
    assert done_consuming.wait(timeout=60), (
        f"consumers drained only "
        f"{sum(len(v) for v in consumed.values())}/{total} records"
    )
    for t in threads[1 + n_producers :]:
        t.join(timeout=10)
    stop_monitor.set()
    threads[0].join(timeout=10)
    c.stop_replication()

    assert hw_regressions == [], f"HW regressed: {hw_regressions}"
    # per partition: log contents are exactly the one producer's records in
    # order (offsets contiguous, nothing lost, nothing duplicated)
    for p in range(parts):
        expect = [f"p{p}-{j}".encode() for j in range(n_each)]
        got = c.read_range("t", p, 0, n_each)
        assert [bytes(v) for v in got.values] == expect, f"partition {p}"
        assert c.end_offset("t", p) == n_each
        # consumer group saw exactly the produced set, in order
        assert consumed[p] == expect, f"partition {p} consumer view"
